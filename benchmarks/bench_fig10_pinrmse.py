"""Fig. 10: PINRMSE (interpolate the hold-out-error curve directly) vs
PIChol.  The paper's finding: PINRMSE can select λ far from optimal while
PIChol stays on it; we report the selected-λ log-distance of both."""
import jax.numpy as jnp
import numpy as np

from repro.core import cv

from .common import emit, ridge_problem


def run():
    out = {}
    for seed in range(3):
        x, y = ridge_problem(256, seed=seed)
        folds = cv.make_folds(x, y, 5)
        lams = jnp.logspace(-3, 2, 31)
        r_e = cv.cv_exact_cholesky(folds, lams)
        r_pi = cv.cv_picholesky(folds, lams, g=4, block=64)
        r_pin = cv.cv_pinrmse(folds, lams, g=4)
        d_pi = abs(np.log10(r_pi.best_lam) - np.log10(r_e.best_lam))
        d_pin = abs(np.log10(r_pin.best_lam) - np.log10(r_e.best_lam))
        # curve-level fit quality
        fit_pi = float(np.max(np.abs(r_pi.errors - r_e.errors)
                              / (np.abs(r_e.errors) + 1e-30)))
        fit_pin = float(np.max(np.abs(r_pin.errors - r_e.errors)
                               / (np.abs(r_e.errors) + 1e-30)))
        emit(f"fig10_seed{seed}", 0.0,
             f"dlog_pichol={d_pi:.2f} dlog_pinrmse={d_pin:.2f} "
             f"curve_dev_pichol={fit_pi:.2f} curve_dev_pinrmse={fit_pin:.2f}")
        out[seed] = (d_pi, d_pin)
    return out
