"""Fig. 11: NRMSE of the piCholesky least-squares fit as a function of λ.
Paper reports max NRMSE 0.0457 on MNIST; we reproduce the same statistic on
the synthetic polynomial-kernel features."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import packing, picholesky

from .common import emit, ridge_problem


def run():
    h = 256
    x, _ = ridge_problem(h)
    hess = x.T @ x / x.shape[0]   # spectrum ~ O(1): non-trivial fit regime
    sample = picholesky.choose_sample_lambdas(1e-3, 1.0, 4)
    model = picholesky.fit(hess, sample, 2, block=32)
    lams = jnp.logspace(-3, 0, 31)
    eye = jnp.eye(h, dtype=hess.dtype)
    l_e = jax.vmap(lambda l: jnp.linalg.cholesky(hess + l * eye))(lams)
    t_e = packing.pack_tril(l_e, 32)
    t_i = model.eval_packed(lams)
    # NRMSE per λ: rmse over entries / std of exact entries
    err = np.asarray(jnp.sqrt(jnp.mean((t_i - t_e) ** 2, axis=1)))
    denom = np.asarray(jnp.std(t_e, axis=1)) + 1e-30
    nrmse = err / denom
    emit("fig11_nrmse", 0.0,
         f"max={nrmse.max():.4f} median={np.median(nrmse):.4f}")
    return {"max_nrmse": float(nrmse.max())}
