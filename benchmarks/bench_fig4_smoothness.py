"""Fig. 4: entries of L(λ) lie on smooth curves that a 2nd-order polynomial
fit from g samples traces closely.  Reports the max relative deviation of
interpolated vs exact entries over a dense λ grid."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import picholesky

from .common import emit, ridge_problem, timeit


def run():
    h = 256
    x, _ = ridge_problem(h)
    # normalize so the λ sweep is comparable to the spectrum (the regime
    # where interpolation is non-trivial — cf. paper h=16384 plots)
    hess = x.T @ x / x.shape[0]
    sample = picholesky.choose_sample_lambdas(1e-3, 1.0, 6)
    model = picholesky.fit(hess, sample, 2, block=32)
    lams = jnp.logspace(-3, 0, 50)
    l_i = model.eval_factor(lams)
    eye = jnp.eye(h, dtype=hess.dtype)
    l_e = jax.vmap(lambda l: jnp.linalg.cholesky(hess + l * eye))(lams)
    # sample a spread of entries like the figure
    idx = [(0, 0), (h // 2, h // 4), (h - 1, h - 1), (h - 1, 0), (h // 3, h // 3)]
    worst = 0.0
    for (i, j) in idx:
        e = np.asarray(l_e[:, i, j])
        p = np.asarray(l_i[:, i, j])
        worst = max(worst, float(np.max(np.abs(p - e)) /
                                 (np.max(np.abs(e)) + 1e-30)))
    t = timeit(lambda: model.eval_packed(lams))
    emit("fig4_smoothness", t, f"max_entry_rel_dev={worst:.2e}")
    return {"max_entry_rel_dev": worst}
