"""Roofline table from the dry-run artifacts (results/dryrun/*.json).

Not a paper table — this is deliverable (g): per (arch × shape × mesh),
the three roofline terms, the dominant bottleneck, and
MODEL_FLOPS / HLO_FLOPS."""
import glob
import json
import os

from .common import emit


def run():
    files = sorted(glob.glob("results/dryrun/*.json"))
    if not files:
        emit("roofline", 0.0, "no dry-run artifacts (run repro.launch.dryrun)")
        return {}
    out = {}
    for f in files:
        with open(f) as fh:
            r = json.load(fh)
        cell = r["cell"]
        if r["status"] != "ok":
            emit(f"roofline_{cell}", 0.0, f"status={r['status']}")
            continue
        roof = r["roofline"]
        uf = r.get("useful_flops_frac")
        emit(f"roofline_{cell}", roof["step_s"] if "step_s" in roof else 0.0,
             f"bottleneck={roof['bottleneck']} compute={roof['compute_s']:.3e} "
             f"mem={roof['memory_s']:.3e} coll={roof['collective_s']:.3e} "
             f"useful_flops={uf if uf is None else round(uf, 3)}")
        out[cell] = roof
    return out
