"""Roofline table from the dry-run artifacts (results/dryrun/*.json).

Not a paper table — this is deliverable (g): per (arch × shape × mesh),
the three roofline terms, the dominant bottleneck, and
MODEL_FLOPS / HLO_FLOPS.

Under ``REPRO_BENCH_SMOKE=1`` with no artifacts on disk, one CV-sweep
cell (h=128, 2 folds) is dry-run **in process** — AOT-lowered and
roofline-scored through the same
:func:`repro.distributed.autotune.lower_sweep` path the autotuner uses,
zero executions — and written to ``results/dryrun/`` in the
``run_cell`` artifact schema, so CI exercises the artifact→table flow
without the multi-hundred-device launch sweep.
"""
import glob
import json
import os

from .common import SMOKE, emit


def _smoke_artifact(out_dir: str = "results/dryrun") -> str:
    """AOT-lower one tiny CV sweep and record its roofline as a dry-run
    artifact (schema-compatible with ``repro.launch.dryrun.run_cell``)."""
    import numpy as np
    import jax.numpy as jnp

    from repro.core.engine import CVEngine, PiCholeskyStrategy
    from repro.core.folds import make_folds
    from repro.distributed import autotune
    from repro.distributed import roofline as rl

    h, k, q = 128, 2, 8
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(8 * h, h)), jnp.float32)
    y = jnp.asarray(rng.normal(size=(8 * h,)), jnp.float32)
    folds = make_folds(x, y, k)
    lams = jnp.logspace(-3, 2, q, dtype=jnp.float32)
    eng = CVEngine(PiCholeskyStrategy(g=4, block=32), donate=False)
    compiled, chips = autotune.lower_sweep(eng, folds, lams)
    roof = rl.roofline(compiled, chips, hw=rl.detect_hw())
    result = {
        "cell": f"cv_sweep×h{h}k{k}q{q}×smoke",
        "status": "ok",
        "note": "in-process smoke dry-run (lowered, never executed)",
        "chips": chips,
        "roofline": roof.summary(),
    }
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, "cv_sweep__smoke.json")
    with open(path, "w") as f:
        json.dump(result, f, indent=1)
    return path


def run():
    files = sorted(glob.glob("results/dryrun/*.json"))
    if not files and SMOKE:
        files = [_smoke_artifact()]
    if not files:
        emit("roofline", 0.0, "no dry-run artifacts (run repro.launch.dryrun)")
        return {}
    out = {}
    for f in files:
        with open(f) as fh:
            r = json.load(fh)
        cell = r["cell"]
        if r["status"] != "ok":
            emit(f"roofline_{cell}", 0.0, f"status={r['status']}")
            continue
        roof = r["roofline"]
        uf = r.get("useful_flops_frac")
        emit(f"roofline_{cell}", roof["step_s"] if "step_s" in roof else 0.0,
             f"bottleneck={roof['bottleneck']} compute={roof['compute_s']:.3e} "
             f"mem={roof['memory_s']:.3e} coll={roof['collective_s']:.3e} "
             f"useful_flops={uf if uf is None else round(uf, 3)}")
        out[cell] = roof
    return out
