"""Multi-tenant serving bench: latency / throughput / cache hit-rate under
the seeded Zipf traffic mix → ``BENCH_serving.json``.

    PYTHONPATH=src python benchmarks/bench_serving.py
    PYTHONPATH=src python -m benchmarks.run serving

The record is the serving layer's committed trajectory: queue-latency
percentiles (p50/p99), request throughput, the shared cache's cross-tenant
hit-rate, per-tenant stat partitions, and the fidelity audit — every
unique problem's served argmin must be bit-for-bit the solo cold sweep's
(Wilson et al., arXiv:2003.00617: shared approximate CV must *monitor*
per-tenant assessment quality, not assume it).
"""
from __future__ import annotations

import dataclasses
import sys
import time

if __package__ in (None, ""):               # direct script execution
    import pathlib

    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))
    __package__ = "benchmarks"

import jax

if __name__ == "__main__":
    jax.config.update("jax_enable_x64", True)

import numpy as np

from .common import SMOKE, emit, emit_json


def run() -> None:
    from repro.core import engine, factor_cache
    from repro.serving import CVSweepServer, ServerConfig, TrafficConfig, \
        make_traffic

    if SMOKE:
        cfg = TrafficConfig(n_requests=12, n_tenants=3, n_problems=3,
                            h=16, n=128, grid_sizes=(9, 13),
                            shifted_grid_every=5)
        block, max_batch = 8, 4
    else:
        cfg = TrafficConfig(n_requests=48, n_tenants=6, n_problems=8,
                            h=96, n=768, grid_sizes=(17, 25, 33),
                            shifted_grid_every=11)
        block, max_batch = 16, 8
    strat = engine.PiCholeskyStrategy(g=4, block=block)
    srv = CVSweepServer(strat, config=ServerConfig(max_batch=max_batch))

    reqs = make_traffic(cfg)
    # warm the jit caches on a throwaway problem — one request per grid
    # shape — so the measured latencies are service latencies, not XLA
    # compile times (the stacked-dispatch shapes still compile in-band,
    # as they would in a live server)
    from repro.serving import SweepRequest
    from repro.testing import strategies as props
    warm_folds = make_traffic(dataclasses.replace(
        cfg, n_requests=1, n_tenants=1, n_problems=1,
        seed=cfg.seed + 777))[0].folds
    for q in cfg.grid_sizes:
        srv.submit(SweepRequest("_warmup", warm_folds, props.log_grid(q)))
    srv.drain()
    warm_stats = srv.cache.stats

    t0 = time.perf_counter()
    for r in reqs:
        srv.submit(r)
    resps = srv.drain()
    wall = time.perf_counter() - t0

    lat = np.array([r.latency_s for r in resps])
    stats = srv.stats
    # traffic-only cache counters (the warmup round is excluded)
    hits = stats["cache"]["hits"] - warm_stats["hits"]
    misses = stats["cache"]["misses"] - warm_stats["misses"]
    tenants = {t: rec for t, rec in stats["tenants"].items()
               if t.startswith("tenant-")}
    sharing = sum(1 for rec in tenants.values() if rec["hits"])

    # fidelity audit: every unique (problem, grid) served bit-for-bit as a
    # solo cold sweep of the same problem on a fresh cache
    resp_by_id = {r.request_id: r for r in resps}   # service order ≠ submit
    by_problem = {}
    for req in reqs:
        key = (id(req.folds), id(req.lams))
        by_problem.setdefault(key, (req, []))[1].append(
            resp_by_id[req.request_id])
    audits = []
    for req, served in by_problem.values():
        solo = engine.CVEngine(strat, cache=factor_cache.FactorCache(),
                               reuse="covering", cache_anchors=True
                               ).run(req.folds, req.lams)
        audits.append(dict(
            n_served=len(served),
            argmin_match=all(r.result.best_lam == solo.best_lam
                             for r in served),
            bitwise_match=all(np.array_equal(r.result.errors, solo.errors)
                              for r in served)))
    argmin_match = all(a["argmin_match"] for a in audits)

    record = {
        "schema": "bench_serving/v1",
        "smoke": SMOKE,
        "jax_backend": jax.default_backend(),
        "x64": bool(jax.config.jax_enable_x64),
        "config": {
            "n_requests": cfg.n_requests, "n_tenants": cfg.n_tenants,
            "n_problems": cfg.n_problems, "h": cfg.h, "n": cfg.n,
            "k": cfg.k, "zipf_a": cfg.zipf_a, "seed": cfg.seed,
            "grid_sizes": list(cfg.grid_sizes),
            "shifted_grid_every": cfg.shifted_grid_every,
            "block": block, "max_batch": max_batch,
            "strategy": strat.name,
        },
        "latency": {
            "p50_s": float(np.percentile(lat, 50)),
            "p99_s": float(np.percentile(lat, 99)),
            "mean_s": float(lat.mean()),
            "max_s": float(lat.max()),
        },
        "throughput_rps": len(resps) / wall,
        "wall_s": wall,
        "cache": {
            "hits": hits, "misses": misses,
            "hit_rate": hits / (hits + misses) if hits + misses else 0.0,
            "anchor_hits": stats["cache"]["anchor_hits"],
            "entries": stats["cache"]["entries"],
            "evictions": stats["cache"]["evictions"],
            "bytes": stats["cache"]["bytes"],
            "bytes_saved": stats["cache"]["bytes_saved"],
            "live_bytes_saved": stats["cache"]["live_bytes_saved"],
            "tenants_sharing": sharing,
        },
        "tenants": tenants,
        "batching": {
            "dispatches": stats["dispatches"],
            "batch_mean": stats["batch_mean"],
            "unique_problems": len(by_problem),
        },
        "fidelity": {
            "problems_audited": len(audits),
            "argmin_match": argmin_match,
            "bitwise_match": all(a["bitwise_match"] for a in audits),
        },
    }
    emit("serving_p50_latency", record["latency"]["p50_s"],
         f"p99={record['latency']['p99_s']:.3f}s")
    emit("serving_throughput", 0.0,
         f"rps={record['throughput_rps']:.2f}")
    emit("serving_hit_rate", 0.0,
         f"hit_rate={record['cache']['hit_rate']:.3f}"
         f",sharing={sharing}/{cfg.n_tenants}")
    emit("serving_fidelity", 0.0, f"argmin_match={argmin_match}")
    emit_json("BENCH_serving.json", record)


if __name__ == "__main__":
    run()
