"""Table 1: triangular vectorization strategies — row-wise vs full-matrix vs
the aligned scheme (paper: recursive; here: tile-major, its TPU analogue).

Reports vec / fit / interp times per strategy per dimension.  The expected
ordering from the paper reproduces: full-matrix has the cheapest vec but ~2×
the fit+interp work; row-wise pays unaligned copies; the aligned scheme wins
the total."""
import jax
import jax.numpy as jnp

from repro.core import packing, picholesky

from .common import SIZES, emit, timeit


def _bench_strategy(hess, sample, lams, pack, unpack, dim_packed):
    eye = jnp.eye(hess.shape[0], dtype=hess.dtype)
    factors = jax.vmap(lambda l: jnp.linalg.cholesky(hess + l * eye))(sample)

    vec = jax.jit(pack)
    t_vec = timeit(vec, factors)
    targets = vec(factors)

    v = picholesky.vandermonde(sample, 2).astype(targets.dtype)

    def fit(t):
        return jnp.linalg.solve(v.T @ v, v.T @ t)

    fitj = jax.jit(fit)
    t_fit = timeit(fitj, targets)
    theta = fitj(targets)

    dense_v = picholesky.vandermonde(lams, 2).astype(targets.dtype)

    def interp(th):
        rows = dense_v @ th
        return unpack(rows)

    interpj = jax.jit(interp)
    t_interp = timeit(interpj, theta)
    return t_vec, t_fit, t_interp


def run():
    out = {}
    for h in SIZES:
        x = jax.random.normal(jax.random.PRNGKey(0), (2 * h, h), jnp.float32)
        hess = (x.T @ x + h * jnp.eye(h)).astype(jnp.float64)
        sample = picholesky.choose_sample_lambdas(1e-2, 1.0, 5)
        lams = jnp.logspace(-2, 0, 31)

        strategies = {
            "rowwise": (lambda f: packing.pack_tril_rowwise(f),
                        lambda r: packing.unpack_tril_rowwise(r, h)),
            "fullmatrix": (lambda f: packing.pack_tril_full(f),
                           lambda r: r.reshape(-1, h, h)),
            "tile_packed": (lambda f: packing.pack_tril(f, 128),
                            lambda r: packing.unpack_tril(r, h, 128)),
        }
        d = h * (h + 1) // 2
        work = {"rowwise": d, "fullmatrix": h * h,
                "tile_packed": packing.packed_size(h, 128)}
        for name, (pack, unpack) in strategies.items():
            tv, tf, ti = _bench_strategy(hess, sample, lams, pack, unpack, h)
            total = tv + tf + ti
            # work ratio = fit/interp GEMM columns relative to the minimal D
            # (paper requirement (ii)); alignment is the TPU story — on this
            # CPU container absolute times are not indicative of TPU DMA.
            emit(f"table1_{name}_h{h}", total,
                 f"vec={tv:.4f}s fit={tf:.4f}s interp={ti:.4f}s "
                 f"gemm_work_ratio={work[name] / d:.3f}")
            out[(name, h)] = (tv, tf, ti)
    return out
