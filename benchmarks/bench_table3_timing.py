"""Table 3 / Fig. 6: wall time of the six CV algorithms per fold — plus the
engine-vs-host comparison the unified sweep exists for.

On this container the absolute times are CPU seconds; the reproduction
target is the RELATIVE ordering, the PIChol speedup over Chol
(paper: ~3.8–4.3× at q=31, g=4), and the CVEngine speedup over the eager
host drivers (one jitted compiled sweep vs op-by-op tracing per call)."""
import jax
import jax.numpy as jnp

from repro.core import cv, cv_host, engine

from .common import SIZES, bench_pair, emit, ridge_problem, timeit


def run():
    out = {}
    # the O(d³) factorization term must dominate for the paper's comparison
    # to be meaningful — use the larger sizes regardless of CI scale
    sizes = sorted(set(SIZES + [1024]))[-2:]
    for h in sizes:
        x, y = ridge_problem(h)
        folds = cv.make_folds(x, y, 5)
        lams = jnp.logspace(-3, 2, 31)

        algos = {
            "chol": lambda: cv.cv_exact_cholesky(folds, lams),
            "pichol": lambda: cv.cv_picholesky(folds, lams, g=4, block=64),
            "mchol": lambda: cv.cv_multilevel_cholesky(folds, c=0.0, s=1.5,
                                                       s0=0.1),
            "svd": lambda: cv.cv_svd(folds, lams, mode="full"),
            "tsvd": lambda: cv.cv_svd(folds, lams, mode="truncated",
                                      k_trunc=h // 4),
            "rsvd": lambda: cv.cv_svd(folds, lams, mode="randomized",
                                      k_trunc=h // 4,
                                      key=jax.random.PRNGKey(0)),
        }
        times = {}
        for name, fn in algos.items():
            # warmup=1 excludes XLA compilation (the paper times the math,
            # not the compiler); repeats=1 keeps the harness CI-sized
            t = timeit(fn, repeats=1, warmup=1)
            times[name] = t
            emit(f"table3_{name}_h{h}", t, f"seconds={t:.3f}")
        speedup = times["chol"] / times["pichol"]
        emit(f"table3_speedup_h{h}", 0.0, f"pichol_vs_chol={speedup:.2f}x")

        # ---- engine vs host baseline: same math, one jitted sweep vs the
        # eager per-call-traced drivers.  Engines are prebuilt so the
        # comparison times the sweep, not tracing.
        host = {
            "chol": lambda: cv_host.host_cv_exact_cholesky(folds, lams),
            "pichol": lambda: cv_host.host_cv_picholesky(folds, lams, g=4,
                                                         block=64),
        }
        engines = {
            "chol": engine.CVEngine("exact"),
            "pichol": engine.CVEngine(engine.PiCholeskyStrategy(g=4,
                                                                block=64)),
        }
        for name in host:
            eng = engines[name]
            pair = bench_pair(f"table3_{name}_h{h}", host[name],
                              lambda: eng.run(folds, lams))
            times[f"host_{name}"] = pair["host"]
            times[f"engine_{name}"] = pair["engine"]
        out[h] = times
    return out
