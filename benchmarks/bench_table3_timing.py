"""Table 3 / Fig. 6: wall time of the six CV algorithms per fold — plus the
engine-vs-host comparison the unified sweep exists for, and the λ-sweep
scaling record (time + peak memory at q ∈ {100, 1000}) that the packed
chunked pipeline is accountable to.

On this container the absolute times are CPU seconds; the reproduction
target is the RELATIVE ordering, the PIChol speedup over Chol
(paper: ~3.8–4.3× at q=31, g=4), and the CVEngine speedup over the eager
host drivers (one jitted compiled sweep vs op-by-op tracing per call).

Everything measured here is also emitted machine-readably to
``BENCH_table3.json`` at the repo root (schema ``bench_table3/v1``) so the
perf trajectory is recorded across PRs; ``REPRO_BENCH_SMOKE=1`` re-emits
the same schema on tiny problems for CI."""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cv, cv_host, engine, factor_cache, packing
from repro.core.backends import CountingBackend, ReferenceBackend
from repro.core.precision import resolve_precision

from .common import SIZES, SMOKE, bench_pair, emit, emit_json, ridge_problem, timeit


def _algo_table(sizes) -> dict:
    """The per-h six-algorithm table + engine-vs-host pairs (q = 31)."""
    out = {}
    for h in sizes:
        x, y = ridge_problem(h)
        folds = cv.make_folds(x, y, 5)
        lams = jnp.logspace(-3, 2, 31)
        block = max(16, min(64, h // 8))

        algos = {
            "chol": lambda: cv.cv_exact_cholesky(folds, lams),
            "pichol": lambda: cv.cv_picholesky(folds, lams, g=4, block=block),
            "mchol": lambda: cv.cv_multilevel_cholesky(folds, c=0.0, s=1.5,
                                                       s0=0.1),
            "svd": lambda: cv.cv_svd(folds, lams, mode="full"),
            "tsvd": lambda: cv.cv_svd(folds, lams, mode="truncated",
                                      k_trunc=h // 4),
            "rsvd": lambda: cv.cv_svd(folds, lams, mode="randomized",
                                      k_trunc=h // 4,
                                      key=jax.random.PRNGKey(0)),
        }
        times = {}
        for name, fn in algos.items():
            # warmup=1 excludes XLA compilation (the paper times the math,
            # not the compiler); repeats=1 keeps the harness CI-sized
            t = timeit(fn, repeats=1, warmup=1)
            times[name] = t
            emit(f"table3_{name}_h{h}", t, f"seconds={t:.3f}")
        speedup = times["chol"] / times["pichol"]
        emit(f"table3_speedup_h{h}", 0.0, f"pichol_vs_chol={speedup:.2f}x")
        times["pichol_vs_chol_speedup"] = speedup

        # ---- engine vs host baseline: same math, one jitted sweep vs the
        # eager per-call-traced drivers.  Engines are prebuilt so the
        # comparison times the sweep, not tracing.
        host = {
            "chol": lambda: cv_host.host_cv_exact_cholesky(folds, lams),
            "pichol": lambda: cv_host.host_cv_picholesky(folds, lams, g=4,
                                                         block=block),
        }
        engines = {
            "chol": engine.CVEngine("exact"),
            "pichol": engine.CVEngine(engine.PiCholeskyStrategy(g=4,
                                                                block=block)),
        }
        for name in host:
            eng = engines[name]
            pair = bench_pair(f"table3_{name}_h{h}", host[name],
                              lambda: eng.run(folds, lams))
            times[f"host_{name}"] = pair["host"]
            times[f"engine_{name}"] = pair["engine"]
            times[f"engine_vs_host_{name}"] = pair["speedup"]
        out[str(h)] = times
    return out


def _sweep_scaling(h: int, qs, chunk: int) -> dict:
    """Engine-vs-host timing and peak-memory of the λ sweep as q grows.

    The host driver materializes the dense (q, h, h) interpolated factor
    batch; the engine streams λ in `chunk`-sized packed chunks, so its
    peak should be flat in q (`temp_bytes_chunked`) while the host's and
    the unchunked engine's grow linearly (`est_dense_bytes`).
    """
    x, y = ridge_problem(h)
    folds = cv.make_folds(x, y, 5)
    block = max(16, min(64, h // 8))
    strat = lambda: engine.PiCholeskyStrategy(g=4, block=block)  # noqa: E731
    eng_chunked = engine.CVEngine(strat(), lam_chunk=chunk, donate=False)
    eng_dense = engine.CVEngine(strat(), lam_chunk=None, donate=False)

    per_lam_packed = packing.packed_size(h, block) * 8
    record = {"h": h, "chunk": chunk, "block": block,
              "est_packed_chunk_bytes": chunk * per_lam_packed, "q": {}}
    for q in qs:
        lams = jnp.logspace(-3, 2, q)
        t_host = timeit(lambda: cv_host.host_cv_picholesky(
            folds, lams, g=4, block=block), repeats=1, warmup=1)
        t_eng = timeit(lambda: eng_chunked.run(folds, lams),
                       repeats=1, warmup=1)
        rec = {
            "host_s": t_host,
            "engine_s": t_eng,
            "engine_vs_host": t_host / t_eng,
            "temp_bytes_chunked": eng_chunked.sweep_temp_bytes(folds, lams),
            "temp_bytes_unchunked": eng_dense.sweep_temp_bytes(folds, lams),
            "est_dense_bytes": q * h * h * 8,
        }
        record["q"][str(q)] = rec
        emit(f"table3_sweep_q{q}_h{h}", t_eng,
             f"host={t_host:.3f}s engine={t_eng:.3f}s "
             f"peak_chunked={rec['temp_bytes_chunked']} "
             f"peak_unchunked={rec['temp_bytes_unchunked']}")
    return record


def _warm_vs_cold(h: int, qs, chunk: int) -> dict:
    """Factor-cache replay record: the same sweep cold (fold_state runs,
    cache write-only) vs warm (cache hit, fold_state skipped — zero
    factorizations, asserted via the CountingBackend trace hook).

    Both engines are warmed up once before timing so the comparison is
    factorize+fit+sweep vs replay-only, not compile time.  Measured per
    grid density: the λ-stage is paid by both paths, so the warm advantage
    is largest on coarse grids (the repeated model-assessment pass the
    cache exists for) and approaches the λ-stage floor as q grows.
    """
    x, y = ridge_problem(h)
    folds = cv.make_folds(x, y, 5)
    block = max(16, min(64, h // 8))
    strat = lambda: engine.PiCholeskyStrategy(g=4, block=block)  # noqa: E731

    record = {"h": h, "chunk": chunk, "block": block, "grids": {}}
    for q in qs:
        lams = jnp.logspace(-3, 2, q)
        cache = factor_cache.FactorCache()
        cold_bk = CountingBackend(ReferenceBackend())
        cold = engine.CVEngine(strat(), backend=cold_bk, cache=cache,
                               reuse=False, lam_chunk=chunk, donate=False)
        warm_bk = CountingBackend(ReferenceBackend())
        warm = engine.CVEngine(strat(), backend=warm_bk, cache=cache,
                               lam_chunk=chunk, donate=False)

        r_cold = cold.run(folds, lams)      # compiles + traces the cold path
        t_cold = timeit(lambda: cold.run(folds, lams), repeats=3, warmup=0)
        r_warm = warm.run(folds, lams)      # traces the replay path
        t_warm = timeit(lambda: warm.run(folds, lams), repeats=3, warmup=0)
        rec = {
            "cold_s": t_cold, "warm_s": t_warm,
            "warm_vs_cold_speedup": t_cold / t_warm,
            "cold_trace_cholesky_calls": cold_bk.n_cholesky,
            "warm_trace_cholesky_calls": warm_bk.n_cholesky,
            "cold_n_exact_chol": r_cold.n_exact_chol,
            "warm_n_exact_chol": r_warm.n_exact_chol,
            "cache": cache.stats,
        }
        record["grids"][str(q)] = rec
        emit(f"table3_warmcold_h{h}_q{q}", t_warm,
             f"cold={t_cold:.3f}s warm={t_warm:.3f}s "
             f"speedup={rec['warm_vs_cold_speedup']:.2f}x "
             f"warm_chol={warm_bk.n_cholesky}")
    return record


def _overlap_vs_serial(h: int, k: int, q: int, chunk: int) -> dict:
    """Pipelined async sweep vs the serial staged driver (PR-4 tentpole).

    All three modes run the SAME jitted stage functions (per-fold
    fold_state + per-chunk fold_errors) on one prebuilt engine, so the
    comparison times dispatch strategy, not tracing:

    * ``serial_s``     — ``sweep_async(pipelined=False)``: block after
      every stage dispatch, full grid (the bit-for-bit reference).
    * ``pipelined_s``  — ``sweep_async(pipelined=True)``, full grid:
      non-blocking dispatch with chunk lookahead; isolates the pure
      overlap win (host dispatch hides under device compute).
    * ``early_stop_s`` — pipelined + ``stop_tol=0``: the λ-search workload
      the pipelined sweep exists for — the stream stops once the hold-out
      curve has bottomed out, so tail chunks are never evaluated.

    ``overlap_vs_serial`` (the committed acceptance ratio) is
    serial / early-stop: the wall-clock advantage of the incremental
    pipelined search over the serial full sweep at identical selection
    (``argmin_match`` asserts the early-stopped λ* equals the full
    sweep's).  The λ grid spans (-3, 6) decades so its hold-out minimum
    sits mid-grid — a grid whose minimum hugs the upper edge would leave
    nothing to skip and say nothing about early stopping.
    """
    x, y = ridge_problem(h)
    folds = cv.make_folds(x, y, k)
    block = max(16, min(64, h // 8))
    eng = engine.CVEngine(engine.PiCholeskyStrategy(g=4, block=block),
                          lam_chunk=chunk, donate=False)
    lams = jnp.logspace(-3, 6, q)

    r_serial = eng.run_async(folds, lams, pipelined=False)   # compiles stages
    t_serial = timeit(lambda: eng.run_async(folds, lams, pipelined=False),
                      repeats=3, warmup=0)
    t_pipe = timeit(lambda: eng.run_async(folds, lams), repeats=3, warmup=0)
    r_es = eng.run_async(folds, lams, stop_tol=0.0)
    t_es = timeit(lambda: eng.run_async(folds, lams, stop_tol=0.0),
                  repeats=3, warmup=0)
    info = r_es.extras["engine"]["async"]
    rec = {
        "h": h, "k": k, "q": q, "chunk": chunk, "block": block,
        "serial_s": t_serial, "pipelined_s": t_pipe, "early_stop_s": t_es,
        "pipelined_vs_serial": t_serial / t_pipe,
        "overlap_vs_serial": t_serial / t_es,
        "chunks_total": info["chunks_total"],
        "chunks_evaluated": info["chunks_evaluated"],
        "lams_evaluated": info["lams_evaluated"],
        "argmin_match": bool(r_es.best_lam == r_serial.best_lam),
    }
    emit(f"table3_overlap_h{h}_k{k}_q{q}", t_es,
         f"serial={t_serial:.3f}s pipelined={t_pipe:.3f}s "
         f"early_stop={t_es:.3f}s overlap_vs_serial="
         f"{rec['overlap_vs_serial']:.2f}x "
         f"chunks={info['chunks_evaluated']}/{info['chunks_total']}")
    return rec


def _precision_sweep(h: int, q: int, chunk: int) -> dict:
    """Mixed-precision factor pipeline record (PR-5 tentpole).

    One fp32-native ridge problem swept under three precision policies on
    prebuilt engines (cold each time — no cache), recording:

    * ``cold_s``            — wall clock of the full cold sweep,
    * ``state_bytes``       — the fitted per-fold state payload (Θ + packed
      anchors, measured from the actual cached arrays): on the kernel path
      Θ is the ONLY O(h²) buffer in the whole fused sweep (the
      interpolated factor lives tile-by-tile in registers), so the state
      payload is the sweep's dominant resident factor memory — and every
      cache entry / HBM residency budget is priced in it,
    * ``replay_temp_bytes`` — XLA temp bytes of the λ-stream stage
      (informational: on this CPU container bf16 arithmetic is emulated
      through fp32 temporaries, so compute temps do NOT shrink here; on
      TPU the MXU consumes bf16 natively),
    * ``packed_bytes_per_lam`` — one packed factor at the storage dtype,
    * the selected λ*, for the correctness half of the record.

    Acceptance (non-smoke, enforced by ``scripts/check_bench_schema.py``):
    ``bf16_store`` must deliver ≥1.3× cold-sweep speedup OR ≥1.9×
    state-payload memory reduction vs ``fp32`` (on this container the win
    is memory; on TPU both apply), and ``bf16_refined`` must reproduce the
    fp32 argmin exactly (``argmin_match``).
    """
    x, y = ridge_problem(h)
    x, y = x.astype(jnp.float32), y.astype(jnp.float32)
    folds = cv.make_folds(x, y, 5)
    block = max(16, min(64, h // 8))
    lams = jnp.logspace(-3, 2, q)

    rec = {"h": h, "k": 5, "q": q, "chunk": chunk, "block": block,
           "policies": {}}
    results = {}
    for pol in ("fp32", "bf16_store", "bf16_refined"):
        cache = factor_cache.FactorCache()
        eng = engine.CVEngine(engine.PiCholeskyStrategy(g=4, block=block),
                              precision=pol, lam_chunk=chunk, donate=False,
                              cache=cache, reuse=False, cache_anchors=True)
        r = eng.run(folds, lams)            # compile + trace (+ cache write)
        t = timeit(lambda: eng.run(folds, lams), repeats=3, warmup=0)
        temp = eng.replay_temp_bytes(folds, lams)
        state_bytes = next(iter(cache.entries.values())).nbytes
        store = resolve_precision(pol).store_dtype(jnp.float32)
        results[pol] = (r, t, state_bytes)
        rec["policies"][pol] = {
            "cold_s": t,
            "state_bytes": state_bytes,
            "replay_temp_bytes": temp,
            "packed_bytes_per_lam": packing.packed_nbytes(h, block, store),
            "best_lam": float(r.best_lam),
            "argmin_index": int(np.argmin(r.errors)),
        }
        emit(f"table3_precision_{pol}_h{h}", t,
             f"cold={t:.3f}s state_bytes={state_bytes} "
             f"best_lam={r.best_lam:.4g}")

    r32, t32, m32 = results["fp32"]
    _, t16, m16 = results["bf16_store"]
    r16r, _, _ = results["bf16_refined"]
    rec["speedup_bf16_store"] = t32 / t16
    rec["mem_ratio_bf16_store"] = m32 / m16
    rec["argmin_match"] = bool(float(r16r.best_lam) == float(r32.best_lam))
    emit(f"table3_precision_summary_h{h}", 0.0,
         f"speedup={rec['speedup_bf16_store']:.2f}x "
         f"mem_ratio={rec['mem_ratio_bf16_store']:.2f}x "
         f"argmin_match={rec['argmin_match']}")
    return rec


def _autotune_record(h: int, k: int, q: int) -> dict:
    """Roofline-guided autotuner record (PR-7 tentpole): predicted vs
    measured wall time for every candidate of a small (block × λ-chunk)
    lattice, on one fp32 ridge problem.

    The tuner's whole value proposition is *compile-time* selection — every
    candidate is AOT-lowered and scored against the roofline model, nothing
    executes — so this record closes the loop by actually RUNNING each
    candidate afterwards and checking the prediction against the stopwatch:

    * ``tuned_vs_default``     — measured default-config time over measured
      chosen-config time.  The default is always in the lattice and wins
      predicted ties, so this ratio is ≥ 1.0 by construction when the tuner
      keeps the default and must be ≥ 1.0 in measurement for the choice to
      have been worth making (enforced non-smoke by
      ``scripts/check_bench_schema.py``).  When the tuner keeps the default
      the two entries share one measurement and the ratio is exactly 1.0.
    * ``chosen_rank_measured`` — the chosen config's rank (0 = fastest) in
      the measured ordering of all candidates; the schema checker requires
      top-2 non-smoke, i.e. the static roofline score ranks the lattice
      about as well as running everything would have.
    * ``cache_hit_second_tune`` — re-tuning the same geometry must be a
      content-addressed :class:`~repro.distributed.autotune.TuningCache`
      hit with ZERO new lowerings.
    * ``argmin_match``         — tuning changes tiling/chunking, never
      math: the tuned sweep must select the same λ* as the default sweep.

    Mesh shapes are pinned to ``[None]`` (the bench container is
    single-device); the mesh dimension of the lattice is exercised by
    ``tests/test_autotune.py`` under the 4-virtual-device test topology.
    """
    from repro.distributed import autotune
    from repro.distributed import roofline as rl

    x, y = ridge_problem(h)
    x, y = x.astype(jnp.float32), y.astype(jnp.float32)
    folds = cv.make_folds(x, y, k)
    block = max(16, min(64, h // 8))
    lams = jnp.logspace(-3, 2, q, dtype=jnp.float32)
    blocks = (block, 2 * block) if SMOKE else (16, 32, 64)
    lattice = dict(blocks=blocks, mesh_shapes=[None])
    hw = rl.detect_hw()
    eng = engine.CVEngine(engine.PiCholeskyStrategy(g=4, block=block),
                          donate=False)

    tcache = autotune.TuningCache()
    t0 = time.perf_counter()
    chosen = autotune.tune(eng, folds, lams, cache=tcache, hw=hw, **lattice)
    tune_s = time.perf_counter() - t0
    n_low = tcache.lowerings
    again = autotune.tune(eng, folds, lams, cache=tcache, hw=hw, **lattice)
    cache_hit = bool(again.source == "cache" and tcache.lowerings == n_low)

    default = autotune.default_config(eng, k, h, q, jnp.float32)
    cands = autotune.candidate_lattice(
        h=h, k=k, q=q, n_devices=len(jax.devices()), default=default,
        store_dtype=jnp.float32, budget=engine.LAM_CHUNK_BUDGET_BYTES,
        **lattice)
    scored = autotune.score_candidates(eng, folds, lams, cands, hw=hw)

    # close the loop: run every candidate (warm — one compile pass, then
    # median) and rank the tuner's compile-time choice by the stopwatch
    repeats = 1 if SMOKE else 5
    measured = {}
    for cand in scored:
        derived = eng._apply_tuned(cand)
        measured[cand.key()] = timeit(lambda: derived.run(folds, lams),
                                      repeats=repeats, warmup=1)
    t_default = measured[default.key()]
    t_chosen = measured[chosen.key()]
    rank = sorted(measured.values()).index(t_chosen)

    r_default = eng._apply_tuned(default).run(folds, lams)
    r_tuned = eng._apply_tuned(chosen).run(folds, lams)

    rec = {
        "h": h, "k": k, "q": q, "hw": hw.name,
        "lattice": {"blocks": list(blocks), "mesh_shapes": ["none"]},
        "n_candidates": len(scored),
        "lowerings": n_low,
        "tune_s": tune_s,
        "cache_hit_second_tune": cache_hit,
        "candidates": [dict(c.to_json(), measured_s=measured[c.key()])
                       for c in scored],
        "chosen": dict(chosen.to_json(), measured_s=t_chosen),
        "default": dict(default.to_json(), measured_s=t_default),
        "tuned_vs_default": t_default / t_chosen,
        "chosen_rank_measured": rank,
        "argmin_match": bool(float(r_tuned.best_lam)
                             == float(r_default.best_lam)),
    }
    emit(f"table3_autotune_h{h}_q{q}", t_chosen,
         f"tuned_vs_default={rec['tuned_vs_default']:.2f}x "
         f"rank={rank}/{len(scored)} lowerings={n_low} "
         f"cache_hit={cache_hit} tune_s={tune_s:.2f}")
    return rec


def _adaptive_search(h: int, k: int, q: int, wave: int,
                     tol_decades: float) -> dict:
    """Adaptive λ-refinement economics (PR-8 tentpole): the search must
    recover the dense grid's λ* within its interval tolerance (plus one
    dense-grid step, the dense argmin's own quantization) while spending
    at most HALF the dense grid's λ evaluations — both floors enforced
    non-smoke by ``scripts/check_bench_schema.py``.

    Both sweeps run against one shared factor cache (state warm, the λ
    axis is the only variable), so ``dense_s / search_s`` is the pure
    evaluation saving; ``evals_vs_grid`` is the machine-checkable form.
    ``selection`` closes the self-tuning loop: interpolant selection
    against the cached anchor targets must factorize NOTHING
    (``chol_calls_warm == 0``, always enforced).
    """
    x, y = ridge_problem(h)
    folds = cv.make_folds(x, y, k)
    lams = jnp.logspace(-3, 2, q)
    cache = factor_cache.FactorCache()
    eng = engine.CVEngine(engine.PiCholeskyStrategy(g=4, block=16),
                          cache=cache, cache_anchors=True, lam_chunk=wave,
                          donate=False)
    repeats = 1 if SMOKE else 3
    dense_s = timeit(lambda: eng.run(folds, lams), repeats=repeats,
                     warmup=1)
    r_dense = eng.run(folds, lams)
    search_s = timeit(lambda: eng.search(folds, lams, wave=wave,
                                         tol_decades=tol_decades),
                      repeats=repeats, warmup=1)
    r_search = eng.search(folds, lams, wave=wave, tol_decades=tol_decades)
    info = r_search.extras["engine"]["search"]
    step = 5.0 / (q - 1)                       # dense spacing in decades
    gap = abs(float(np.log10(r_search.best_lam))
              - float(np.log10(r_dense.best_lam)))

    # self-tuning selection on a warm anchor cache: zero factorizations
    bk = CountingBackend(ReferenceBackend())
    sel_eng = engine.CVEngine(engine.PiCholeskyStrategy(g=4, block=16),
                              backend=bk, cache=cache, cache_anchors=True,
                              donate=False)
    sel = sel_eng.select_interpolant(folds, lams)
    chol_warm = bk.n_cholesky

    rec = {
        "h": h, "k": k, "q": q, "wave": info["wave"],
        "tol_decades": tol_decades,
        "dense_s": dense_s, "search_s": search_s,
        "waves": info["waves"],
        "lams_evaluated": info["lams_evaluated"],
        "evals_vs_grid": info["evals_vs_grid"],
        "interval_decades": info["interval_decades"],
        "stopped_on": info["stopped_on"],
        "best_lam_dense": float(r_dense.best_lam),
        "best_lam_search": float(r_search.best_lam),
        "lam_gap_decades": gap,
        "lam_agree": bool(gap <= tol_decades + step),
        "selection": {"degree": sel["degree"], "basis": sel["basis"],
                      "anchor_status": sel["anchor_status"],
                      "chol_calls_warm": int(chol_warm)},
    }
    emit(f"table3_search_h{h}_q{q}", search_s,
         f"evals={rec['lams_evaluated']}/{q} "
         f"({rec['evals_vs_grid']:.2f}x) waves={rec['waves']} "
         f"gap={gap:.3f}dec agree={rec['lam_agree']} "
         f"dense_s={dense_s:.3f} sel={sel['basis']}/r{sel['degree']} "
         f"chol_warm={chol_warm}")
    return rec


def _sketched_anchors(h: int, n: int, k: int, q: int, ms,
                      lr_h: int, lr_n: int, lr_rank: int) -> dict:
    """Sketched-anchor + low-rank ACV frontier record (PR-9 tentpole).

    Two regimes, one committed contract
    (``max(speedup_sketched, speedup_low_rank) ≥ 2×`` with λ-selection
    agreement, enforced non-smoke by ``scripts/check_bench_schema.py``):

    * **n ≫ h (sketched)** — anchor-build = per-fold Gram formation + g
      anchor Cholesky factorizations, timed dense (XᵀX from all n_tr
      rows) vs CountSketch ((S·X)ᵀ(S·X) from m buckets).  The accuracy
      half is the frontier: ``max_curve_diff`` vs the dense engine curve
      must TIGHTEN as m grows (``tightens_with_m``), and the largest-m
      pick's *relative regret on the dense curve* must be ≤ 1e-3
      (``argmin_agree`` — the hold-out curve is noise-flat at n ≫ h, so
      index distance is meaningless but regret is exact).  On this
      1-core CPU container the CountSketch scatter roughly ties BLAS
      dsyrk (``speedup_sketched`` ≈ 1×) — the wall-clock win in this
      regime needs accelerator scatter units; the committed speedup
      floor rides the low-rank half of the OR.
    * **n ≪ h (low-rank)** — the same anchor-build timed dense (g
      Cholesky factorizations of the (h, h) Hessian) vs ONE SVD of the
      (n_tr, h) design (arXiv:2008.10547); ``argmin_match`` is exact
      because the full-rank spectral sweep is the same math.
    """
    from repro.core import picholesky, solvers
    from repro.core import sketch as sk
    from repro.data import make_low_rank_dataset

    g, anchors = 4, picholesky.choose_sample_lambdas(1e-3, 1e2, 4)
    lams = jnp.logspace(-3, 2, q)
    repeats = 1 if SMOKE else 3

    def build_timer(x_folds, kf, hf_fn, factorize=True):
        """Jitted per-fold anchor-factor build: Gram (or factors) for
        every fold × anchor, the λ-independent stage the cache stores."""
        hh = x_folds.shape[-1]
        eye = jnp.eye(hh, dtype=x_folds.dtype)

        def per_fold(f):
            others = (f + 1 + jnp.arange(kf - 1)) % kf
            x_tr = x_folds[others].reshape(-1, hh)
            out = hf_fn(x_tr, f)
            if factorize:               # a Gram: factorize at every anchor
                return jax.vmap(
                    lambda s: jnp.linalg.cholesky(out + s * eye))(anchors)
            return out

        fn = jax.jit(lambda xf: jax.vmap(per_fold)(jnp.arange(kf)))
        return timeit(lambda: fn(x_folds), repeats=repeats, warmup=1)

    # ---- n >> h: sketched anchors ------------------------------------
    x, y = ridge_problem(h, n=n)
    folds = cv.make_folds(x, y, k)
    t_dense = build_timer(folds.x_folds, k,
                          lambda x_tr, f: x_tr.T @ x_tr)
    r_dense = engine.CVEngine(engine.PiCholeskyStrategy(g=g, block=8),
                              donate=False).run(folds, lams)
    ed = np.asarray(r_dense.errors)

    per_m, t_sk_best = {}, None
    for m in ms:
        plan = sk.SketchPlan(method="countsketch", m=m, seed=0, ihs_iters=2)
        t_sk = build_timer(folds.x_folds, k,
                           lambda x_tr, f: sk.sketched_gram(plan, x_tr, f))
        t_sk_best = t_sk if t_sk_best is None else min(t_sk_best, t_sk)
        r_sk = engine.CVEngine(engine.PiCholeskySketched(
            g=g, block=8, sketch=plan), donate=False).run(folds, lams)
        es = np.asarray(r_sk.errors)
        regret = float(ed[int(np.argmin(es))] - ed.min())
        per_m[str(m)] = {
            "build_s": t_sk,
            "build_speedup": t_dense / t_sk,
            "max_curve_diff": float(np.max(np.abs(es - ed))),
            "regret_on_dense": regret,
            "regret_rel": regret / max(float(ed.min()), 1e-30),
        }
        emit(f"table3_sketch_m{m}_h{h}", t_sk,
             f"build_speedup={t_dense / t_sk:.2f}x "
             f"curve_diff={per_m[str(m)]['max_curve_diff']:.3g} "
             f"regret_rel={per_m[str(m)]['regret_rel']:.3g}")

    diffs = [per_m[str(m)]["max_curve_diff"] for m in ms]
    largest = per_m[str(max(ms))]

    # ---- n << h: low-rank ACV ----------------------------------------
    x2, y2 = make_low_rank_dataset(jax.random.PRNGKey(1), lr_n, lr_h,
                                   lr_rank, dtype=jnp.float64)
    folds2 = cv.make_folds(x2, y2, k)
    t_lr_dense = build_timer(folds2.x_folds, k,
                             lambda x_tr, f: x_tr.T @ x_tr)

    def lr_factors(x_tr, f):
        fac = solvers.lowrank_ridge_factors(x_tr)
        return fac.vt                   # vt carries the O(n h) payload
    t_lr = build_timer(folds2.x_folds, k, lr_factors, factorize=False)
    r_ex = engine.CVEngine("exact", donate=False).run(folds2, lams)
    r_lr = engine.CVEngine("low_rank", donate=False).run(folds2, lams)
    lr_match = bool(int(np.argmin(np.asarray(r_lr.errors)))
                    == int(np.argmin(np.asarray(r_ex.errors))))

    rec = {
        "h": h, "n": n, "k": k, "q": q, "g": g, "method": "countsketch",
        "m_values": [int(m) for m in ms],
        "build_dense_s": t_dense,
        "per_m": per_m,
        "speedup_sketched": t_dense / t_sk_best,
        "tightens_with_m": bool(diffs[-1] < diffs[0]),
        "argmin_agree": bool(largest["regret_rel"] <= 1e-3),
        "low_rank": {
            "h": lr_h, "n": lr_n, "k": k, "rank": lr_rank,
            "build_dense_s": t_lr_dense,
            "build_lowrank_s": t_lr,
            "speedup_low_rank": t_lr_dense / t_lr,
            "argmin_match": lr_match,
            "max_curve_diff": float(np.max(np.abs(
                np.asarray(r_lr.errors) - np.asarray(r_ex.errors)))),
        },
    }
    emit(f"table3_sketched_anchors_h{h}", t_sk_best,
         f"speedup_sketched={rec['speedup_sketched']:.2f}x "
         f"speedup_low_rank={rec['low_rank']['speedup_low_rank']:.2f}x "
         f"tightens={rec['tightens_with_m']} "
         f"argmin_agree={rec['argmin_agree']} lr_match={lr_match}")
    return rec


def run():
    if SMOKE:
        sizes, sweep_h, qs, chunk = [32], 32, [10, 25], 4
    else:
        # the O(d³) factorization term must dominate for the paper's
        # comparison to be meaningful — use the larger sizes regardless of
        # CI scale; the sweep-scaling record needs dense q, not large h
        sizes = sorted(set(SIZES + [1024]))[-2:]
        sweep_h, qs, chunk = 128, [100, 1000], 16

    # warm-vs-cold wants the factorization term visible (the cost the
    # cache removes): large h, the paper's q=31 grid + a coarse q=10 pass
    wc_h, wc_qs = (32, [10]) if SMOKE else (512, [10, 31])
    # overlap-vs-serial wants both stages visible: the ISSUE-4 acceptance
    # point (k=10, h=512) with a grid dense enough that skipped λ chunks
    # are real wall-clock
    ov_args = (32, 4, 16, 2) if SMOKE else (512, 10, 96, 8)
    # precision sweep at the ISSUE-5 acceptance point (h=512, the paper's
    # q=31 grid, fixed chunk so the memory ratio is the dtype ratio)
    ps_args = (32, 10, 4) if SMOKE else (512, 31, 8)
    # autotune at a mid size: big enough that block choice is real
    # wall-clock, small enough that measuring every lattice candidate
    # stays harness-sized
    at_args = (32, 4, 8) if SMOKE else (256, 5, 64)
    # adaptive search vs its own dense grid: q dense enough that the
    # refinement's fixed wave cost amortizes (the ≤ 0.5 evals floor)
    as_args = (32, 4, 32, 6, 0.1) if SMOKE else (256, 5, 96, 8, 0.05)
    # sketched anchors: the n ≫ h half needs n big enough that the dense
    # Gram is real wall-clock and the hold-out curve is in its asymptotic
    # (flat) regime; the n ≪ h half needs h ≫ n so g Choleskys of (h, h)
    # dwarf one SVD of (n_tr, h)
    sa_args = ((16, 2048, 4, 9, [256, 512], 96, 32, 8) if SMOKE
               else (32, 32768, 4, 31, [1024, 4096], 768, 128, 16))
    record = {
        "schema": "bench_table3/v1",
        "smoke": SMOKE,
        "jax_backend": jax.default_backend(),
        "x64": bool(jax.config.jax_enable_x64),
        "sizes": _algo_table(sizes),
        "sweep_scaling": _sweep_scaling(sweep_h, qs, chunk),
        "warm_vs_cold": _warm_vs_cold(wc_h, wc_qs, chunk),
        "overlap_vs_serial": _overlap_vs_serial(*ov_args),
        "precision_sweep": _precision_sweep(*ps_args),
        "autotune": _autotune_record(*at_args),
        "adaptive_search": _adaptive_search(*as_args),
        "sketched_anchors": _sketched_anchors(*sa_args),
    }
    emit_json("BENCH_table3.json", record)
    return record
