"""Table 4: minimum hold-out error and selected λ for the six algorithms."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cv

from .common import emit, ridge_problem


def run():
    h = max(256, __import__("benchmarks.common", fromlist=["SIZES"]).SIZES[0])
    x, y = ridge_problem(h)
    folds = cv.make_folds(x, y, 5)
    lams = jnp.logspace(-3, 2, 31)

    results = {
        "chol": cv.cv_exact_cholesky(folds, lams),
        "pichol": cv.cv_picholesky(folds, lams, g=4, block=64),
        "mchol": cv.cv_multilevel_cholesky(folds, c=0.0, s=1.5, s0=0.05),
        "svd": cv.cv_svd(folds, lams, mode="full"),
        "tsvd": cv.cv_svd(folds, lams, mode="truncated", k_trunc=h // 4),
        "rsvd": cv.cv_svd(folds, lams, mode="randomized", k_trunc=h // 4,
                          key=jax.random.PRNGKey(0)),
    }
    ref = results["chol"]
    out = {}
    for name, r in results.items():
        dlog = abs(np.log10(r.best_lam) - np.log10(ref.best_lam))
        emit(f"table4_{name}", 0.0,
             f"min_err={r.best_error:.4f} lam={r.best_lam:.4g} "
             f"dlog_lam_vs_chol={dlog:.2f} n_chol={r.n_exact_chol}")
        out[name] = (r.best_error, r.best_lam, r.n_exact_chol)
    return out
