"""Shared benchmark utilities: timing, CSV/JSON emission, problem construction."""
from __future__ import annotations

import json
import os
import pathlib
import time
from typing import Callable

import jax
import jax.numpy as jnp

# benchmark scale: paper uses h up to 16384; this container is 1-core CPU,
# so default sizes are scaled down. REPRO_BENCH_SCALE=paper restores larger h.
SCALE = os.environ.get("REPRO_BENCH_SCALE", "ci")
SIZES = {"ci": [256, 512], "mid": [512, 1024, 2048],
         "paper": [1024, 2048, 4096]}[SCALE]

# REPRO_BENCH_SMOKE=1: tiny problems, one repeat — CI runs this to catch
# schema drift in the emitted JSON records, not to measure anything.
SMOKE = os.environ.get("REPRO_BENCH_SMOKE", "") == "1"


def timeit(fn: Callable, *args, repeats: int = 3, warmup: int = 1) -> float:
    """Median wall seconds of fn(*args) with block_until_ready."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def emit(name: str, seconds: float, derived: str = "") -> None:
    print(f"{name},{seconds * 1e6:.1f},{derived}", flush=True)


def emit_json(filename: str, record: dict) -> pathlib.Path:
    """Write a machine-readable benchmark record to the repo root.

    The perf trajectory lives in these committed files; smoke-mode CI
    re-emits them on tiny problems so schema drift fails fast.
    """
    path = pathlib.Path(__file__).resolve().parents[1] / filename
    path.write_text(json.dumps(record, indent=2, sort_keys=True) + "\n")
    emit(f"json_{filename}", 0.0, f"path={path}")
    return path


def ridge_problem(h: int, n: int | None = None, seed: int = 0):
    from repro.data import make_regression_dataset
    n = n or max(2 * h, 512)
    x, y = make_regression_dataset(jax.random.PRNGKey(seed), n, h,
                                   dtype=jnp.float64)
    return x, y


def bench_pair(tag: str, host_fn: Callable, engine_fn: Callable,
               repeats: int = 3, warmup: int = 1) -> dict:
    """Time a host-loop driver against its CVEngine counterpart and emit
    both rows plus the speedup line.  Returns {host, engine, speedup}."""
    t_host = timeit(host_fn, repeats=repeats, warmup=warmup)
    t_eng = timeit(engine_fn, repeats=repeats, warmup=warmup)
    emit(f"{tag}_host", t_host, f"seconds={t_host:.3f}")
    emit(f"{tag}_engine", t_eng, f"seconds={t_eng:.3f}")
    emit(f"{tag}_engine_speedup", 0.0,
         f"engine_vs_host={t_host / t_eng:.2f}x")
    return {"host": t_host, "engine": t_eng, "speedup": t_host / t_eng}
