"""Shared benchmark utilities: timing, CSV emission, problem construction."""
from __future__ import annotations

import os
import time
from typing import Callable

import jax
import jax.numpy as jnp

# benchmark scale: paper uses h up to 16384; this container is 1-core CPU,
# so default sizes are scaled down. REPRO_BENCH_SCALE=paper restores larger h.
SCALE = os.environ.get("REPRO_BENCH_SCALE", "ci")
SIZES = {"ci": [256, 512], "mid": [512, 1024, 2048],
         "paper": [1024, 2048, 4096]}[SCALE]


def timeit(fn: Callable, *args, repeats: int = 3, warmup: int = 1) -> float:
    """Median wall seconds of fn(*args) with block_until_ready."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def emit(name: str, seconds: float, derived: str = "") -> None:
    print(f"{name},{seconds * 1e6:.1f},{derived}", flush=True)


def ridge_problem(h: int, n: int | None = None, seed: int = 0):
    from repro.data import make_regression_dataset
    n = n or max(2 * h, 512)
    x, y = make_regression_dataset(jax.random.PRNGKey(seed), n, h,
                                   dtype=jnp.float64)
    return x, y
