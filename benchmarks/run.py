"""Benchmark harness — one entry per paper table/figure + the roofline table.

    PYTHONPATH=src python -m benchmarks.run [names...]

Prints ``name,us_per_call,derived`` CSV rows; benches with a machine-readable
record (``table3`` → ``BENCH_table3.json``, ``serving`` →
``BENCH_serving.json``) also write it to the repo root so the perf
trajectory is committed alongside the code.

Environment: REPRO_BENCH_SCALE=ci|mid|paper controls problem sizes (ci
default on this CPU container); REPRO_BENCH_SMOKE=1 shrinks everything to
seconds-scale so CI can validate the emitted JSON schema on every push.
"""
import sys

import jax

jax.config.update("jax_enable_x64", True)

from . import (bench_fig4_smoothness, bench_fig10_pinrmse, bench_fig11_nrmse,
               bench_roofline, bench_serving, bench_table1_vec,
               bench_table3_timing, bench_table4_holdout)

BENCHES = {
    "fig4": bench_fig4_smoothness.run,
    "table1": bench_table1_vec.run,
    "table3": bench_table3_timing.run,
    "table4": bench_table4_holdout.run,
    "fig10": bench_fig10_pinrmse.run,
    "fig11": bench_fig11_nrmse.run,
    "roofline": bench_roofline.run,
    "serving": bench_serving.run,
}

def main() -> None:
    names = sys.argv[1:] or list(BENCHES)
    print("name,us_per_call,derived")
    for name in names:
        BENCHES[name]()


if __name__ == "__main__":
    main()
