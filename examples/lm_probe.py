"""Linear-probe selection on LM hidden states with piCholesky-accelerated
ridge CV (the framework integration from DESIGN.md §4.1).

Extract features from any zoo architecture, then select the probe's
regularization by k-fold CV — with g=4 factorizations instead of 31.

    PYTHONPATH=src python examples/lm_probe.py [--arch smollm-360m]
"""
import argparse

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro import configs  # noqa: E402
from repro.core import cv  # noqa: E402
from repro.models.model import Model  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m", choices=configs.names())
    ap.add_argument("--n-seq", type=int, default=16)
    args = ap.parse_args()

    cfg = configs.get(args.arch).reduced()   # CPU-sized variant
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    # features: last-layer logits restricted to the first 96 dims (a stand-in
    # for pooled hidden states on this CPU box)
    key = jax.random.PRNGKey(1)
    tokens = jax.random.randint(key, (args.n_seq, 32), 0, cfg.vocab_size)
    extra = {}
    if cfg.family == "audio":
        extra["enc_frames"] = jax.random.normal(
            key, (args.n_seq, 16, cfg.d_model), jnp.float32)
    if cfg.family == "vlm":
        extra["image_embeds"] = jax.random.normal(
            key, (args.n_seq, cfg.n_image_tokens, cfg.d_model), jnp.float32)
    logits, _ = jax.jit(model.forward)(params, tokens, extra)
    feats = logits.reshape(-1, cfg.vocab_size)[:, :96].astype(jnp.float64)
    feats = jnp.concatenate(
        [feats, jnp.ones((feats.shape[0], 1), jnp.float64)], axis=1)

    # synthetic probe target over those features
    theta_true = jax.random.normal(jax.random.PRNGKey(2), (97,), jnp.float64)
    y = feats @ theta_true + 0.5 * jax.random.normal(
        jax.random.PRNGKey(3), (feats.shape[0],), jnp.float64)

    folds = cv.make_folds(feats, y, 4)
    lams = jnp.logspace(-4, 1, 31)
    r_exact = cv.cv_exact_cholesky(folds, lams)
    r_pi = cv.cv_picholesky(folds, lams, g=4, block=32)
    print(f"arch={args.arch}  features={feats.shape}")
    print(f"exact   CV: λ*={r_exact.best_lam:.4g} err={r_exact.best_error:.4f}"
          f"  ({r_exact.n_exact_chol} factorizations)")
    print(f"piChol  CV: λ*={r_pi.best_lam:.4g} err={r_pi.best_error:.4f}"
          f"  ({r_pi.n_exact_chol} factorizations)")


if __name__ == "__main__":
    main()
