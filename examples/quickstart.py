"""Quickstart: piCholesky in 30 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402

from repro.core import picholesky, solvers  # noqa: E402

# An SPD Hessian (e.g. XᵀX from ridge regression)
key = jax.random.PRNGKey(0)
h = 512
x = jax.random.normal(key, (2048, h), jnp.float64)
hessian = x.T @ x
grad = x.T @ jax.random.normal(jax.random.fold_in(key, 1), (2048,), jnp.float64)

# Fit the interpolant from g=5 exact factorizations…
sample = picholesky.choose_sample_lambdas(1e-3, 1.0, g=5)
model = picholesky.fit(hessian, sample, degree=2)

# …then sweep 31 λ values at O(r d²) each instead of O(d³)
lams = jnp.logspace(-3, 0, 31)
factors = model.eval_factor(lams)                       # (31, h, h)
thetas = jax.vmap(lambda l: solvers.solve_from_factor(l, grad))(factors)

# accuracy vs exact
exact = solvers.solve_cholesky_sweep(hessian, grad, lams)
rel = jnp.linalg.norm(thetas - exact, axis=1) / jnp.linalg.norm(exact, axis=1)
print(f"swept {len(lams)} λ values with {len(sample)} factorizations")
print(f"max relative solution error vs exact: {float(rel.max()):.2e}")
