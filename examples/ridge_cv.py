"""End-to-end reproduction of the paper's experiment pipeline (§6) on
synthetic polynomial-kernel features: all six algorithms, hold-out curves,
selected λ, and factorization counts.

    PYTHONPATH=src python examples/ridge_cv.py [--h 512] [--n 1500]
"""
import argparse
import time

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import cv  # noqa: E402
from repro.data import make_regression_dataset  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--h", type=int, default=384)
    ap.add_argument("--n", type=int, default=1200)
    ap.add_argument("--folds", type=int, default=5)
    args = ap.parse_args()

    x, y = make_regression_dataset(jax.random.PRNGKey(0), args.n, args.h,
                                   dtype=jnp.float64)
    folds = cv.make_folds(x, y, args.folds)
    lams = jnp.logspace(-3, 2, 31)

    algos = {
        "Chol": lambda: cv.cv_exact_cholesky(folds, lams),
        "PIChol": lambda: cv.cv_picholesky(folds, lams, g=4),
        "MChol": lambda: cv.cv_multilevel_cholesky(folds, c=0.0, s=1.5,
                                                   s0=0.05),
        "SVD": lambda: cv.cv_svd(folds, lams, mode="full"),
        "t-SVD": lambda: cv.cv_svd(folds, lams, mode="truncated",
                                   k_trunc=args.h // 4),
        "r-SVD": lambda: cv.cv_svd(folds, lams, mode="randomized",
                                   k_trunc=args.h // 4,
                                   key=jax.random.PRNGKey(1)),
    }
    print(f"{'algo':8s} {'time(s)':>8s} {'min holdout':>12s} "
          f"{'selected λ':>11s} {'#chol':>6s}")
    for name, fn in algos.items():
        t0 = time.perf_counter()
        r = fn()
        dt = time.perf_counter() - t0
        print(f"{name:8s} {dt:8.2f} {r.best_error:12.4f} "
              f"{r.best_lam:11.4g} {r.n_exact_chol:6d}")


if __name__ == "__main__":
    main()
