"""End-to-end reproduction of the paper's experiment pipeline (§6) on
synthetic polynomial-kernel features: all six algorithms, hold-out curves,
selected λ, and factorization counts — then the same sweep through the
unified CVEngine (one jitted batched computation, optionally sharded over
all local devices with --mesh).

    PYTHONPATH=src python examples/ridge_cv.py [--h 512] [--n 1500] [--mesh]
                                               [--tune] [--search] [--sketch]
"""
import argparse
import time

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import cv, engine  # noqa: E402
from repro.data import make_regression_dataset  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--h", type=int, default=384)
    ap.add_argument("--n", type=int, default=1200)
    ap.add_argument("--folds", type=int, default=5)
    ap.add_argument("--mesh", action="store_true",
                    help="shard the engine sweep over all local devices")
    ap.add_argument("--tune", action="store_true",
                    help="roofline-guided autotune demo: AOT-score a "
                         "block/λ-chunk/mesh lattice (zero executions) and "
                         "run the sweep at the predicted-fastest config")
    ap.add_argument("--precision", default="fp32",
                    choices=["native", "fp32", "bf16_store", "bf16_refined",
                             "fp64"],
                    help="precision policy for the mixed-precision demo "
                         "section (compared against fp32)")
    ap.add_argument("--search", action="store_true",
                    help="adaptive λ-refinement demo: recover the dense "
                         "grid's λ* with a fraction of its evaluations, "
                         "plus LOO interpolant selection and bound-guided "
                         "anchor advice")
    ap.add_argument("--sketch", action="store_true",
                    help="sketched-anchor + low-rank demo: build anchor "
                         "factors from a CountSketch-compressed Gram "
                         "(n ≫ h regime) and run the low-rank ACV "
                         "strategy on an n ≪ h problem")
    args = ap.parse_args()

    x, y = make_regression_dataset(jax.random.PRNGKey(0), args.n, args.h,
                                   dtype=jnp.float64)
    folds = cv.make_folds(x, y, args.folds)
    lams = jnp.logspace(-3, 2, 31)

    algos = {
        "Chol": lambda: cv.cv_exact_cholesky(folds, lams),
        "PIChol": lambda: cv.cv_picholesky(folds, lams, g=4),
        "MChol": lambda: cv.cv_multilevel_cholesky(folds, c=0.0, s=1.5,
                                                   s0=0.05),
        "SVD": lambda: cv.cv_svd(folds, lams, mode="full"),
        "t-SVD": lambda: cv.cv_svd(folds, lams, mode="truncated",
                                   k_trunc=args.h // 4),
        "r-SVD": lambda: cv.cv_svd(folds, lams, mode="randomized",
                                   k_trunc=args.h // 4,
                                   key=jax.random.PRNGKey(1)),
    }
    print(f"{'algo':8s} {'time(s)':>8s} {'min holdout':>12s} "
          f"{'selected λ':>11s} {'#chol':>6s}")
    for name, fn in algos.items():
        t0 = time.perf_counter()
        r = fn()
        dt = time.perf_counter() - t0
        print(f"{name:8s} {dt:8.2f} {r.best_error:12.4f} "
              f"{r.best_lam:11.4g} {r.n_exact_chol:6d}")

    # ---- the same sweep through the unified engine: every strategy is one
    # jitted batched computation; the second run hits compiled code.
    mesh = "auto" if args.mesh else None
    if args.mesh and len(jax.devices()) == 1:
        print("\n--mesh: only one device visible; set e.g. "
              "XLA_FLAGS=--xla_force_host_platform_device_count=4 "
              "to shard on CPU")
    print(f"\nCVEngine (backend=auto, mesh={mesh}, "
          f"{len(jax.devices())} device(s)):")
    strategies = {
        "exact": engine.make_strategy("exact"),
        "pichol": engine.PiCholeskyStrategy(g=4),
        "warm": engine.PiCholeskyWarmstart(g_first=4, g_rest=2),
        "svd": engine.SVDStrategy(mode="full"),
        "pinrmse": engine.PinrmseStrategy(g=4),
    }
    for name, strat in strategies.items():
        eng = engine.CVEngine(strat, mesh=mesh)
        eng.run(folds, lams)                      # compile + warm
        t0 = time.perf_counter()
        r = eng.run(folds, lams)
        dt = time.perf_counter() - t0
        print(f"{name:8s} {dt:8.2f} {r.best_error:12.4f} "
              f"{r.best_lam:11.4g} {r.n_exact_chol:6d}")

    # ---- roofline-guided autotuning: every (block × λ-chunk × mesh)
    # candidate is AOT-lowered and scored against the roofline model —
    # nothing executes — then the sweep runs at the predicted-fastest
    # config.  A second tuned run is a content-addressed TuningCache hit.
    if args.tune:
        from repro.distributed import autotune  # noqa: E402

        xf32 = x.astype(jnp.float32)
        yf32 = y.astype(jnp.float32)
        tfolds = cv.make_folds(xf32, yf32, args.folds)
        tlams = lams.astype(jnp.float32)
        tcache = autotune.TuningCache()
        tuned = engine.CVEngine(engine.PiCholeskyStrategy(g=4), mesh=mesh,
                                tune="auto", tune_cache=tcache)
        base = engine.CVEngine(engine.PiCholeskyStrategy(g=4), mesh=mesh)
        t0 = time.perf_counter()
        r = tuned.run(tfolds, tlams)              # tune + compile + run
        t_first = time.perf_counter() - t0
        cfg = r.extras["engine"]["tune"]
        base.run(tfolds, tlams)                   # compile the default
        t0 = time.perf_counter()
        tuned.run(tfolds, tlams)                  # cache hit + compiled code
        t_tuned = time.perf_counter() - t0
        t0 = time.perf_counter()
        base.run(tfolds, tlams)
        t_default = time.perf_counter() - t0
        print(f"\nAutotune (lattice scored via AOT roofline, "
              f"{tcache.lowerings} lowerings, 0 executions):")
        print(f"  chosen: block={cfg['block']} lam_chunk={cfg['lam_chunk']} "
              f"mesh={cfg['mesh_shape']} predicted={cfg['predicted_s']:.3e}s "
              f"[{cfg['source']}]")
        print(f"  first tuned run (incl. tuning) {t_first:8.2f}s, "
              f"warm tuned {t_tuned:8.4f}s vs default {t_default:8.4f}s")
        print(f"  tuning cache: {tcache.stats}")

    # ---- warm-replay factor cache: the model-assessment loop.  The first
    # sweep fits and caches Θ per fold; every later sweep over a grid with
    # the same λ range (any density) replays it — zero factorizations.
    from repro.core import factor_cache  # noqa: E402

    cache = factor_cache.FactorCache()
    print("\nFactorCache warm replay (PiCholesky, g=4):")
    for tag, grid, reuse in [("cold 31", lams, False),   # write-only
                             ("warm 31", lams, "exact"),
                             ("warm 101", jnp.logspace(-3, 2, 101),
                              "exact")]:
        eng = engine.CVEngine(engine.PiCholeskyStrategy(g=4), cache=cache,
                              reuse=reuse)
        eng.run(folds, grid)                      # compile
        t0 = time.perf_counter()
        r = eng.run(folds, grid)
        dt = time.perf_counter() - t0
        status = r.extras["engine"]["cache"]["status"]
        print(f"{tag:8s} {dt:8.2f} {r.best_error:12.4f} "
              f"{r.best_lam:11.4g} {r.n_exact_chol:6d}  [{status}]")

    # ---- adaptive λ-search: same range as the dense grid, a fraction of
    # its evaluations — then the self-tuning pieces: LOO interpolant
    # selection (zero factorizations on the warm anchor cache the sweep
    # above populated) and the Thm 4.4 anchor-placement advisor.
    if args.search:
        dense = jnp.logspace(-3, 2, 96)
        scache = factor_cache.FactorCache()
        eng = engine.CVEngine(engine.PiCholeskyStrategy(g=4), cache=scache,
                              cache_anchors=True, lam_chunk=8)
        r_dense = eng.run(folds, dense)
        t0 = time.perf_counter()
        r_dense = eng.run(folds, dense)           # warm dense baseline
        t_dense = time.perf_counter() - t0
        eng.search(folds, dense)                  # compile the wave shape
        t0 = time.perf_counter()
        r_s = eng.search(folds, dense)
        t_search = time.perf_counter() - t0
        info = r_s.extras["engine"]["search"]
        sel = eng.select_interpolant(folds, dense)
        gap = abs(float(jnp.log10(r_s.best_lam))
                  - float(jnp.log10(r_dense.best_lam)))
        print(f"\nAdaptive λ-search (dense q={dense.size} vs "
              f"wave={info['wave']}, tol={info['tol_decades']} decades):")
        print(f"  dense   {t_dense:8.2f}s λ*={r_dense.best_lam:9.4g}  "
              f"{dense.size} evaluations")
        print(f"  search  {t_search:8.2f}s λ*={r_s.best_lam:9.4g}  "
              f"{info['lams_evaluated']} evaluations "
              f"({info['evals_vs_grid']:.2f}x) in {info['waves']} waves, "
              f"stopped on {info['stopped_on']}, gap {gap:.3f} decades")
        print(f"  interpolant: {sel['basis']}/r{sel['degree']} by LOO "
              f"(anchor targets: {sel['anchor_status']})")
        adv = eng.advise_anchor(folds, dense, probe_dim=24)
        lo, hi = adv["intervals"][adv["worst"]]
        print(f"  anchor advice (probe d={adv['probe_dim']}): weakest "
              f"interval [{lo:.3g}, {hi:.3g}] → next anchor "
              f"≈ {adv['proposal']:.4g}")

    # ---- sketched anchors + low-rank ACV: the two regimes outside the
    # dense pipeline's sweet spot.  n ≫ h: anchor factors come from a
    # CountSketch-compressed Gram (m buckets instead of n_tr rows) + IHS
    # refinement — curves converge to the dense engine's as m grows.
    # n ≪ h: one SVD of the (n_tr, h) design replaces g Choleskys of the
    # (h, h) Hessian; the spectral sweep matches the exact engine.
    if args.sketch:
        from repro.core import sketch as sk  # noqa: E402
        from repro.data import make_low_rank_dataset  # noqa: E402

        n_tall = max(args.n, 16 * args.h)
        xt, yt = make_regression_dataset(jax.random.PRNGKey(2), n_tall,
                                         args.h, dtype=jnp.float64,
                                         noise=8.0)
        tfolds = cv.make_folds(xt, yt, args.folds)
        r_dense = engine.CVEngine(engine.PiCholeskyStrategy(g=4)).run(
            tfolds, lams)
        ed = np.asarray(r_dense.errors)
        print(f"\nSketched anchors (countsketch, n={n_tall} ≫ h={args.h}, "
              f"dense λ*={r_dense.best_lam:.4g}):")
        print(f"{'m':>6s} {'time(s)':>8s} {'max curve diff':>15s} "
              f"{'regret on dense':>16s} {'selected λ':>11s}")
        for m in (1024, 4096):
            plan = sk.SketchPlan(method="countsketch", m=m, seed=0,
                                 ihs_iters=2)
            eng = engine.CVEngine(engine.PiCholeskyStrategy(g=4),
                                  sketch=plan)
            eng.run(tfolds, lams)                 # compile
            t0 = time.perf_counter()
            r = eng.run(tfolds, lams)
            dt = time.perf_counter() - t0
            es = np.asarray(r.errors)
            regret = ed[int(np.argmin(es))] - ed.min()
            print(f"{m:6d} {dt:8.2f} {np.max(np.abs(es - ed)):15.3e} "
                  f"{regret:16.3e} {r.best_lam:11.4g}")

        h_wide, n_small, rank = 4 * args.h, args.h // 4, args.h // 16
        xl, yl = make_low_rank_dataset(jax.random.PRNGKey(3), n_small,
                                       h_wide, rank, dtype=jnp.float64)
        lfolds = cv.make_folds(xl, yl, args.folds)
        print(f"\nLow-rank ACV (h={h_wide} ≫ n={n_small}, planted "
              f"rank {rank}):")
        for name in ("exact", "low_rank"):
            eng = engine.CVEngine(name)
            eng.run(lfolds, lams)                 # compile
            t0 = time.perf_counter()
            r = eng.run(lfolds, lams)
            dt = time.perf_counter() - t0
            print(f"{name:9s} {dt:8.2f} {r.best_error:12.4f} "
                  f"{r.best_lam:11.4g} {r.n_exact_chol:6d} chol")

    # ---- mixed-precision policies: one PrecisionPolicy governs storage /
    # compute / accumulation / fit dtypes and the per-chunk fp32 residual
    # refinement.  bf16 storage halves the fitted state (and every cache
    # entry); bf16_refined reproduces the fp32-selected λ*.
    print(f"\nPrecision policies (fp32 baseline vs --precision="
          f"{args.precision}):")
    xf, yf = x.astype(jnp.float32), y.astype(jnp.float32)
    folds32 = cv.make_folds(xf, yf, args.folds)
    print(f"{'policy':14s} {'time(s)':>8s} {'min holdout':>12s} "
          f"{'selected λ':>11s} {'state bytes':>12s}")
    for pol in dict.fromkeys(["fp32", args.precision]):
        pcache = factor_cache.FactorCache()
        eng = engine.CVEngine(engine.PiCholeskyStrategy(g=4), precision=pol,
                              cache=pcache, reuse=False)
        eng.run(folds32, lams)                    # compile + cache write
        t0 = time.perf_counter()
        r = eng.run(folds32, lams)
        dt = time.perf_counter() - t0
        entry = next(iter(pcache.entries.values()))
        print(f"{pol:14s} {dt:8.2f} {r.best_error:12.4f} "
              f"{r.best_lam:11.4g} {entry.nbytes:12d}")


if __name__ == "__main__":
    main()
