"""Multi-tenant CV sweep serving driver: submit a seeded Zipf traffic mix
of ridge-CV problems, serve them through the admission-batched
`CVSweepServer`, and print latency / throughput / shared-cache hit-rate
(the `serve_lm.py` of the CV engine).

    PYTHONPATH=src python examples/serve_cv.py --requests 24 --tenants 4
"""
import argparse
import time

import jax

jax.config.update("jax_enable_x64", True)

import numpy as np

from repro.core.engine import PiCholeskyStrategy
from repro.serving import CVSweepServer, ServerConfig, TrafficConfig, \
    make_traffic


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--tenants", type=int, default=4)
    ap.add_argument("--problems", type=int, default=6)
    ap.add_argument("--h", type=int, default=48)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--zipf-a", type=float, default=1.2)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--cache-mb", type=float, default=None,
                    help="byte budget of the shared cache (default: none)")
    args = ap.parse_args()

    cfg = TrafficConfig(n_requests=args.requests, n_tenants=args.tenants,
                        n_problems=args.problems, h=args.h, n=8 * args.h,
                        zipf_a=args.zipf_a, seed=args.seed)
    srv = CVSweepServer(
        PiCholeskyStrategy(g=4, block=16),
        config=ServerConfig(
            max_batch=args.max_batch,
            cache_bytes=(None if args.cache_mb is None
                         else int(args.cache_mb * 2**20))))

    t0 = time.perf_counter()
    for req in make_traffic(cfg):
        srv.submit(req)
    resps = srv.drain()
    wall = time.perf_counter() - t0

    lat = np.array([r.latency_s for r in resps])
    st = srv.stats
    print(f"requests={len(resps)} tenants={args.tenants} "
          f"problems={args.problems} h={args.h}")
    print(f"p50 {np.percentile(lat, 50)*1e3:.0f} ms   "
          f"p99 {np.percentile(lat, 99)*1e3:.0f} ms   "
          f"{len(resps)/wall:.1f} req/s   "
          f"{st['dispatches']} dispatches (mean batch "
          f"{st['batch_mean']:.1f})")
    print(f"cache: hit_rate={srv.cache.hit_rate():.2f} "
          f"entries={st['cache']['entries']} "
          f"evictions={st['cache']['evictions']}")
    for tenant in sorted(st["tenants"]):
        rec = st["tenants"][tenant]
        own = srv.take_responses(tenant)
        lams = [f"{r.result.best_lam:.3g}" for r in own[:4]]
        print(f"  {tenant}: {len(own)} served, hit_rate="
              f"{srv.cache.hit_rate(tenant):.2f}, λ* {lams}")


if __name__ == "__main__":
    main()
