"""Batched serving driver: prefill a batch of prompts, then decode tokens
step-by-step with the KV/recurrent cache (any zoo architecture).

    PYTHONPATH=src python examples/serve_lm.py --arch recurrentgemma-2b --tokens 16
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.models.model import Model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b", choices=configs.names())
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=16)
    args = ap.parse_args()

    cfg = configs.get(args.arch).reduced()   # CPU-sized variant
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(1)
    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)
    extra = {}
    if cfg.family == "audio":
        extra["enc_frames"] = jax.random.normal(
            key, (args.batch, args.prompt_len // cfg.enc_seq_ratio,
                  cfg.d_model), jnp.float32)
    if cfg.family == "vlm":
        extra["image_embeds"] = jax.random.normal(
            key, (args.batch, cfg.n_image_tokens, cfg.d_model), jnp.float32)

    prefill = jax.jit(lambda p, t: model.prefill(
        p, t, extra, cache_len=args.prompt_len + args.tokens + 8))
    decode = jax.jit(model.decode)

    t0 = time.perf_counter()
    logits, cache = prefill(params, prompts)
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0

    out_tokens = []
    tok = jnp.argmax(logits[:, -1], -1)[:, None]
    t0 = time.perf_counter()
    for _ in range(args.tokens):
        out_tokens.append(tok)
        logits, cache = decode(params, cache, tok)
        tok = jnp.argmax(logits[:, -1], -1)[:, None]
    jax.block_until_ready(tok)
    t_decode = time.perf_counter() - t0

    gen = jnp.concatenate(out_tokens, axis=1)
    tps = args.batch * args.tokens / t_decode
    print(f"arch={cfg.name} batch={args.batch} prompt={args.prompt_len}")
    print(f"prefill: {t_prefill*1e3:.1f} ms   decode: "
          f"{t_decode*1e3/args.tokens:.1f} ms/token   {tps:.0f} tok/s")
    print(f"first generated ids: {gen[0, :8].tolist()}")


if __name__ == "__main__":
    main()
