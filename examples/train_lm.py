"""End-to-end LM training driver: data pipeline -> model -> sharded AdamW ->
fault-tolerant loop (checkpoint/auto-resume/straggler accounting).

Default preset is CPU-sized; ``--preset 100m`` trains a ~100M-param model
(a few hundred steps on real hardware; on this CPU container expect ~1 s+
per step — the loop, checkpointing and resume logic are identical).

    PYTHONPATH=src python examples/train_lm.py --steps 50
    PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 300
"""
import argparse
import dataclasses
import itertools

import jax
import jax.numpy as jnp

from repro import configs
from repro.data import token_stream
from repro.models.model import Model
from repro.optim import adamw
from repro.train import TrainLoop, TrainLoopConfig, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m", choices=configs.names())
    ap.add_argument("--preset", default="tiny", choices=["tiny", "100m"])
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--compress-grads", action="store_true")
    args = ap.parse_args()

    cfg = configs.get(args.arch)
    if args.preset == "tiny":
        cfg = cfg.reduced()
    else:  # ~100M: keep width, trim depth+vocab of the reference config
        cfg = dataclasses.replace(
            cfg, n_layers=min(cfg.n_layers, 12), vocab_size=32768,
            dtype="float32", param_dtype="float32", remat=False)
    print(f"arch={cfg.name} params≈{cfg.n_params()/1e6:.2f}M "
          f"(preset={args.preset})")

    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = adamw(lr=3e-4)
    opt_state = opt[0](params)
    if args.compress_grads:
        residual = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        opt_state = (opt_state, residual)
    step = jax.jit(make_train_step(model, opt,
                                   compress_grads=args.compress_grads))

    loop = TrainLoop(
        TrainLoopConfig(total_steps=args.steps, ckpt_every=25,
                        ckpt_dir=args.ckpt_dir, log_every=5),
        step, params, opt_state)
    data = token_stream(jax.random.PRNGKey(1), cfg.vocab_size,
                        args.batch, args.seq)
    out = loop.run(itertools.islice(data, args.steps + 5))
    for entry in out["log"]:
        print(f"step {entry['step']:5d}  loss {entry['loss']:.4f}  "
              f"{entry['sec_per_step']:.2f}s/step")
    print(f"done at step {out['final_step']}; "
          f"straggler steps: {out['straggler_steps']}")


if __name__ == "__main__":
    main()
