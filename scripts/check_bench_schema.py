#!/usr/bin/env python
"""Validate the schema of the emitted BENCH_*.json records.

CI runs the benches in smoke mode (REPRO_BENCH_SMOKE=1) and then this
script, so a bench refactor that silently changes the machine-readable
record — the committed perf trajectory — fails fast instead of producing
an artifact later PRs cannot compare against.
"""
from __future__ import annotations

import json
import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parents[1]

SWEEP_Q_KEYS = {"host_s", "engine_s", "engine_vs_host",
                "temp_bytes_chunked", "temp_bytes_unchunked",
                "est_dense_bytes"}

WARM_COLD_Q_KEYS = {"cold_s", "warm_s", "warm_vs_cold_speedup",
                    "cold_trace_cholesky_calls",
                    "warm_trace_cholesky_calls", "cold_n_exact_chol",
                    "warm_n_exact_chol", "cache"}

OVERLAP_KEYS = {"h", "k", "q", "chunk", "block", "serial_s", "pipelined_s",
                "early_stop_s", "pipelined_vs_serial", "overlap_vs_serial",
                "chunks_total", "chunks_evaluated", "lams_evaluated",
                "argmin_match"}

#: ISSUE-4 acceptance floor for the committed (non-smoke) record: the
#: pipelined early-stop search must beat the serial full sweep by ≥1.15×
#: wall-clock at k=10 folds, h=512 on the benchmark host.
OVERLAP_MIN_SPEEDUP = 1.15

PRECISION_KEYS = {"h", "k", "q", "chunk", "block", "policies",
                  "speedup_bf16_store", "mem_ratio_bf16_store",
                  "argmin_match"}

PRECISION_POLICY_KEYS = {"cold_s", "state_bytes", "replay_temp_bytes",
                         "packed_bytes_per_lam", "best_lam", "argmin_index"}

#: ISSUE-5 acceptance floors for the committed (non-smoke) record at
#: h=512: bf16 storage must deliver ≥1.3× cold-sweep speedup OR ≥1.9×
#: fitted-state memory reduction vs fp32 (either floor satisfies — on a
#: CPU container the win is memory, on TPU both apply), and bf16_refined
#: must reproduce the fp32 hold-out argmin exactly.
PRECISION_MIN_SPEEDUP = 1.3
PRECISION_MIN_MEM_RATIO = 1.9

AUTOTUNE_KEYS = {"h", "k", "q", "hw", "lattice", "n_candidates",
                 "lowerings", "tune_s", "cache_hit_second_tune",
                 "candidates", "chosen", "default", "tuned_vs_default",
                 "chosen_rank_measured", "argmin_match"}

AUTOTUNE_CONFIG_KEYS = {"block", "lam_chunk", "mesh_shape", "predicted_s",
                        "source", "measured_s"}

#: ISSUE-7 acceptance floors for the committed (non-smoke) record: the
#: roofline-chosen config must measure no slower than the default
#: (tuned_vs_default ≥ 1.0 — choosing the default itself is a legal
#: verdict and scores exactly 1.0), must land in the top-2 of the
#: measured candidate ordering (the static score ranks the lattice about
#: as well as running everything would), must change selection never math
#: (argmin parity with the default sweep), and re-tuning the same
#: geometry must be a pure cache hit.
AUTOTUNE_MIN_TUNED_VS_DEFAULT = 1.0
AUTOTUNE_MAX_CHOSEN_RANK = 1

SEARCH_KEYS = {"h", "k", "q", "wave", "tol_decades", "dense_s", "search_s",
               "waves", "lams_evaluated", "evals_vs_grid",
               "interval_decades", "stopped_on", "best_lam_dense",
               "best_lam_search", "lam_gap_decades", "lam_agree",
               "selection"}

SEARCH_SELECTION_KEYS = {"degree", "basis", "anchor_status",
                         "chol_calls_warm"}

#: ISSUE-8 acceptance floors for the committed (non-smoke) record: the
#: adaptive search must recover the dense grid's λ* within its interval
#: tolerance + one dense-grid step (``lam_agree``) while spending at most
#: HALF the dense grid's λ evaluations.  The self-tuning half —
#: interpolant selection against cached anchor targets factorizes
#: NOTHING — is scale-independent and enforced in smoke mode too.
SEARCH_MAX_EVALS_VS_GRID = 0.5

SKETCHED_KEYS = {"h", "n", "k", "q", "g", "method", "m_values",
                 "build_dense_s", "per_m", "speedup_sketched",
                 "tightens_with_m", "argmin_agree", "low_rank"}

SKETCHED_PER_M_KEYS = {"build_s", "build_speedup", "max_curve_diff",
                       "regret_on_dense", "regret_rel"}

SKETCHED_LOW_RANK_KEYS = {"h", "n", "k", "rank", "build_dense_s",
                          "build_lowrank_s", "speedup_low_rank",
                          "argmin_match", "max_curve_diff"}

#: ISSUE-9 acceptance floors for the committed (non-smoke) record: ONE of
#: the two regimes must deliver a ≥2× anchor-build speedup — sketched
#: Gram at n ≫ h (needs accelerator scatter; on the 1-core CPU host the
#: CountSketch segment-sum roughly ties BLAS dsyrk) OR the low-rank SVD
#: path at n ≪ h (g Choleskys of (h, h) vs one SVD of (n_tr, h); this is
#: the half that carries the floor on CPU, measured ~13× at h=768).
#: λ-selection agreement rides along: the low-rank argmin must match the
#: exact engine ALWAYS (same math at full rank — a mismatch is a bug, not
#: a small-problem artifact), the largest-m sketched pick must sit within
#: 1e-3 relative regret of the dense curve's minimum, and max_curve_diff
#: must tighten from the smallest to the largest m (the frontier claim).
SKETCHED_MIN_SPEEDUP = 2.0


def _check_sketched(rec: dict, errors: list) -> None:
    sa = rec.get("sketched_anchors", {})
    missing = SKETCHED_KEYS - sa.keys()
    if missing:
        errors.append(f"sketched_anchors missing {sorted(missing)}")
        return
    lr = sa["low_rank"]
    lm = SKETCHED_LOW_RANK_KEYS - lr.keys()
    if lm:
        errors.append(f"sketched_anchors.low_rank missing {sorted(lm)}")
        return
    if not sa["per_m"]:
        errors.append("sketched_anchors.per_m is empty")
    for m, mrec in sa["per_m"].items():
        mm = SKETCHED_PER_M_KEYS - mrec.keys()
        if mm:
            errors.append(f"sketched_anchors.per_m[{m}] missing {sorted(mm)}")
    # correctness halves are scale-independent: enforced in smoke too
    if not lr["argmin_match"]:
        errors.append(
            "sketched_anchors.low_rank: low_rank engine selected a "
            "different λ* than exact (full-rank spectral sweep is the "
            "same math — a mismatch is a bug, not an approximation)")
    # perf/accuracy floors are properties of the committed sizes on the
    # benchmark host; smoke shrinks the problem to schema-validation scale
    if not rec.get("smoke"):
        best = max(sa["speedup_sketched"], lr["speedup_low_rank"])
        if best < SKETCHED_MIN_SPEEDUP:
            errors.append(
                f"sketched_anchors: neither regime clears the "
                f"{SKETCHED_MIN_SPEEDUP}x anchor-build floor (sketched "
                f"{sa['speedup_sketched']:.3f}x, low_rank "
                f"{lr['speedup_low_rank']:.3f}x)")
        if not sa["argmin_agree"]:
            errors.append(
                "sketched_anchors: largest-m sketched λ* exceeds 1e-3 "
                "relative regret on the dense hold-out curve")
        if not sa["tightens_with_m"]:
            errors.append(
                "sketched_anchors: max_curve_diff did not tighten from "
                "the smallest to the largest m — growing the sketch no "
                "longer buys accuracy")


def check_table3(path: pathlib.Path) -> list[str]:
    errors = []
    rec = json.loads(path.read_text())
    if rec.get("schema") != "bench_table3/v1":
        errors.append(f"schema: expected bench_table3/v1, got {rec.get('schema')!r}")
    for key in ("sizes", "sweep_scaling", "warm_vs_cold", "overlap_vs_serial",
                "precision_sweep", "autotune", "adaptive_search",
                "sketched_anchors", "jax_backend", "x64", "smoke"):
        if key not in rec:
            errors.append(f"missing top-level key {key!r}")
    for h, times in rec.get("sizes", {}).items():
        for algo in ("chol", "pichol", "host_pichol", "engine_pichol",
                     "pichol_vs_chol_speedup", "engine_vs_host_pichol"):
            if algo not in times:
                errors.append(f"sizes[{h}] missing {algo!r}")
    sweep = rec.get("sweep_scaling", {})
    for key in ("h", "chunk", "block", "est_packed_chunk_bytes", "q"):
        if key not in sweep:
            errors.append(f"sweep_scaling missing {key!r}")
    if not sweep.get("q"):
        errors.append("sweep_scaling.q is empty")
    for q, qrec in sweep.get("q", {}).items():
        missing = SWEEP_Q_KEYS - qrec.keys()
        if missing:
            errors.append(f"sweep_scaling.q[{q}] missing {sorted(missing)}")
    wc = rec.get("warm_vs_cold", {})
    for key in ("h", "chunk", "block", "grids"):
        if key not in wc:
            errors.append(f"warm_vs_cold missing {key!r}")
    if not wc.get("grids"):
        errors.append("warm_vs_cold.grids is empty")
    for q, qrec in wc.get("grids", {}).items():
        missing = WARM_COLD_Q_KEYS - qrec.keys()
        if missing:
            errors.append(f"warm_vs_cold.grids[{q}] missing {sorted(missing)}")
            continue
        if qrec["warm_trace_cholesky_calls"] != 0:
            errors.append(
                f"warm_vs_cold.grids[{q}]: warm sweep traced "
                f"{qrec['warm_trace_cholesky_calls']} cholesky calls "
                "(the warm-replay contract is zero)")
        if qrec["warm_n_exact_chol"] != 0:
            errors.append(
                f"warm_vs_cold.grids[{q}]: warm_n_exact_chol must be 0")
    ov = rec.get("overlap_vs_serial", {})
    missing = OVERLAP_KEYS - ov.keys()
    if missing:
        errors.append(f"overlap_vs_serial missing {sorted(missing)}")
    else:
        if not ov["argmin_match"]:
            errors.append(
                "overlap_vs_serial: early-stopped search selected a "
                "different λ* than the serial full sweep (argmin_match "
                "is the correctness half of the early-stop contract)")
        if ov["chunks_evaluated"] >= ov["chunks_total"]:
            errors.append(
                "overlap_vs_serial: early stop never fired "
                f"({ov['chunks_evaluated']}/{ov['chunks_total']} chunks) — "
                "the λ grid no longer bottoms out mid-range")
        # the ≥1.15× floor is a property of the benchmark host at the
        # acceptance point (k=10, h=512); smoke mode shrinks the problem
        # to schema-validation scale where the ratio is meaningless
        if not rec.get("smoke") and \
                ov["overlap_vs_serial"] < OVERLAP_MIN_SPEEDUP:
            errors.append(
                f"overlap_vs_serial: committed speedup "
                f"{ov['overlap_vs_serial']:.3f}x below the "
                f"{OVERLAP_MIN_SPEEDUP}x acceptance floor")
    ps = rec.get("precision_sweep", {})
    missing = PRECISION_KEYS - ps.keys()
    if missing:
        errors.append(f"precision_sweep missing {sorted(missing)}")
    else:
        for pol in ("fp32", "bf16_store", "bf16_refined"):
            prec = ps["policies"].get(pol)
            if prec is None:
                errors.append(f"precision_sweep.policies missing {pol!r}")
                continue
            pm = PRECISION_POLICY_KEYS - prec.keys()
            if pm:
                errors.append(
                    f"precision_sweep.policies[{pol}] missing {sorted(pm)}")
        if not ps["argmin_match"]:
            errors.append(
                "precision_sweep: bf16_refined selected a different λ* than "
                "fp32 (refined reproduction of the fp32 argmin is the "
                "correctness half of the mixed-precision contract)")
        # the ≥1.3×-speed-OR-≥1.9×-memory floor is a property of the
        # committed h=512 record; smoke shrinks to schema-validation scale
        if not rec.get("smoke") and \
                ps["speedup_bf16_store"] < PRECISION_MIN_SPEEDUP and \
                ps["mem_ratio_bf16_store"] < PRECISION_MIN_MEM_RATIO:
            errors.append(
                f"precision_sweep: bf16_store delivers neither the "
                f"{PRECISION_MIN_SPEEDUP}x speed floor "
                f"({ps['speedup_bf16_store']:.3f}x) nor the "
                f"{PRECISION_MIN_MEM_RATIO}x memory floor "
                f"({ps['mem_ratio_bf16_store']:.3f}x)")
    at = rec.get("autotune", {})
    missing = AUTOTUNE_KEYS - at.keys()
    if missing:
        errors.append(f"autotune missing {sorted(missing)}")
    else:
        for label, cfg in (("chosen", at["chosen"]),
                           ("default", at["default"]),
                           *((f"candidates[{i}]", c)
                             for i, c in enumerate(at["candidates"]))):
            cm = AUTOTUNE_CONFIG_KEYS - cfg.keys()
            if cm:
                errors.append(f"autotune.{label} missing {sorted(cm)}")
        if not at["candidates"]:
            errors.append("autotune.candidates is empty")
        if at["lowerings"] < at["n_candidates"]:
            errors.append(
                f"autotune: only {at['lowerings']} lowerings for "
                f"{at['n_candidates']} candidates — scoring is no longer "
                "one AOT lowering per candidate")
        # correctness halves are scale-independent: enforced in smoke too
        if not at["cache_hit_second_tune"]:
            errors.append(
                "autotune: re-tuning the same geometry was not a tuning-"
                "cache hit (content-addressed reuse is the cache contract)")
        if not at["argmin_match"]:
            errors.append(
                "autotune: tuned sweep selected a different λ* than the "
                "default sweep (tuning must change tiling, never math)")
        # perf floors are properties of the committed benchmark host;
        # smoke shrinks the problem to schema-validation scale
        if not rec.get("smoke"):
            if at["tuned_vs_default"] < AUTOTUNE_MIN_TUNED_VS_DEFAULT:
                errors.append(
                    f"autotune: tuned config measured "
                    f"{at['tuned_vs_default']:.3f}x vs default — the "
                    f"roofline choice made the sweep SLOWER (floor: "
                    f"{AUTOTUNE_MIN_TUNED_VS_DEFAULT}x)")
            if at["chosen_rank_measured"] > AUTOTUNE_MAX_CHOSEN_RANK:
                errors.append(
                    f"autotune: chosen config ranks "
                    f"{at['chosen_rank_measured']} in the measured ordering "
                    f"(floor: top-{AUTOTUNE_MAX_CHOSEN_RANK + 1})")
    se = rec.get("adaptive_search", {})
    missing = SEARCH_KEYS - se.keys()
    if missing:
        errors.append(f"adaptive_search missing {sorted(missing)}")
    else:
        sm = SEARCH_SELECTION_KEYS - se["selection"].keys()
        if sm:
            errors.append(f"adaptive_search.selection missing {sorted(sm)}")
        elif se["selection"]["chol_calls_warm"] != 0:
            errors.append(
                f"adaptive_search.selection: "
                f"{se['selection']['chol_calls_warm']} cholesky calls "
                "during selection against a warm anchor cache (the "
                "zero-factorization contract)")
        if se["lams_evaluated"] >= se["q"]:
            errors.append(
                f"adaptive_search: {se['lams_evaluated']} evaluations for "
                f"a q={se['q']} dense grid — the search never saved a "
                "single solve")
        # perf/agreement floors are properties of the committed grid
        # density on the benchmark host; smoke shrinks the problem to
        # schema-validation scale
        if not rec.get("smoke"):
            if se["evals_vs_grid"] > SEARCH_MAX_EVALS_VS_GRID:
                errors.append(
                    f"adaptive_search: evals_vs_grid "
                    f"{se['evals_vs_grid']:.3f} above the "
                    f"{SEARCH_MAX_EVALS_VS_GRID} acceptance ceiling")
            if not se["lam_agree"]:
                errors.append(
                    f"adaptive_search: search λ* "
                    f"{se['best_lam_search']:.4g} missed the dense grid's "
                    f"{se['best_lam_dense']:.4g} by "
                    f"{se['lam_gap_decades']:.3f} decades (tolerance: "
                    f"tol_decades + one grid step)")
    _check_sketched(rec, errors)
    return errors


SERVING_TOP_KEYS = {"schema", "smoke", "jax_backend", "x64", "config",
                    "latency", "throughput_rps", "wall_s", "cache",
                    "tenants", "batching", "fidelity"}
SERVING_LATENCY_KEYS = {"p50_s", "p99_s", "mean_s", "max_s"}
SERVING_CACHE_KEYS = {"hits", "misses", "hit_rate", "anchor_hits", "entries",
                      "evictions", "bytes", "bytes_saved",
                      "live_bytes_saved", "tenants_sharing"}
SERVING_FIDELITY_KEYS = {"problems_audited", "argmin_match", "bitwise_match"}

#: ISSUE-6 acceptance floors for the committed (non-smoke) record: the
#: Zipf traffic mix must produce cross-tenant sharing (hit-rate > 0 with
#: ≥ 2 tenants hitting the shared cache).  Fidelity (per-tenant argmin ==
#: solo cold sweep, bit-for-bit) is a correctness contract and is
#: enforced in smoke mode too.
SERVING_MIN_HIT_RATE = 0.0        # strict: hit_rate must exceed this
SERVING_MIN_TENANTS_SHARING = 2


def check_serving(path: pathlib.Path) -> list[str]:
    errors = []
    rec = json.loads(path.read_text())
    if rec.get("schema") != "bench_serving/v1":
        errors.append(
            f"schema: expected bench_serving/v1, got {rec.get('schema')!r}")
    missing = SERVING_TOP_KEYS - rec.keys()
    if missing:
        errors.append(f"missing top-level keys {sorted(missing)}")
        return errors
    for section, keys in (("latency", SERVING_LATENCY_KEYS),
                          ("cache", SERVING_CACHE_KEYS),
                          ("fidelity", SERVING_FIDELITY_KEYS)):
        miss = keys - rec[section].keys()
        if miss:
            errors.append(f"{section} missing {sorted(miss)}")
    if errors:
        return errors
    if not rec["tenants"]:
        errors.append("tenants section is empty — per-tenant stat "
                      "partitioning produced nothing")
    # correctness is precision-independent and enforced in smoke mode too:
    # a served result that disagrees with the solo cold sweep is a stale
    # or foreign cache read, never a small-problem artifact
    if not rec["fidelity"]["argmin_match"]:
        errors.append(
            "fidelity: a tenant's served argmin differs from its solo "
            "cold sweep (shared-cache serving must be bit-for-bit)")
    # perf/sharing floors are properties of the committed traffic mix on
    # the benchmark host — smoke mode shrinks the problem to
    # schema-validation scale where rates and latencies are meaningless
    if not rec.get("smoke"):
        if rec["cache"]["hit_rate"] <= SERVING_MIN_HIT_RATE:
            errors.append(
                f"cache: hit_rate {rec['cache']['hit_rate']:.3f} — the "
                "Zipf mix produced no cross-tenant reuse")
        if rec["cache"]["tenants_sharing"] < SERVING_MIN_TENANTS_SHARING:
            errors.append(
                f"cache: only {rec['cache']['tenants_sharing']} tenant(s) "
                f"hit the shared cache (floor: "
                f"{SERVING_MIN_TENANTS_SHARING})")
        if rec["throughput_rps"] <= 0:
            errors.append("throughput_rps must be positive")
    return errors


CHECKS = {
    "BENCH_table3.json": (check_table3, "python -m benchmarks.run table3"),
    "BENCH_serving.json": (check_serving, "python -m benchmarks.run serving"),
}


def main() -> int:
    failed = False
    for name, (check, hint) in CHECKS.items():
        path = ROOT / name
        if not path.exists():
            print(f"FAIL: {path} not found (run `{hint}`)")
            failed = True
            continue
        errors = check(path)
        for e in errors:
            print(f"FAIL: {name}: {e}")
        if errors:
            failed = True
        else:
            print(f"{name} schema OK")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
