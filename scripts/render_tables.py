"""Render §Dry-run / §Roofline markdown tables from results/dryrun/*.json,
and the committed bench records (``BENCH_table3.json`` including the
mixed-precision ``precision_sweep`` section, and the multi-tenant
``BENCH_serving.json``).

    PYTHONPATH=src python scripts/render_tables.py [--out results/tables.md]
    PYTHONPATH=src python scripts/render_tables.py --bench BENCH_table3.json
    PYTHONPATH=src python scripts/render_tables.py --bench BENCH_serving.json
"""
import argparse
import glob
import json
import os


def fmt(x, digits=3):
    if x is None:
        return "-"
    return f"{x:.{digits}e}" if (abs(x) < 1e-2 or abs(x) >= 1e4) else f"{x:.{digits}f}"


def fmt_bytes(n):
    if n is None:
        return "-"
    for unit in ("B", "KB", "MB", "GB"):
        if abs(n) < 1024 or unit == "GB":
            return f"{n:.1f}{unit}" if unit != "B" else f"{n}B"
        n /= 1024.0


def render_serving(rec, lines):
    """Markdown sections for a ``bench_serving/v1`` record."""
    cfg = rec.get("config", {})
    lat = rec.get("latency", {})
    cache = rec.get("cache", {})
    lines += [f"## Serving traffic (n={cfg.get('n_requests')} requests, "
              f"{cfg.get('n_tenants')} tenants, "
              f"{cfg.get('n_problems')} problems, h={cfg.get('h')}, "
              f"zipf_a={cfg.get('zipf_a')})", "",
              "| p50 latency | p99 latency | throughput | wall |",
              "|---|---|---|---|",
              f"| {fmt(lat.get('p50_s'))}s | {fmt(lat.get('p99_s'))}s "
              f"| {fmt(rec.get('throughput_rps'), 2)} req/s "
              f"| {fmt(rec.get('wall_s'))}s |", "",
              "## Shared-cache hit-rate", "",
              "| hits | misses | hit rate | anchor hits | tenants sharing "
              "| evictions |",
              "|---|---|---|---|---|---|",
              f"| {cache.get('hits')} | {cache.get('misses')} "
              f"| **{fmt(cache.get('hit_rate'), 3)}** "
              f"| {cache.get('anchor_hits')} "
              f"| {cache.get('tenants_sharing')}/{cfg.get('n_tenants')} "
              f"| {cache.get('evictions')} |", ""]
    tenants = rec.get("tenants", {})
    if tenants:
        lines += ["### Per-tenant partition", "",
                  "| tenant | hits | misses | anchor hits | puts |",
                  "|---|---|---|---|---|"]
        for t, r in sorted(tenants.items()):
            lines.append(f"| {t} | {r.get('hits')} | {r.get('misses')} "
                         f"| {r.get('anchor_hits')} | {r.get('puts')} |")
        lines.append("")
    fid = rec.get("fidelity", {})
    bat = rec.get("batching", {})
    lines += [f"batching: {bat.get('dispatches')} dispatches, mean batch "
              f"{fmt(bat.get('batch_mean'), 2)}; fidelity: "
              f"{fid.get('problems_audited')} problems audited, "
              f"argmin_match=**{fid.get('argmin_match')}**, "
              f"bitwise_match=**{fid.get('bitwise_match')}**", ""]
    return lines


def render_bench(path):
    """Markdown lines for a committed BENCH_*.json record."""
    rec = json.load(open(path))
    lines = [f"# Bench record: {os.path.basename(path)} "
             f"({rec.get('schema', '?')}, smoke={rec.get('smoke')})", ""]

    if rec.get("schema") == "bench_serving/v1":
        return render_serving(rec, lines)

    wc = rec.get("warm_vs_cold", {})
    if wc.get("grids"):
        lines += ["## Warm-replay vs cold sweep", "",
                  "| q | cold s | warm s | speedup | warm chol calls |",
                  "|---|---|---|---|---|"]
        for q, r in sorted(wc["grids"].items(), key=lambda kv: int(kv[0])):
            lines.append(f"| {q} | {fmt(r['cold_s'])} | {fmt(r['warm_s'])} "
                         f"| {fmt(r['warm_vs_cold_speedup'], 2)}x "
                         f"| {r['warm_trace_cholesky_calls']} |")
        lines.append("")

    ov = rec.get("overlap_vs_serial", {})
    if ov:
        lines += ["## Pipelined early-stop vs serial full sweep", "",
                  f"serial {fmt(ov.get('serial_s'))}s → early-stop "
                  f"{fmt(ov.get('early_stop_s'))}s "
                  f"(**{fmt(ov.get('overlap_vs_serial'), 2)}x**, "
                  f"{ov.get('chunks_evaluated')}/{ov.get('chunks_total')} "
                  f"chunks, argmin_match={ov.get('argmin_match')})", ""]

    ps = rec.get("precision_sweep", {})
    if ps.get("policies"):
        lines += [f"## Mixed-precision sweep (h={ps.get('h')}, "
                  f"q={ps.get('q')}, chunk={ps.get('chunk')})", "",
                  "| policy | cold s | state bytes | packed B/λ | λ* |",
                  "|---|---|---|---|---|"]
        for pol in ("fp32", "bf16_store", "bf16_refined"):
            r = ps["policies"].get(pol)
            if r is None:
                continue
            lines.append(f"| {pol} | {fmt(r['cold_s'])} "
                         f"| {fmt_bytes(r['state_bytes'])} "
                         f"| {fmt_bytes(r['packed_bytes_per_lam'])} "
                         f"| {fmt(r['best_lam'], 4)} |")
        lines += ["",
                  f"bf16_store vs fp32: "
                  f"**{fmt(ps.get('speedup_bf16_store'), 2)}x** speed, "
                  f"**{fmt(ps.get('mem_ratio_bf16_store'), 2)}x** state "
                  f"memory; bf16_refined argmin_match="
                  f"**{ps.get('argmin_match')}**", ""]
    return lines


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--src", default="results/dryrun")
    ap.add_argument("--out", default="results/tables.md")
    ap.add_argument("--bench", default=None,
                    help="render a committed BENCH_*.json record instead "
                         "of the dry-run tables")
    args = ap.parse_args()

    if args.bench:
        print("\n".join(render_bench(args.bench)))
        return

    rows = []
    for f in sorted(glob.glob(os.path.join(args.src, "*.json"))):
        rows.append(json.load(open(f)))

    lines = ["# Dry-run / roofline tables (generated)", ""]
    for mesh, tag in (("16x16", "single-pod (256 chips)"),
                      ("2x16x16", "multi-pod (512 chips)")):
        lines.append(f"## {tag}")
        lines.append("")
        lines.append("| cell | status | compile s | temp GB | args GB | "
                     "compute s | memory s | collective s | bottleneck | "
                     "useful flops |")
        lines.append("|---|---|---|---|---|---|---|---|---|---|")
        for r in rows:
            if r["cell"].rsplit("×", 1)[-1] != mesh:
                continue
            cell = r["cell"].rsplit("×", 1)[0]
            if r["status"] != "ok":
                lines.append(f"| {cell} | {r['status']}: "
                             f"{r.get('reason', r.get('error', ''))[:60]} "
                             f"| | | | | | | | |")
                continue
            ro = r["roofline"]
            mem = r["memory"]
            temp = (mem.get("temp_size_in_bytes") or 0) / 1e9
            arg = (mem.get("argument_size_in_bytes") or 0) / 1e9
            lines.append(
                f"| {cell} | ok | {r['compile_s']} | {temp:.1f} | {arg:.2f} "
                f"| {fmt(ro['compute_s'])} | {fmt(ro['memory_s'])} "
                f"| {fmt(ro['collective_s'])} | {ro['bottleneck']} "
                f"| {fmt(r.get('useful_flops_frac'), 2)} |")
        lines.append("")
    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        f.write("\n".join(lines) + "\n")
    print(f"wrote {args.out} ({len(rows)} cells)")


if __name__ == "__main__":
    main()
