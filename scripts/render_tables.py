"""Render §Dry-run / §Roofline markdown tables from results/dryrun/*.json.

    PYTHONPATH=src python scripts/render_tables.py [--out results/tables.md]
"""
import argparse
import glob
import json
import os


def fmt(x, digits=3):
    if x is None:
        return "-"
    return f"{x:.{digits}e}" if (abs(x) < 1e-2 or abs(x) >= 1e4) else f"{x:.{digits}f}"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--src", default="results/dryrun")
    ap.add_argument("--out", default="results/tables.md")
    args = ap.parse_args()

    rows = []
    for f in sorted(glob.glob(os.path.join(args.src, "*.json"))):
        rows.append(json.load(open(f)))

    lines = ["# Dry-run / roofline tables (generated)", ""]
    for mesh, tag in (("16x16", "single-pod (256 chips)"),
                      ("2x16x16", "multi-pod (512 chips)")):
        lines.append(f"## {tag}")
        lines.append("")
        lines.append("| cell | status | compile s | temp GB | args GB | "
                     "compute s | memory s | collective s | bottleneck | "
                     "useful flops |")
        lines.append("|---|---|---|---|---|---|---|---|---|---|")
        for r in rows:
            if r["cell"].rsplit("×", 1)[-1] != mesh:
                continue
            cell = r["cell"].rsplit("×", 1)[0]
            if r["status"] != "ok":
                lines.append(f"| {cell} | {r['status']}: "
                             f"{r.get('reason', r.get('error', ''))[:60]} "
                             f"| | | | | | | | |")
                continue
            ro = r["roofline"]
            mem = r["memory"]
            temp = (mem.get("temp_size_in_bytes") or 0) / 1e9
            arg = (mem.get("argument_size_in_bytes") or 0) / 1e9
            lines.append(
                f"| {cell} | ok | {r['compile_s']} | {temp:.1f} | {arg:.2f} "
                f"| {fmt(ro['compute_s'])} | {fmt(ro['memory_s'])} "
                f"| {fmt(ro['collective_s'])} | {ro['bottleneck']} "
                f"| {fmt(r.get('useful_flops_frac'), 2)} |")
        lines.append("")
    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        f.write("\n".join(lines) + "\n")
    print(f"wrote {args.out} ({len(rows)} cells)")


if __name__ == "__main__":
    main()
