"""Fault-tolerant checkpointing.

Guarantees:
* **Atomicity** — arrays land in ``step_<N>.tmp/``; a manifest (tree
  structure + per-leaf sha256) is written last; the directory is
  ``os.replace``d into place only after everything fsyncs.  A crash
  mid-write can never corrupt the latest valid checkpoint.
* **Auto-resume** — ``restore_latest`` walks checkpoints newest-first and
  skips any whose manifest hash-check fails (torn writes from a killed
  host), restoring the newest valid one.
* **Elastic resharding** — checkpoints are mesh-agnostic (full logical
  arrays on disk).  ``restore`` accepts a sharding tree for ANY mesh shape
  and ``jax.device_put``s each leaf onto it, so a job can restart on a
  different number of pods/chips than it crashed on.
* **Async** — ``save_async`` snapshots to host then writes on a worker
  thread, keeping the training loop running.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np

__all__ = ["CheckpointManager"]


def _flatten(tree: Any):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


class CheckpointManager:
    """``keep`` bounds how many steps survive garbage collection; ``None``
    disables GC entirely (content stores like the factor cache keep every
    entry — each one is independently addressable, not a rolling history)."""

    def __init__(self, directory: str, keep: Optional[int] = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------- save

    def save(self, step: int, tree: Any) -> str:
        leaves, treedef = _flatten(tree)
        host = [np.asarray(l) for l in leaves]
        return self._write(step, host, treedef)

    def save_async(self, step: int, tree: Any) -> None:
        self.wait()
        leaves, treedef = _flatten(tree)
        host = [np.asarray(l) for l in leaves]   # device→host snapshot now

        def work():
            self._write(step, host, treedef)

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def step_dir(self, step: int) -> str:
        """Directory a given step lives in (exists only once saved)."""
        return os.path.join(self.directory, f"step_{step:012d}")

    def _write(self, step: int, host_leaves, treedef) -> str:
        final = self.step_dir(step)
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        manifest = {"step": step, "treedef": str(treedef), "leaves": []}
        for i, arr in enumerate(host_leaves):
            path = os.path.join(tmp, f"leaf_{i:06d}.npy")
            np.save(path, arr)
            with open(path, "rb") as f:
                digest = hashlib.sha256(f.read()).hexdigest()
            manifest["leaves"].append(
                {"i": i, "shape": list(arr.shape), "dtype": str(arr.dtype),
                 "sha256": digest})
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
        self._gc()
        return final

    def _gc(self):
        if self.keep is None:
            return
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:012d}"),
                          ignore_errors=True)

    # ------------------------------------------------------------- load

    def all_steps(self):
        out = []
        for name in os.listdir(self.directory):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    out.append(int(name[5:]))
                except ValueError:
                    pass
        return sorted(out)

    def _verify(self, path: str) -> Optional[dict]:
        mpath = os.path.join(path, "manifest.json")
        if not os.path.exists(mpath):
            return None
        with open(mpath) as f:
            manifest = json.load(f)
        for leaf in manifest["leaves"]:
            lp = os.path.join(path, f"leaf_{leaf['i']:06d}.npy")
            if not os.path.exists(lp):
                return None
            with open(lp, "rb") as fh:
                if hashlib.sha256(fh.read()).hexdigest() != leaf["sha256"]:
                    return None
        return manifest

    def restore(self, step: int, like: Any, shardings: Any = None) -> Any:
        """Restore into the structure of ``like``; optionally placing each
        leaf onto ``shardings`` (tree of NamedSharding — any mesh shape)."""
        path = os.path.join(self.directory, f"step_{step:012d}")
        manifest = self._verify(path)
        if manifest is None:
            raise IOError(f"checkpoint at {path} is missing or corrupt")
        leaves, treedef = _flatten(like)
        host = [np.load(os.path.join(path, f"leaf_{i:06d}.npy"))
                for i in range(len(leaves))]
        # extension dtypes (bfloat16) survive np.save only as raw bytes —
        # view them back to the dtype the manifest recorded
        for n, (arr, meta) in enumerate(zip(host, manifest["leaves"])):
            if str(arr.dtype) != meta["dtype"]:
                host[n] = arr.view(np.dtype(meta["dtype"])
                                   ).reshape(meta["shape"])
        if shardings is not None:
            sh_leaves = jax.tree.leaves(shardings)
            host = [jax.device_put(a, s) for a, s in zip(host, sh_leaves)]
        else:
            host = [jax.numpy.asarray(a) for a in host]
        return jax.tree.unflatten(treedef, host)

    def restore_latest(self, like: Any, shardings: Any = None):
        """Newest *valid* checkpoint (skips torn writes). Returns
        (step, tree) or (None, None) when nothing restorable exists."""
        for step in reversed(self.all_steps()):
            path = os.path.join(self.directory, f"step_{step:012d}")
            if self._verify(path) is not None:
                return step, self.restore(step, like, shardings)
        return None, None
