"""Architecture registry: one module per assigned arch (+ the paper's own
ridge-CV workload config).  ``get(name)`` returns the full ModelConfig;
``get(name).reduced()`` the CPU smoke variant.
"""
from __future__ import annotations

from typing import Dict, List

from repro.models.config import ModelConfig

from . import (falcon_mamba_7b, h2o_danube_3_4b, kimi_k2_1t_a32b,
               llama_3_2_vision_11b, minicpm_2b, mixtral_8x7b, picholesky,
               qwen2_1_5b, recurrentgemma_2b, smollm_360m, whisper_base)

_MODULES = [
    qwen2_1_5b, smollm_360m, minicpm_2b, h2o_danube_3_4b, falcon_mamba_7b,
    whisper_base, llama_3_2_vision_11b, recurrentgemma_2b, mixtral_8x7b,
    kimi_k2_1t_a32b,
]

REGISTRY: Dict[str, ModelConfig] = {m.CONFIG.name: m.CONFIG for m in _MODULES}


def get(name: str) -> ModelConfig:
    return REGISTRY[name]


def names() -> List[str]:
    return list(REGISTRY)


# shape grid assigned to the LM pool (seq_len, global_batch, kind)
SHAPES = {
    "train_4k": dict(seq_len=4096, global_batch=256, kind="train"),
    "prefill_32k": dict(seq_len=32768, global_batch=32, kind="prefill"),
    "decode_32k": dict(seq_len=32768, global_batch=128, kind="decode"),
    "long_500k": dict(seq_len=524288, global_batch=1, kind="decode"),
}


def cells():
    """All 40 (arch × shape) cells with runnable/skip annotation."""
    out = []
    for name, cfg in REGISTRY.items():
        for shape, meta in SHAPES.items():
            skip = None
            if shape == "long_500k" and not cfg.subquadratic:
                skip = "pure full-attention arch: 500k decode cache is " \
                       "O(seq) with quadratic prefill — per DESIGN.md §5"
            out.append((name, shape, meta, skip))
    return out
