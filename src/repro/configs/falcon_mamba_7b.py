"""Falcon-Mamba-7B — attention-free Mamba-1 [arXiv:2410.05355; unverified]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b",
    family="ssm",
    n_layers=64,
    d_model=4096,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab_size=65024,
    ssm_state=16,
    d_conv=4,
    expand=2,
)
