"""Llama-3.2-Vision-11B backbone — gated cross-attn image layers every 5;
ViT frontend is a STUB (precomputed patch embeddings)
[hf:meta-llama/Llama-3.2-11B-Vision; unverified]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=128256,
    rope_theta=500_000.0,
    cross_attn_every=5,
    n_image_tokens=1601,
    act="silu",
)
