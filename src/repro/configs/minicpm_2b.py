"""MiniCPM-2B — llama-like dense (WSD schedule) [arXiv:2404.06395; hf]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="minicpm-2b",
    family="dense",
    n_layers=40,
    d_model=2304,
    n_heads=36,
    n_kv_heads=36,
    d_ff=5760,
    vocab_size=122753,
    act="silu",
)
