"""Mixtral-8x7B — 8-expert top-2 MoE with SWA [arXiv:2401.04088; hf]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=32000,
    sliding_window=4096,
    n_experts=8,
    top_k=2,
    moe_d_ff=14336,
    act="silu",
)
