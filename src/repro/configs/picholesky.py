"""The paper's own workload configuration: ridge cross-validation grids
for the piCholesky experiments (§6.3)."""
import dataclasses


@dataclasses.dataclass(frozen=True)
class PiCholeskyConfig:
    h: int = 1024                 # feature dim + intercept (paper: up to 16384)
    n_train: int = 4096
    k_folds: int = 5
    n_lambdas: int = 31           # dense candidate grid (paper: 31)
    g_samples: int = 4            # sparse exact factorizations (paper: 4)
    degree: int = 2               # polynomial order (paper: 2)
    lam_lo: float = 1e-3
    lam_hi: float = 1.0
    block: int = 128              # packing/factorization tile
    mchol_s: float = 1.5
    mchol_s0: float = 0.0025


CONFIG = PiCholeskyConfig()
