"""RecurrentGemma-2B — RG-LRU + local attention, 2:1 pattern
[arXiv:2402.19427; hf]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    d_ff=7680,
    vocab_size=256000,
    head_dim=256,
    pattern_rnn=2,
    local_window=2048,
    lru_width=2560,
    act="silu",
)
