"""Whisper-base — encoder-decoder; conv/mel frontend is a STUB: input_specs
provides precomputed frame embeddings [arXiv:2212.04356; unverified]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    family="audio",
    n_layers=6,
    n_enc_layers=6,
    d_model=512,
    n_heads=8,
    n_kv_heads=8,
    d_ff=2048,
    vocab_size=51865,
    act="gelu",
    enc_seq_ratio=2,
)
