"""repro.core — piCholesky: polynomial interpolation of Cholesky factors.

Public API:
  packing      tile-major triangular pack/unpack (TPU-aligned §5 layout)
  picholesky   Algorithm 1 fit/eval
  solvers      ridge solvers (Chol / SVD / t-SVD / r-SVD)
  backends     the single backend= switch (Pallas kernels vs jnp.linalg)
  engine       CVEngine — jitted/sharded fold × λ sweep + CVStrategy plug-ins
  factor_cache warm-replay cache of fitted Θ / packed anchors (content-keyed)
  cv           k-fold CV drivers (compat wrappers over the engine) + MChol
  cv_host      pre-engine host-loop drivers (benchmark baseline, test oracle)
  bound        Theorem 4.4/4.7 error-bound terms
  ridge_cv     RidgeCV — the end-to-end, mesh-aware entry point
  precision    PrecisionPolicy — the pipeline's mixed-precision contract
"""
from . import (backends, bound, cv, cv_host, engine, factor_cache,  # noqa: F401
               folds, packing, picholesky, precision, ridge_cv, solvers)
from .backends import resolve_backend  # noqa: F401
from .precision import PrecisionPolicy, resolve_precision  # noqa: F401
from .engine import CVEngine, CVStrategy, make_strategy  # noqa: F401
from .factor_cache import FactorCache  # noqa: F401
from .folds import CVResult, FoldData, make_folds  # noqa: F401
from .picholesky import PiCholesky, fit as fit_picholesky  # noqa: F401
from .ridge_cv import RidgeCV  # noqa: F401
