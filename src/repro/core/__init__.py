"""repro.core — piCholesky: polynomial interpolation of Cholesky factors.

Public API:
  packing      tile-major triangular pack/unpack (TPU-aligned §5 layout)
  picholesky   Algorithm 1 fit/eval
  solvers      ridge solvers (Chol / SVD / t-SVD / r-SVD)
  cv           k-fold CV drivers (Chol, PIChol, MChol, SVD family, PINRMSE)
  bound        Theorem 4.4/4.7 error-bound terms
  ridge_cv     RidgeCV — the end-to-end, mesh-aware entry point
"""
from . import bound, cv, packing, picholesky, ridge_cv, solvers  # noqa: F401
from .cv import CVResult, FoldData, make_folds  # noqa: F401
from .picholesky import PiCholesky, fit as fit_picholesky  # noqa: F401
from .ridge_cv import RidgeCV  # noqa: F401
