"""Linear-algebra backend selection — the single ``backend=`` switch.

Every piCholesky hot spot (factorize, triangular solve, pack/unpack,
interpolant evaluation) has two implementations: the Pallas TPU kernels in
:mod:`repro.kernels` and the ``jnp.linalg`` reference path.  This module
packages each pair behind one object so callers (``solvers.py``,
``picholesky.py``, the :class:`~repro.core.engine.CVEngine`) thread a single
``backend=`` argument instead of per-function ``chol_fn`` plumbing.

Resolution rules for :func:`resolve_backend`:

* ``None`` / ``"auto"`` — Pallas kernels when the default jax backend is TPU
  (compiled) and the plain ``jnp.linalg`` path elsewhere.  On CPU the Pallas
  path would run in interpret mode, which is only useful for testing.
* ``"pallas"`` — force the kernel path (interpret mode off-TPU).
* ``"reference"`` / ``"ref"`` — force the ``jnp.linalg`` path.
* an existing :class:`LinalgBackend` — returned unchanged.

Kernel imports happen lazily inside the Pallas methods so importing
``repro.core`` never drags in the Pallas toolchain.

Every backend also carries the pipeline's
:class:`~repro.core.precision.PrecisionPolicy` (``precision=``): the
factorization runs at the policy's accumulation dtype (never 16-bit), the
packed-domain solves feed the MXU at the compute dtype with full-precision
accumulation, and solutions come back in the accumulation dtype.  The
default ``native`` policy inherits every input dtype — bit-compatible with
the pre-policy backends.
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
from typing import Union

import jax
import jax.numpy as jnp

from .precision import PRESETS, PrecisionLike, PrecisionPolicy, \
    resolve_precision

__all__ = ["LinalgBackend", "ReferenceBackend", "PallasBackend",
           "CountingBackend", "resolve_backend", "retile_backend",
           "BackendLike"]


class LinalgBackend:
    """Interface shared by both backends (duck-typed, no ABC machinery).

    Two groups of methods: the dense surface (``cholesky`` / ``solve_lower``
    / ``solve_from_factor`` / ``pack_tril`` / ``unpack_tril``) and the
    packed-domain surface (``solve_packed`` / ``interp_solve`` /
    ``interp_factors``), which consumes the tile-packed ``(P,)`` layout
    directly so factors never round-trip through a dense ``(h, h)`` buffer
    on the sweep hot path.
    """

    name: str = "abstract"
    precision: PrecisionPolicy = PRESETS["native"]

    def with_precision(self, policy: PrecisionPolicy) -> "LinalgBackend":
        """This backend with ``policy`` attached (same kernels, new dtype
        contract).  Frozen-dataclass backends return a copy."""
        return dataclasses.replace(self, precision=policy)

    def cholesky(self, a: jax.Array) -> jax.Array:
        raise NotImplementedError

    def solve_lower(self, l: jax.Array, b: jax.Array, *,
                    transpose: bool = False) -> jax.Array:
        raise NotImplementedError

    def solve_from_factor(self, l, g: jax.Array) -> jax.Array:
        """L Lᵀ θ = g via forward + back substitution.

        ``l`` may be a dense factor or a :class:`~repro.core.packing.PackedFactor`
        (dispatched to :meth:`solve_packed` — no unpack).
        """
        from .packing import PackedFactor
        if isinstance(l, PackedFactor):
            return self.solve_packed(l, g)
        w = self.solve_lower(l, g)
        return self.solve_lower(l, w, transpose=True)

    def pack_tril(self, mat: jax.Array, block: int) -> jax.Array:
        raise NotImplementedError

    def unpack_tril(self, vec: jax.Array, h: int, block: int) -> jax.Array:
        raise NotImplementedError

    # -- packed-domain surface (the factor pipeline's native currency) -----

    def solve_packed(self, pf, g: jax.Array) -> jax.Array:
        """L Lᵀ θ = g directly on the tile-packed factor (no dense L)."""
        raise NotImplementedError

    def interp_solve(self, theta: jax.Array, lams: jax.Array, g: jax.Array,
                     *, h: int, block: int, center=0.0,
                     rhs_per_lam: bool = False) -> jax.Array:
        """Fused interpolant evaluation + substitution at a λ chunk:
        (q, h) solutions with no (q, h, h) — or even (q, P) on the kernel
        path — intermediate.  ``rhs_per_lam=True`` takes a per-λ RHS
        (q, h[, m]) — the refinement residuals — instead of one shared g."""
        raise NotImplementedError

    def interp_factors(self, theta: jax.Array, lams: jax.Array,
                       *, h: int, block: int, center=0.0) -> jax.Array:
        """Dense interpolated factors (q, h, h) — debug / dense consumers."""
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class ReferenceBackend(LinalgBackend):
    """``jnp.linalg`` path — correct on every platform, XLA-fused.

    Mixed precision on this path keeps the *storage* contract (bf16 Θ and
    packed rows stream at half the bytes) while the substitutions run at
    the accumulation dtype — ``jnp.linalg`` has no 16-bit factorization,
    and a bf16-stored factor is defined as the rounding of a
    full-precision one, not a bf16 factorization.
    """

    name: str = "reference"
    precision: PrecisionPolicy = PRESETS["native"]

    def cholesky(self, a):
        # factorize at the accumulation dtype: bf16 inputs promote to fp32
        return jnp.linalg.cholesky(
            a.astype(self.precision.accum_dtype(a.dtype)))

    def solve_lower(self, l, b, *, transpose=False):
        l = l.astype(self.precision.accum_dtype(l.dtype))
        b2 = b[..., None] if b.ndim == l.ndim - 1 else b
        out = jax.lax.linalg.triangular_solve(
            l, b2.astype(l.dtype), left_side=True, lower=True,
            transpose_a=transpose)
        return out[..., 0] if b.ndim == l.ndim - 1 else out

    def pack_tril(self, mat, block):
        from . import packing
        return packing.pack_tril(mat, block)

    def unpack_tril(self, vec, h, block):
        from . import packing
        return packing.unpack_tril(vec, h, block)

    def solve_packed(self, pf, g):
        from . import packing
        ad = self.precision.accum_dtype(pf.vec.dtype)
        # vec is consumed at its storage dtype (tiles promote per-GEMM) —
        # no full-width upcast copy of the packed batch
        fn = functools.partial(packing.solve_packed_ref,
                               h=pf.h, block=pf.block, accum_dtype=ad)
        for _ in range(pf.vec.ndim - 1):   # batched factors via vmap
            fn = jax.vmap(fn, in_axes=(0, None))
        return fn(pf.vec, g.astype(ad))

    def interp_solve(self, theta, lams, g, *, h, block, center=0.0,
                     rhs_per_lam=False):
        from . import packing, picholesky
        ad = self.precision.accum_dtype(theta.dtype)
        model = picholesky.PiCholesky(
            theta=theta, center=jnp.asarray(center, ad),
            h=h, block=block)
        # (q, P) interpolated rows at the STORAGE dtype — the policy's
        # memory win on this path; the substitution accumulates at accum
        # with each tile promoted inside its GEMM (no full-width upcast)
        vecs = model.eval_packed(jnp.atleast_1d(lams))
        if rhs_per_lam:
            return jax.vmap(lambda v, gi: packing.solve_packed_ref(
                v, gi.astype(ad), h, block, accum_dtype=ad))(vecs, g)
        return jax.vmap(lambda v: packing.solve_packed_ref(
            v, g.astype(ad), h, block, accum_dtype=ad))(vecs)

    def interp_factors(self, theta, lams, *, h, block, center=0.0):
        from . import picholesky
        model = picholesky.PiCholesky(
            theta=theta, center=jnp.asarray(center, theta.dtype),
            h=h, block=block)
        return self.unpack_tril(model.eval_packed(jnp.atleast_1d(lams)),
                                h, block)


@dataclasses.dataclass(frozen=True)
class PallasBackend(LinalgBackend):
    """Pallas kernel path: blocked Cholesky/trsm, tile pack/unpack, and the
    packed-domain kernels (packed trsm, fused Horner interp-solve/unpack).

    ``chol_block`` / ``trsm_block`` are the *kernel* tile sizes (MXU-sized
    on real TPUs, small in CPU interpret-mode tests).  The packed *layout*
    block is always carried by the data (``pack_tril(mat, block)`` /
    :class:`~repro.core.packing.PackedFactor.block`), never by the backend;
    :func:`resolve_backend` sizes all kernel tiles from one ``block=`` so
    the pack/unpack layout and the compute kernels stay consistent.
    """

    name: str = "pallas"
    chol_block: int = 256
    trsm_block: int = 256
    precision: PrecisionPolicy = PRESETS["native"]

    def _dtypes(self, input_dtype):
        """(compute, accum) static kernel params — None when inherited, so
        native-policy calls hit the exact pre-policy jit cache keys."""
        p = self.precision
        if p.is_native:
            return None, None
        return (str(p.compute_dtype(input_dtype)),
                str(p.accum_dtype(input_dtype)))

    def cholesky(self, a):
        from repro.kernels.chol_blocked import cholesky_blocked
        cd, ad = self._dtypes(a.dtype)
        return cholesky_blocked(a, block=self.chol_block,
                                compute_dtype=cd, accum_dtype=ad)

    def solve_lower(self, l, b, *, transpose=False):
        from repro.kernels.trsm import solve_lower_blocked
        cd, ad = self._dtypes(l.dtype)
        return solve_lower_blocked(l, b, self.trsm_block, transpose=transpose,
                                   compute_dtype=cd, accum_dtype=ad)

    def pack_tril(self, mat, block):
        from repro.kernels.tri_pack import pack_tril

        def one(m):
            return pack_tril(m, block)

        fn = one
        for _ in range(mat.ndim - 2):  # kernel is single-matrix; batch via vmap
            fn = jax.vmap(fn)
        return fn(mat)

    def unpack_tril(self, vec, h, block):
        from repro.kernels.tri_pack import unpack_tril

        def one(v):
            return unpack_tril(v, h, block)

        fn = one
        for _ in range(vec.ndim - 1):
            fn = jax.vmap(fn)
        return fn(vec)

    def solve_packed(self, pf, g):
        from repro.kernels.packed_trsm import solve_packed

        cd, ad = self._dtypes(pf.vec.dtype)
        fn = functools.partial(solve_packed, h=pf.h, block=pf.block,
                               compute_dtype=cd, accum_dtype=ad)
        for _ in range(pf.vec.ndim - 1):
            fn = jax.vmap(fn, in_axes=(0, None))
        return fn(pf.vec, g)

    def interp_solve(self, theta, lams, g, *, h, block, center=0.0,
                     rhs_per_lam=False):
        from repro.kernels.poly_interp import interp_solve
        cd, ad = self._dtypes(theta.dtype)
        return interp_solve(theta, jnp.atleast_1d(lams), g, h, block,
                            center=center, rhs_per_lam=rhs_per_lam,
                            compute_dtype=cd, accum_dtype=ad)

    def interp_factors(self, theta, lams, *, h, block, center=0.0):
        from repro.kernels.poly_interp import interp_factors
        return interp_factors(theta, jnp.atleast_1d(lams), h, block,
                              center=center)


class CountingBackend(LinalgBackend):
    """Delegating wrapper that counts calls to ``cholesky`` — the
    factorization-counting hook behind the warm-replay acceptance test and
    the warm-vs-cold bench record.

    Counts **trace-site** calls: under ``jit``/``vmap`` each traced call
    site increments once per trace, not once per batched execution, and a
    cached compiled sweep re-executes without counting.  That is exactly
    the right granularity for the cache contract — a warm replay whose
    computation graph contains *no* factorization keeps the counter at
    zero, while any cold path (however batched) moves it.  Keeps the inner
    backend's ``name`` so cache fingerprints are unaffected by counting.

    Counting is **stage-granular**: the pipelined sweep wraps each stage's
    trace in :meth:`stage`, so :attr:`by_stage` attributes every counted op
    (``cholesky`` and the λ-stage workhorses ``interp_solve`` /
    ``solve_packed``) to the stage whose computation graph contains it —
    e.g. a cold piCholesky sweep counts its factorizations under
    ``'fold_state'`` and only fused interpolant solves under
    ``'fold_errors'``; calls traced outside any scope land in
    ``'unstaged'``.  Like the flat counter, attribution happens at trace
    time: re-executing a compiled stage moves nothing.
    """

    def __init__(self, inner: LinalgBackend, _shared_counts: dict = None):
        self.inner = inner
        # stage label -> {op: trace-site count}; the single source of truth
        # (n_cholesky is derived), shareable across with_precision views
        self.by_stage: dict = {} if _shared_counts is None else _shared_counts
        self._stage: str | None = None

    @property
    def n_cholesky(self) -> int:
        return sum(rec.get("cholesky", 0) for rec in self.by_stage.values())

    @property
    def name(self) -> str:          # fingerprint-transparent
        return self.inner.name

    @property
    def precision(self) -> PrecisionPolicy:   # policy-transparent
        return self.inner.precision

    def with_precision(self, policy: PrecisionPolicy) -> "CountingBackend":
        """A view over the SAME counters with ``policy`` attached.

        Never mutates this instance (an engine attaching its policy must
        not retroactively change another engine sharing the backend), and
        never forks the counts (callers hold this object to read them —
        ops traced through the view keep landing here).
        """
        return CountingBackend(self.inner.with_precision(policy),
                               _shared_counts=self.by_stage)

    def reset(self) -> None:
        self.by_stage.clear()       # in place — views share this dict

    @contextlib.contextmanager
    def stage(self, label: str):
        """Attribute ops traced inside this scope to ``label`` (reentrant —
        nested scopes restore the outer label on exit)."""
        prev, self._stage = self._stage, label
        try:
            yield self
        finally:
            self._stage = prev

    def stage_count(self, label: str, op: str = "cholesky") -> int:
        return self.by_stage.get(label, {}).get(op, 0)

    def _count(self, op: str) -> None:
        rec = self.by_stage.setdefault(self._stage or "unstaged", {})
        rec[op] = rec.get(op, 0) + 1

    def cholesky(self, a):
        self._count("cholesky")
        return self.inner.cholesky(a)

    def solve_lower(self, l, b, *, transpose=False):
        return self.inner.solve_lower(l, b, transpose=transpose)

    def solve_from_factor(self, l, g):
        return self.inner.solve_from_factor(l, g)

    def pack_tril(self, mat, block):
        return self.inner.pack_tril(mat, block)

    def unpack_tril(self, vec, h, block):
        return self.inner.unpack_tril(vec, h, block)

    def solve_packed(self, pf, g):
        self._count("solve_packed")
        return self.inner.solve_packed(pf, g)

    def interp_solve(self, theta, lams, g, *, h, block, center=0.0,
                     rhs_per_lam=False):
        self._count("interp_solve")
        return self.inner.interp_solve(theta, lams, g, h=h, block=block,
                                       center=center,
                                       rhs_per_lam=rhs_per_lam)

    def interp_factors(self, theta, lams, *, h, block, center=0.0):
        return self.inner.interp_factors(theta, lams, h=h, block=block,
                                         center=center)


BackendLike = Union[None, str, LinalgBackend]


def retile_backend(bk: LinalgBackend, *, chol_block: int | None = None,
                   trsm_block: int | None = None) -> LinalgBackend:
    """``bk`` with the given Pallas kernel tile sizes (the autotuner's
    block dimension).  Backends without kernel tiles (reference) are
    returned unchanged; a :class:`CountingBackend` is re-wrapped around
    its retiled inner backend **sharing the same counters** — retiling
    must never fork the counts a test is holding a reference to."""
    if chol_block is None and trsm_block is None:
        return bk
    if isinstance(bk, CountingBackend):
        inner = retile_backend(bk.inner, chol_block=chol_block,
                               trsm_block=trsm_block)
        if inner is bk.inner:
            return bk
        return CountingBackend(inner, _shared_counts=bk.by_stage)
    if isinstance(bk, PallasBackend):
        return dataclasses.replace(
            bk, chol_block=chol_block or bk.chol_block,
            trsm_block=trsm_block or bk.trsm_block)
    return bk


def resolve_backend(backend: BackendLike = None, *,
                    block: int | None = None,
                    chol_block: int | None = None,
                    trsm_block: int | None = None,
                    precision: PrecisionLike = None) -> LinalgBackend:
    """Map a ``backend=`` argument to a concrete :class:`LinalgBackend`.

    ``block`` (when given) sizes **all** Pallas kernel tiles
    (``chol_block`` and ``trsm_block``) from the one value callers use as
    their packing-layout block — so small test problems get proportionate
    interpret-mode kernels and the pack/unpack layout never disagrees with
    the compute tiles.  ``chol_block`` / ``trsm_block`` override the tiles
    individually (the autotuner's chosen kernel tiles; they also re-tile a
    backend *instance* via :func:`retile_backend`).  The packed-domain
    kernels take their tile size from the data's own layout block
    (:class:`~repro.core.packing.PackedFactor`), which is consistent by
    construction.

    ``precision`` attaches a :class:`~repro.core.precision.PrecisionPolicy`
    (name, policy object, or ``None`` = the environment default).  A
    backend *instance* keeps its own policy unless ``precision`` is given
    explicitly — the engine resolves its policy from the backend it ends up
    with, so there is exactly one policy per pipeline.
    """
    if isinstance(backend, LinalgBackend):
        if precision is not None:
            pol = resolve_precision(precision)
            if pol != backend.precision:
                backend = backend.with_precision(pol)
        return retile_backend(backend, chol_block=chol_block,
                              trsm_block=trsm_block)
    pol = resolve_precision(precision)
    if backend is None or backend == "auto":
        backend = "pallas" if jax.default_backend() == "tpu" else "reference"
    if backend in ("reference", "ref", "jnp"):
        return ReferenceBackend(precision=pol)
    if backend == "pallas":
        cb = chol_block or block
        tb = trsm_block or block
        if cb is not None or tb is not None:
            return PallasBackend(chol_block=cb or 256, trsm_block=tb or 256,
                                 precision=pol)
        return PallasBackend(precision=pol)
    raise ValueError(f"unknown backend {backend!r}; expected 'auto', "
                     "'pallas', 'reference', or a LinalgBackend")
