"""Linear-algebra backend selection — the single ``backend=`` switch.

Every piCholesky hot spot (factorize, triangular solve, pack/unpack,
interpolant evaluation) has two implementations: the Pallas TPU kernels in
:mod:`repro.kernels` and the ``jnp.linalg`` reference path.  This module
packages each pair behind one object so callers (``solvers.py``,
``picholesky.py``, the :class:`~repro.core.engine.CVEngine`) thread a single
``backend=`` argument instead of per-function ``chol_fn`` plumbing.

Resolution rules for :func:`resolve_backend`:

* ``None`` / ``"auto"`` — Pallas kernels when the default jax backend is TPU
  (compiled) and the plain ``jnp.linalg`` path elsewhere.  On CPU the Pallas
  path would run in interpret mode, which is only useful for testing.
* ``"pallas"`` — force the kernel path (interpret mode off-TPU).
* ``"reference"`` / ``"ref"`` — force the ``jnp.linalg`` path.
* an existing :class:`LinalgBackend` — returned unchanged.

Kernel imports happen lazily inside the Pallas methods so importing
``repro.core`` never drags in the Pallas toolchain.
"""
from __future__ import annotations

import dataclasses
from typing import Union

import jax
import jax.numpy as jnp

__all__ = ["LinalgBackend", "ReferenceBackend", "PallasBackend",
           "resolve_backend", "BackendLike"]


class LinalgBackend:
    """Interface shared by both backends (duck-typed, no ABC machinery)."""

    name: str = "abstract"

    def cholesky(self, a: jax.Array) -> jax.Array:
        raise NotImplementedError

    def solve_lower(self, l: jax.Array, b: jax.Array, *,
                    transpose: bool = False) -> jax.Array:
        raise NotImplementedError

    def solve_from_factor(self, l: jax.Array, g: jax.Array) -> jax.Array:
        """L Lᵀ θ = g via forward + back substitution."""
        w = self.solve_lower(l, g)
        return self.solve_lower(l, w, transpose=True)

    def pack_tril(self, mat: jax.Array, block: int) -> jax.Array:
        raise NotImplementedError

    def unpack_tril(self, vec: jax.Array, h: int, block: int) -> jax.Array:
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class ReferenceBackend(LinalgBackend):
    """``jnp.linalg`` path — correct on every platform, XLA-fused."""

    name: str = "reference"

    def cholesky(self, a):
        return jnp.linalg.cholesky(a)

    def solve_lower(self, l, b, *, transpose=False):
        b2 = b[..., None] if b.ndim == l.ndim - 1 else b
        out = jax.lax.linalg.triangular_solve(
            l, b2.astype(l.dtype), left_side=True, lower=True,
            transpose_a=transpose)
        return out[..., 0] if b.ndim == l.ndim - 1 else out

    def pack_tril(self, mat, block):
        from . import packing
        return packing.pack_tril(mat, block)

    def unpack_tril(self, vec, h, block):
        from . import packing
        return packing.unpack_tril(vec, h, block)


@dataclasses.dataclass(frozen=True)
class PallasBackend(LinalgBackend):
    """Pallas kernel path: blocked Cholesky, blocked trsm, tile pack/unpack.

    ``chol_block`` / ``trsm_block`` are the kernel tile sizes (MXU-sized on
    real TPUs, small in CPU interpret-mode tests); ``pack_block`` must match
    the packing layout the caller uses elsewhere.
    """

    name: str = "pallas"
    chol_block: int = 256
    trsm_block: int = 256

    def cholesky(self, a):
        from repro.kernels.chol_blocked import cholesky_blocked
        return cholesky_blocked(a, block=self.chol_block)

    def solve_lower(self, l, b, *, transpose=False):
        from repro.kernels.trsm import solve_lower_blocked
        return solve_lower_blocked(l, b, self.trsm_block, transpose=transpose)

    def pack_tril(self, mat, block):
        from repro.kernels.tri_pack import pack_tril

        def one(m):
            return pack_tril(m, block)

        fn = one
        for _ in range(mat.ndim - 2):  # kernel is single-matrix; batch via vmap
            fn = jax.vmap(fn)
        return fn(mat)

    def unpack_tril(self, vec, h, block):
        from repro.kernels.tri_pack import unpack_tril

        def one(v):
            return unpack_tril(v, h, block)

        fn = one
        for _ in range(vec.ndim - 1):
            fn = jax.vmap(fn)
        return fn(vec)


BackendLike = Union[None, str, LinalgBackend]


def resolve_backend(backend: BackendLike = None, *,
                    block: int | None = None) -> LinalgBackend:
    """Map a ``backend=`` argument to a concrete :class:`LinalgBackend`.

    ``block`` (when given) sizes the Pallas kernel tiles — callers running
    small test problems pass their packing block so interpret-mode kernels
    stay proportionate.
    """
    if isinstance(backend, LinalgBackend):
        return backend
    if backend is None or backend == "auto":
        backend = "pallas" if jax.default_backend() == "tpu" else "reference"
    if backend in ("reference", "ref", "jnp"):
        return ReferenceBackend()
    if backend == "pallas":
        if block is not None:
            return PallasBackend(chol_block=block, trsm_block=block)
        return PallasBackend()
    raise ValueError(f"unknown backend {backend!r}; expected 'auto', "
                     "'pallas', 'reference', or a LinalgBackend")
