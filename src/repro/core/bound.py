"""Theorem 4.4 / 4.7 error-bound machinery (small-d, exact).

Computes the Taylor expansion of the Cholesky map C(A + λI), the remainder
magnitude R_[a,b], and the piCholesky uniform bound — used by tests to check
the bound actually dominates the observed error on random SPD matrices.

All operators act on vec(·) of full d×d matrices; M = [[C(A)]] is the
derivative of S: L ↦ LLᵀ restricted appropriately: vec(ΓLᵀ + LΓᵀ) =
(L⊗I)vec(Γ) + (I⊗L)vec(Γᵀ).  Following the paper we use the symmetrized
operator M = L⊗I + I⊗L acting on vec of the symmetric perturbation; its
pseudo-application to v_I reproduces DC(I) because I is symmetric.
Only intended for d ≲ 48 (M is d²×d²).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

__all__ = ["m_operator", "taylor_factor", "remainder_r", "picholesky_bound",
           "anchor_advisor"]


import functools


@functools.lru_cache(maxsize=None)
def _transpose_perm(d: int):
    import numpy as np
    t = np.zeros((d * d, d * d))
    for i in range(d):
        for j in range(d):
            t[i * d + j, j * d + i] = 1.0
    return t


def _kron_op(x: jax.Array) -> jax.Array:
    """Bracket operator: M vec_r(Γ) = vec_r(Γ Xᵀ + X Γᵀ) for ANY Γ.

    (Row-major vec: vec_r(ΓXᵀ) = (I⊗X)vec_r(Γ); vec_r(XΓᵀ) =
    (X⊗I)·T·vec_r(Γ) with T the transpose permutation.  The paper drops T by
    treating v_{Γᵀ} = v_Γ, which only holds for symmetric Γ — the Cholesky
    perturbation Γ is lower-triangular, so T is required for the Taylor
    factor to actually converge at third order.)
    """
    d = x.shape[0]
    eye = jnp.eye(d, dtype=x.dtype)
    t = jnp.asarray(_transpose_perm(d), x.dtype)
    return jnp.kron(eye, x) + jnp.kron(x, eye) @ t


def m_operator(a: jax.Array, s: jax.Array) -> jax.Array:
    """M_s = [[C(A + sI)]] (d²×d²), transpose-corrected."""
    d = a.shape[0]
    l = jnp.linalg.cholesky(a + s * jnp.eye(d, dtype=a.dtype))
    return _kron_op(l)


def _solve_lower_structured(m: jax.Array, v: jax.Array, d: int) -> jax.Array:
    """Solve M x = v for x = vec(Γ), Γ lower-triangular (DS_L is invertible
    only on the lower-triangular subspace — Thm 4.1). We restrict M's columns
    to the tril support and least-squares solve."""
    mask = jnp.tril(jnp.ones((d, d), bool)).reshape(-1)
    cols = jnp.where(mask)[0]
    m_sub = m[:, cols]
    x_sub, *_ = jnp.linalg.lstsq(m_sub, v)
    x = jnp.zeros(d * d, m.dtype).at[cols].set(x_sub)
    return x


def taylor_factor(a: jax.Array, lam: jax.Array, lam_c: jax.Array) -> jax.Array:
    """p_TS(λ; λ_c): second-order Taylor approximation of C(A+λI) (Thm 4.4)."""
    d = a.shape[0]
    eye = jnp.eye(d, dtype=a.dtype)
    l_c = jnp.linalg.cholesky(a + lam_c * eye)
    m = _kron_op(l_c)
    v_i = eye.reshape(-1)
    d1 = _solve_lower_structured(m, v_i, d)                       # M⁻¹ v_I
    e = _kron_op(d1.reshape(d, d))                                # E_c
    d2 = _solve_lower_structured(m, e @ d1, d)                    # M⁻¹ E M⁻¹ v_I
    dl = (lam - lam_c) * d1 - 0.5 * (lam - lam_c) ** 2 * d2
    return l_c + dl.reshape(d, d)


def remainder_r(a: jax.Array, lo: float, hi: float, n_grid: int = 9) -> jax.Array:
    """R_[lo,hi] (Thm 4.4): max over s of
    ‖M⁻¹E‖₂²‖M⁻¹v_I‖₂ + ‖M⁻¹‖₂‖M⁻¹E‖₂‖M⁻¹v_I‖₂²."""
    d = a.shape[0]
    eye = jnp.eye(d, dtype=a.dtype)
    v_i = eye.reshape(-1)

    def term(s):
        m = m_operator(a, s)
        m_inv = jnp.linalg.pinv(m)
        m_inv_vi = _solve_lower_structured(m, v_i, d)
        e = _kron_op(m_inv_vi.reshape(d, d))
        m_inv_e = m_inv @ e
        n_mie = jnp.linalg.norm(m_inv_e, 2)
        n_miv = jnp.linalg.norm(m_inv_vi)
        n_mi = jnp.linalg.norm(m_inv, 2)
        return n_mie**2 * n_miv + n_mi * n_mie * n_miv**2

    grid = jnp.linspace(lo, hi, n_grid)
    return jnp.max(jnp.stack([term(s) for s in grid]))


def anchor_advisor(a: jax.Array, anchors, n_grid: int = 5) -> dict:
    """Where is the interpolant weakest, and where should the next anchor go?

    Scores every adjacent-anchor interval ``[λ_i, λ_{i+1}]`` with the local
    Thm 4.4 error shape ``γ_i³ · R_[λ_i, λ_{i+1}]`` (γ_i the interval
    half-width; ``R`` from :func:`remainder_r` evaluated on ``n_grid``
    shifts inside the interval) and proposes the *log-midpoint* of the
    worst interval as the next anchor — anchors are log-spaced, so the
    log-midpoint is the split that halves the interval in the metric the
    grid lives in.

    ``a`` must be small (d ≲ 48 — ``M`` is d²×d²); callers with production-
    sized Hessians pass a leading principal submatrix as a probe (see
    :meth:`~repro.core.engine.CVEngine.advise_anchor`).

    Returns ``dict(intervals=[(lo, hi)...], scores=[...], worst=index,
    proposal=float)``.
    """
    import numpy as np

    arr = np.sort(np.asarray(anchors, dtype=float).ravel())
    if arr.shape[0] < 2:
        raise ValueError(f"need at least 2 anchors to score intervals, "
                         f"got {arr.shape[0]}")
    if np.any(arr <= 0):
        raise ValueError("anchor advisor works over log-λ: "
                         "anchors must be positive")
    intervals = list(zip(arr[:-1], arr[1:]))
    scores = []
    for lo, hi in intervals:
        gamma = 0.5 * (hi - lo)
        r = float(remainder_r(a, float(lo), float(hi), n_grid=n_grid))
        scores.append(gamma**3 * r)
    worst = int(np.argmax(scores))
    lo, hi = intervals[worst]
    proposal = float(10.0 ** (0.5 * (np.log10(lo) + np.log10(hi))))
    return dict(intervals=[(float(lo), float(hi)) for lo, hi in intervals],
                scores=[float(s) for s in scores], worst=worst,
                proposal=proposal)


def picholesky_bound(a: jax.Array, sample_lams: jax.Array, lam_c: float,
                     gamma: float) -> jax.Array:
    """RHS of Theorem 4.7 (uniform over [λ_c−γ, λ_c+γ])."""
    from .picholesky import vandermonde

    d = a.shape[0]
    big_d = d * (d + 1) / 2.0
    g = sample_lams.shape[0]
    w = float(jnp.max(jnp.abs(sample_lams - lam_c)))
    v = vandermonde(sample_lams, 2)
    v_pinv_norm = jnp.linalg.norm(jnp.linalg.pinv(v), 2)
    r = remainder_r(a, lam_c - gamma, lam_c + gamma)
    return (gamma**3 + jnp.sqrt(g * 1.0) * w**3 * (1 + gamma**2) * (lam_c + 1)
            * v_pinv_norm) * r / jnp.sqrt(big_d)
