"""k-fold cross-validation drivers (§6) — compatibility layer.

The six public ``cv_*`` drivers keep their original signatures but are now
thin wrappers over :class:`repro.core.engine.CVEngine`: one jitted, batched
fold × λ sweep per call instead of host-side Python loops.  All wrappers
accept two opt-in kwargs the legacy API did not have:

* ``backend=`` — ``'auto'`` | ``'pallas'`` | ``'reference'`` linear-algebra
  backend (see :mod:`repro.core.backends`),
* ``mesh=`` — ``None`` | ``'auto'`` | a 2-D (folds × lams) Mesh to shard
  the sweep (see :func:`repro.distributed.sharding.make_cv_mesh`).

``cv_multilevel_cholesky`` (MChol, §6.2) remains a host-side driver: its
binary search is decision-dependent, so there is no dense grid to batch.

The original host-loop implementations live on in
:mod:`repro.core.cv_host` as the benchmark baseline and test oracle.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from . import picholesky, solvers
from .backends import BackendLike
from .engine import CVEngine, make_strategy
from .folds import CVResult, FoldData, holdout_nrmse, make_folds

__all__ = [
    "FoldData", "make_folds", "holdout_nrmse", "CVResult",
    "cv_exact_cholesky", "cv_picholesky", "cv_picholesky_warmstart",
    "cv_multilevel_cholesky", "cv_svd", "cv_pinrmse",
]


# One engine (→ one jit cache) per distinct driver configuration, so
# repeated driver calls with the same shapes hit compiled code.  Bounded:
# callers that pass a fresh callable per call (new id() each time, e.g. a
# chol_fn lambda built in a loop) would otherwise grow this forever.
_ENGINES: dict = {}
_ENGINE_CACHE_MAX = 64


def _engine(name: str, backend: BackendLike, mesh, engine_block=None,
            precision=None, **params) -> CVEngine:
    """``engine_block`` sizes the Pallas kernel tiles (CVEngine.block);
    a strategy-level ``block`` (packing layout) goes in ``params``."""
    def hashable(v):
        if isinstance(v, (jax.Array, np.ndarray)):
            return np.asarray(v).tobytes()
        return v if v.__hash__ is not None else id(v)

    key = (name, backend if isinstance(backend, str) or backend is None
           else id(backend),
           mesh if mesh in (None, "auto") else id(mesh), engine_block,
           hashable(precision) if precision is not None else None,
           tuple((k, hashable(v)) for k, v in sorted(params.items())))
    if key not in _ENGINES:
        while len(_ENGINES) >= _ENGINE_CACHE_MAX:
            _ENGINES.pop(next(iter(_ENGINES)))
        _ENGINES[key] = CVEngine(make_strategy(name, **params),
                                 backend=backend, mesh=mesh,
                                 block=engine_block, precision=precision)
    return _ENGINES[key]


def _fold_train_stats(folds: FoldData, f: jax.Array):
    return folds.hess - folds.fold_hess[f], folds.grad - folds.fold_grad[f]


def cv_exact_cholesky(folds: FoldData, lams: jax.Array, chol_fn=None, *,
                      backend: BackendLike = "reference",
                      mesh=None, precision=None) -> CVResult:
    """Chol baseline: k·q exact factorizations."""
    eng = _engine("exact", backend, mesh, precision=precision,
                  chol_fn=chol_fn)
    return eng.run(folds, lams)


def cv_picholesky(
    folds: FoldData,
    lams: jax.Array,
    g: int = 4,
    degree: int = 2,
    *,
    block: int = 128,
    basis: str = "monomial",
    chol_fn=None,
    backend: BackendLike = "reference",
    mesh=None,
    precision=None,
) -> CVResult:
    """piCholesky CV: k·g exact factorizations + interpolation for the rest."""
    eng = _engine("picholesky", backend, mesh, engine_block=block,
                  precision=precision, g=g,
                  degree=degree, block=block, basis=basis, chol_fn=chol_fn)
    result = eng.run(folds, lams)
    result.extras["sample_lams"] = np.asarray(
        picholesky.choose_sample_lambdas(float(lams[0]), float(lams[-1]), g))
    return result


def cv_picholesky_warmstart(
    folds: FoldData,
    lams: jax.Array,
    g_first: int = 4,
    g_rest: int = 2,
    degree: int = 2,
    *,
    mu: float = 1e-6,
    block: int = 128,
    chol_fn=None,
    backend: BackendLike = "reference",
    mesh=None,
) -> CVResult:
    """piCholesky with cross-fold warm-starting (the paper's §7 future work).

    An anchor fit on fold 0 (``g_first`` exact factorizations) provides a
    coefficient prior; every fold then refits only the *residual* from
    ``g_rest`` fresh factorizations with a scale-relative damping ``mu``
    (see :class:`repro.core.engine.PiCholeskyWarmstart` for the exact
    objective — ``mu`` is relative, not an absolute Tikhonov weight).

    Total factorizations: g_first + k·g_rest  (vs k·g for plain PIChol).
    """
    eng = _engine("picholesky_warmstart", backend, mesh, engine_block=block,
                  g_first=g_first, g_rest=g_rest, degree=degree, mu=mu,
                  block=block, chol_fn=chol_fn)
    result = eng.run(folds, lams)
    result.extras["sample_lams"] = np.asarray(
        picholesky.choose_sample_lambdas(float(lams[0]), float(lams[-1]),
                                         g_first))
    return result


def cv_multilevel_cholesky(
    folds: FoldData,
    c: float,
    s: float = 1.5,
    s0: float = 0.0025,
    chol_fn=None,
) -> CVResult:
    """MChol (§6.2): binary-search in log₁₀(λ) with exact factorizations.

    Starts from range [10^(c−s), 10^(c+s)]; each level evaluates the three
    shifts 10^{c−s},10^c,10^{c+s}, recenters on the argmin, halves s.
    (Host-side by construction: each level's shifts depend on the previous
    level's argmin, so there is no dense grid for the engine to batch.)
    """
    k = folds.fold_hess.shape[0]
    visited_lams, visited_errs, n_chol = [], [], 0

    def mean_err(lam: float) -> float:
        nonlocal n_chol
        errs = []
        for f in range(k):
            h_tr, g_tr = _fold_train_stats(folds, jnp.asarray(f))
            theta = solvers.solve_cholesky(h_tr, g_tr, jnp.asarray(lam, h_tr.dtype), chol_fn)
            errs.append(holdout_nrmse(theta, folds.x_folds[f], folds.y_folds[f]))
        n_chol += k
        return float(jnp.stack(errs).mean())

    cache: dict[float, float] = {}
    while s > s0:
        cands = [10.0 ** (c - s), 10.0 ** c, 10.0 ** (c + s)]
        errs = []
        for lam in cands:
            if lam not in cache:
                cache[lam] = mean_err(lam)
                visited_lams.append(lam)
                visited_errs.append(cache[lam])
            errs.append(cache[lam])
        c = float(np.log10(cands[int(np.argmin(errs))]))
        s /= 2.0
    order = np.argsort(visited_lams)
    return CVResult.from_errors(
        np.asarray(visited_lams)[order], np.asarray(visited_errs)[order], n_chol)


def cv_svd(folds: FoldData, lams: jax.Array, mode: str = "full",
           k_trunc: int = 0, key=None, *,
           backend: BackendLike = "reference", mesh=None) -> CVResult:
    """SVD / t-SVD / r-SVD baselines operating on the raw design matrix."""
    eng = _engine("svd", backend, mesh, mode=mode, k_trunc=k_trunc, key=key)
    return eng.run(folds, lams)


def cv_pinrmse(folds: FoldData, lams: jax.Array, g: int = 4, degree: int = 2,
               chol_fn=None, *, backend: BackendLike = "reference",
               mesh=None) -> CVResult:
    """PINRMSE straw-man (§6.5): interpolate the hold-out-error curve itself
    from g exact evaluations — shown by the paper to select wrong λ's."""
    eng = _engine("pinrmse", backend, mesh, g=g, degree=degree,
                  chol_fn=chol_fn)
    result = eng.run(folds, lams)
    result.extras["sample_lams"] = np.asarray(
        picholesky.choose_sample_lambdas(float(lams[0]), float(lams[-1]), g))
    return result
