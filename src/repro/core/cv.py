"""k-fold cross-validation drivers (§6): exact Chol sweep, piCholesky,
Multi-level Cholesky, SVD family, and the PINRMSE straw-man.

The fold trick: with ``H_f = X_fᵀX_f`` per fold, the training Hessian of
fold f is ``H − H_f`` (one pass over the data, §1's O(nd²) paid once).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import packing, picholesky, solvers

__all__ = [
    "FoldData", "make_folds", "holdout_nrmse", "CVResult",
    "cv_exact_cholesky", "cv_picholesky", "cv_picholesky_warmstart",
    "cv_multilevel_cholesky", "cv_svd", "cv_pinrmse",
]


class FoldData(NamedTuple):
    """Per-fold sufficient statistics + raw held-out blocks."""
    hess: jax.Array        # (h, h) total XᵀX
    grad: jax.Array        # (h,)   total Xᵀy
    fold_hess: jax.Array   # (k, h, h)
    fold_grad: jax.Array   # (k, h)
    x_folds: jax.Array     # (k, n_f, h)
    y_folds: jax.Array     # (k, n_f)


def make_folds(x: jax.Array, y: jax.Array, k: int) -> FoldData:
    n = x.shape[0]
    n_f = n // k
    x = x[: n_f * k].reshape(k, n_f, -1)
    y = y[: n_f * k].reshape(k, n_f)
    fold_hess = jnp.einsum("kni,knj->kij", x, x)
    fold_grad = jnp.einsum("kni,kn->ki", x, y)
    return FoldData(fold_hess.sum(0), fold_grad.sum(0), fold_hess, fold_grad, x, y)


def holdout_nrmse(theta: jax.Array, x_hold: jax.Array, y_hold: jax.Array) -> jax.Array:
    """Normalized RMSE on the held-out fold (paper's hold-out error)."""
    pred = x_hold @ theta
    mse = jnp.mean((pred - y_hold) ** 2)
    denom = jnp.std(y_hold) + 1e-30
    return jnp.sqrt(mse) / denom


@dataclasses.dataclass
class CVResult:
    lams: np.ndarray           # dense candidate grid
    errors: np.ndarray         # (q,) mean hold-out error across folds
    best_lam: float
    best_error: float
    n_exact_chol: int          # factorizations actually performed
    extras: dict = dataclasses.field(default_factory=dict)

    @staticmethod
    def from_errors(lams, errors, n_exact, **extras) -> "CVResult":
        lams = np.asarray(lams)
        errors = np.asarray(errors)
        i = int(np.argmin(errors))
        return CVResult(lams, errors, float(lams[i]), float(errors[i]),
                        n_exact, dict(extras))


def _fold_train_stats(folds: FoldData, f: jax.Array):
    return folds.hess - folds.fold_hess[f], folds.grad - folds.fold_grad[f]


def cv_exact_cholesky(folds: FoldData, lams: jax.Array, chol_fn=None) -> CVResult:
    """Chol baseline: k·q exact factorizations."""
    k = folds.fold_hess.shape[0]

    def per_fold(f):
        h_tr, g_tr = _fold_train_stats(folds, f)
        thetas = solvers.solve_cholesky_sweep(h_tr, g_tr, lams, chol_fn)
        return jax.vmap(lambda t: holdout_nrmse(t, folds.x_folds[f], folds.y_folds[f]))(thetas)

    errs = jax.vmap(per_fold)(jnp.arange(k))  # (k, q)
    return CVResult.from_errors(lams, errs.mean(0), k * len(lams))


def cv_picholesky(
    folds: FoldData,
    lams: jax.Array,
    g: int = 4,
    degree: int = 2,
    *,
    block: int = 128,
    basis: str = "monomial",
    chol_fn=None,
) -> CVResult:
    """piCholesky CV: k·g exact factorizations + interpolation for the rest."""
    k = folds.fold_hess.shape[0]
    sample = picholesky.choose_sample_lambdas(float(lams[0]), float(lams[-1]), g)

    def per_fold(f):
        h_tr, g_tr = _fold_train_stats(folds, f)
        model = picholesky.fit(h_tr, sample, degree, block=block, basis=basis,
                               chol_fn=chol_fn)
        l_interp = model.eval_factor(lams)  # (q, h, h)
        thetas = jax.vmap(lambda l: solvers.solve_from_factor(l, g_tr))(l_interp)
        return jax.vmap(lambda t: holdout_nrmse(t, folds.x_folds[f], folds.y_folds[f]))(thetas)

    errs = jax.vmap(per_fold)(jnp.arange(k))
    return CVResult.from_errors(lams, errs.mean(0), k * g,
                                sample_lams=np.asarray(sample))


def cv_picholesky_warmstart(
    folds: FoldData,
    lams: jax.Array,
    g_first: int = 4,
    g_rest: int = 2,
    degree: int = 2,
    *,
    mu: float = 1.0,
    block: int = 128,
    chol_fn=None,
) -> CVResult:
    """piCholesky with cross-fold warm-starting (the paper's §7 future work).

    Fold 0 fits Θ⁰ from ``g_first`` exact factorizations.  Later folds'
    Hessians differ only by one fold block (H − H_f), so their coefficient
    matrices are close to Θ⁰: they are fit from just ``g_rest`` samples with
    a ridge pull toward Θ⁰:

        Θ_f = (VᵀV + μI)⁻¹ (VᵀT_f + μΘ⁰)

    Total factorizations: g_first + (k−1)·g_rest  (vs k·g for plain PIChol).
    """
    k = folds.fold_hess.shape[0]
    chol = chol_fn or jnp.linalg.cholesky
    sample_full = picholesky.choose_sample_lambdas(float(lams[0]),
                                                   float(lams[-1]), g_first)
    # anchor fold: full fit + its λ* locates the region that matters
    h0, g0 = _fold_train_stats(folds, jnp.asarray(0))
    base = picholesky.fit(h0, sample_full, degree, block=block, chol_fn=chol)
    th0 = jax.vmap(lambda l: solvers.solve_from_factor(l, g0)
                   )(base.eval_factor(lams))
    e0 = jax.vmap(lambda t: holdout_nrmse(t, folds.x_folds[0],
                                          folds.y_folds[0]))(th0)
    lam_anchor = float(lams[int(np.argmin(np.asarray(e0)))])

    # refresh points for the remaining folds, clustered ±1 decade around the
    # anchor optimum (per Thm 4.7, accuracy is only needed near λ*)
    sample_rest = jnp.logspace(np.log10(lam_anchor) - 1,
                               np.log10(lam_anchor) + 1,
                               max(g_rest, 1)).astype(lams.dtype)
    v = picholesky.vandermonde(sample_rest, degree).astype(base.theta.dtype)
    vtv = v.T @ v
    eye = jnp.eye(degree + 1, dtype=v.dtype)

    def fold_errors(f):
        h_tr, g_tr = _fold_train_stats(folds, f)
        if int(f) == 0:
            return e0
        h = h_tr.shape[-1]
        ident = jnp.eye(h, dtype=h_tr.dtype)
        factors = jax.vmap(lambda lam: chol(h_tr + lam * ident))(sample_rest)
        t = packing.pack_tril(factors, block)
        theta = jnp.linalg.solve(vtv + mu * eye,
                                 v.T @ t + mu * base.theta)
        model = picholesky.PiCholesky(theta=theta, center=base.center,
                                      h=base.h, block=block)
        l_interp = model.eval_factor(lams)
        thetas = jax.vmap(lambda l: solvers.solve_from_factor(l, g_tr))(l_interp)
        return jax.vmap(lambda th: holdout_nrmse(
            th, folds.x_folds[f], folds.y_folds[f]))(thetas)

    errs = jnp.stack([fold_errors(jnp.asarray(f)) for f in range(k)])
    n_chol = g_first + (k - 1) * max(g_rest, 1)
    return CVResult.from_errors(lams, errs.mean(0), n_chol,
                                sample_lams=np.asarray(sample_full))


def cv_multilevel_cholesky(
    folds: FoldData,
    c: float,
    s: float = 1.5,
    s0: float = 0.0025,
    chol_fn=None,
) -> CVResult:
    """MChol (§6.2): binary-search in log₁₀(λ) with exact factorizations.

    Starts from range [10^(c−s), 10^(c+s)]; each level evaluates the three
    shifts 10^{c−s},10^c,10^{c+s}, recenters on the argmin, halves s.
    """
    k = folds.fold_hess.shape[0]
    visited_lams, visited_errs, n_chol = [], [], 0

    def mean_err(lam: float) -> float:
        nonlocal n_chol
        errs = []
        for f in range(k):
            h_tr, g_tr = _fold_train_stats(folds, jnp.asarray(f))
            theta = solvers.solve_cholesky(h_tr, g_tr, jnp.asarray(lam, h_tr.dtype), chol_fn)
            errs.append(holdout_nrmse(theta, folds.x_folds[f], folds.y_folds[f]))
        n_chol += k
        return float(jnp.stack(errs).mean())

    cache: dict[float, float] = {}
    while s > s0:
        cands = [10.0 ** (c - s), 10.0 ** c, 10.0 ** (c + s)]
        errs = []
        for lam in cands:
            if lam not in cache:
                cache[lam] = mean_err(lam)
                visited_lams.append(lam)
                visited_errs.append(cache[lam])
            errs.append(cache[lam])
        c = float(np.log10(cands[int(np.argmin(errs))]))
        s /= 2.0
    order = np.argsort(visited_lams)
    return CVResult.from_errors(
        np.asarray(visited_lams)[order], np.asarray(visited_errs)[order], n_chol)


def cv_svd(folds: FoldData, lams: jax.Array, mode: str = "full",
           k_trunc: int = 0, key=None) -> CVResult:
    """SVD / t-SVD / r-SVD baselines operating on the raw design matrix."""
    k = folds.fold_hess.shape[0]
    n_f = folds.x_folds.shape[1]
    idx = jnp.arange(k)

    def per_fold(f):
        mask = idx != f
        x_tr = folds.x_folds[mask.nonzero(size=k - 1)[0]].reshape((k - 1) * n_f, -1)
        y_tr = folds.y_folds[mask.nonzero(size=k - 1)[0]].reshape(-1)
        if mode == "full":
            thetas = solvers.solve_svd(x_tr, y_tr, lams)
        elif mode == "truncated":
            thetas = solvers.solve_truncated_svd(x_tr, y_tr, lams, k_trunc)
        else:
            thetas = solvers.solve_randomized_svd(x_tr, y_tr, lams, k_trunc, key)
        return jax.vmap(lambda t: holdout_nrmse(t, folds.x_folds[f], folds.y_folds[f]))(thetas)

    errs = jnp.stack([per_fold(f) for f in range(k)])
    return CVResult.from_errors(lams, errs.mean(0), 0)


def cv_pinrmse(folds: FoldData, lams: jax.Array, g: int = 4, degree: int = 2,
               chol_fn=None) -> CVResult:
    """PINRMSE straw-man (§6.5): interpolate the hold-out-error curve itself
    from g exact evaluations — shown by the paper to select wrong λ's."""
    sample = picholesky.choose_sample_lambdas(float(lams[0]), float(lams[-1]), g)
    exact = cv_exact_cholesky(folds, sample, chol_fn)
    v = picholesky.vandermonde(sample, degree).astype(jnp.float64
                                                      if jax.config.jax_enable_x64 else jnp.float32)
    t = jnp.asarray(exact.errors, v.dtype)
    theta = jnp.linalg.solve(v.T @ v, v.T @ t)
    dense_v = picholesky.vandermonde(lams, degree).astype(v.dtype)
    errs = dense_v @ theta
    k = folds.fold_hess.shape[0]
    return CVResult.from_errors(lams, errs, k * g, sample_lams=np.asarray(sample))
