"""Host-loop CV drivers — the pre-engine reference implementations.

These are the original eager drivers (per-fold work vmapped, but traced
op-by-op on every call — no jit, no sharding, no backend switch), kept
verbatim for two jobs the engine cannot do for itself:

* **test oracle** — ``tests/test_engine.py`` checks every
  :class:`~repro.core.engine.CVEngine` strategy against these independent
  implementations (same math, different execution structure), so a bug in
  the batching/sharding machinery cannot hide behind "both paths share the
  code";
* **benchmark baseline** — ``benchmarks/bench_table3_timing.py`` reports
  engine vs host-loop wall time; the gap is the paper's §5 "exploit the
  architecture" claim made measurable.

Do not add features here; new work goes through the engine strategies.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from . import picholesky, solvers
from .folds import CVResult, FoldData, holdout_nrmse

__all__ = ["host_cv_exact_cholesky", "host_cv_picholesky", "host_cv_svd",
           "host_cv_pinrmse"]


def _fold_train_stats(folds: FoldData, f: jax.Array):
    return folds.hess - folds.fold_hess[f], folds.grad - folds.fold_grad[f]


def host_cv_exact_cholesky(folds: FoldData, lams: jax.Array,
                           chol_fn=None) -> CVResult:
    """Chol baseline: k·q exact factorizations."""
    k = folds.fold_hess.shape[0]

    def per_fold(f):
        h_tr, g_tr = _fold_train_stats(folds, f)
        thetas = solvers.solve_cholesky_sweep(h_tr, g_tr, lams, chol_fn)
        return jax.vmap(lambda t: holdout_nrmse(
            t, folds.x_folds[f], folds.y_folds[f]))(thetas)

    errs = jax.vmap(per_fold)(jnp.arange(k))  # (k, q)
    return CVResult.from_errors(lams, errs.mean(0), k * len(lams))


def host_cv_picholesky(folds: FoldData, lams: jax.Array, g: int = 4,
                       degree: int = 2, *, block: int = 128,
                       basis: str = "monomial", chol_fn=None) -> CVResult:
    """piCholesky CV: k·g exact factorizations + interpolation for the rest."""
    k = folds.fold_hess.shape[0]
    sample = picholesky.choose_sample_lambdas(float(lams[0]), float(lams[-1]), g)

    def per_fold(f):
        h_tr, g_tr = _fold_train_stats(folds, f)
        model = picholesky.fit(h_tr, sample, degree, block=block, basis=basis,
                               chol_fn=chol_fn)
        l_interp = model.eval_factor(lams)  # (q, h, h)
        thetas = jax.vmap(lambda l: solvers.solve_from_factor(l, g_tr))(l_interp)
        return jax.vmap(lambda t: holdout_nrmse(
            t, folds.x_folds[f], folds.y_folds[f]))(thetas)

    errs = jax.vmap(per_fold)(jnp.arange(k))
    return CVResult.from_errors(lams, errs.mean(0), k * g,
                                sample_lams=np.asarray(sample))


def host_cv_svd(folds: FoldData, lams: jax.Array, mode: str = "full",
                k_trunc: int = 0, key=None) -> CVResult:
    """SVD / t-SVD / r-SVD baselines operating on the raw design matrix."""
    k = folds.fold_hess.shape[0]
    n_f = folds.x_folds.shape[1]
    idx = jnp.arange(k)

    def per_fold(f):
        mask = idx != f
        x_tr = folds.x_folds[mask.nonzero(size=k - 1)[0]].reshape((k - 1) * n_f, -1)
        y_tr = folds.y_folds[mask.nonzero(size=k - 1)[0]].reshape(-1)
        if mode == "full":
            thetas = solvers.solve_svd(x_tr, y_tr, lams)
        elif mode == "truncated":
            thetas = solvers.solve_truncated_svd(x_tr, y_tr, lams, k_trunc)
        else:
            thetas = solvers.solve_randomized_svd(x_tr, y_tr, lams, k_trunc, key)
        return jax.vmap(lambda t: holdout_nrmse(
            t, folds.x_folds[f], folds.y_folds[f]))(thetas)

    errs = jnp.stack([per_fold(f) for f in range(k)])
    return CVResult.from_errors(lams, errs.mean(0), 0)


def host_cv_pinrmse(folds: FoldData, lams: jax.Array, g: int = 4,
                    degree: int = 2, chol_fn=None) -> CVResult:
    """PINRMSE straw-man (§6.5): interpolate the hold-out-error curve itself
    from g exact evaluations — shown by the paper to select wrong λ's."""
    sample = picholesky.choose_sample_lambdas(float(lams[0]), float(lams[-1]), g)
    exact = host_cv_exact_cholesky(folds, sample, chol_fn)
    v = picholesky.vandermonde(sample, degree).astype(
        jnp.float64 if jax.config.jax_enable_x64 else jnp.float32)
    t = jnp.asarray(exact.errors, v.dtype)
    theta = jnp.linalg.solve(v.T @ v, v.T @ t)
    dense_v = picholesky.vandermonde(lams, degree).astype(v.dtype)
    errs = dense_v @ theta
    k = folds.fold_hess.shape[0]
    return CVResult.from_errors(lams, errs, k * g, sample_lams=np.asarray(sample))
