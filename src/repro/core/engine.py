"""Unified CV engine: one jitted, batched, sharded fold × λ sweep.

The paper's experiment is a dense grid of independent ridge solves — k folds
by q regularizers.  The legacy drivers in :mod:`repro.core.cv` walked that
grid with host-side Python loops (one trace per fold, NumPy syncs mid-sweep).
This module runs the whole grid as **one jitted computation**:

* folds are batched with ``vmap`` (all per-fold factorizations/fits are a
  single batched kernel launch),
* with a mesh, the grid is laid over a 2-D ``(folds × lams)`` device mesh
  via ``shard_map`` — fold Hessians shard over the fold axis, the λ grid
  over the λ axis (padded to divisibility, see
  :mod:`repro.distributed.sharding`),
* the per-fold training Hessians are donated into the sweep so the largest
  intermediate (k × h × h) never holds two copies in HBM,
* the λ axis is **streamed**: each device's λ shard is processed in
  fixed-size chunks under an outer ``lax.map`` (``lam_chunk=``, default
  VMEM-sized), and the interpolant strategies solve each chunk in the
  tile-packed domain (:class:`~repro.core.packing.PackedFactor` currency,
  fused Horner + packed trsm) — peak sweep memory is O(chunk · P),
  independent of the grid size q,
* all linear algebra goes through one ``backend=`` switch
  (:mod:`repro.core.backends`): Pallas kernels on TPU, ``jnp.linalg``
  elsewhere,
* with a ``cache=`` (:mod:`repro.core.factor_cache`), repeated sweeps over
  overlapping λ grids take the **warm-replay path**: the fitted per-fold Θ
  is content-fingerprinted and reused, skipping the heavy ``fold_state``
  stage entirely — a warm sweep performs *zero* Cholesky factorizations
  and replays any grid over the cached anchor range through the fused
  ``interp_solve`` chunked stream,
* the same seam also drives the **pipelined staged sweep**
  (:meth:`CVEngine.sweep_async` / :meth:`CVEngine.run_async`): per-fold
  ``fold_state`` stages dispatch without blocking (double-buffered donated
  Hessian slices), the λ grid streams through one jitted chunk stage, each
  completed chunk is yielded as a partial hold-out curve, and the
  early-stop search (``stop_tol=``) terminates the stream once the running
  minimum stops improving — the hold-out curve is evaluated only as far as
  selection needs it.

Algorithms plug in through the small :class:`CVStrategy` protocol; the five
paper algorithms (`exact`, `picholesky`, `picholesky_warmstart`, `svd`,
`pinrmse`) ship as built-ins.  Adding a strategy means implementing at most
three methods:

``prepare(x_folds, y_folds, h_tr, g_tr, lams, bk)``
    Replicated setup (runs identically on every device): pick sample λs,
    fit an anchor model, stash training data a fold needs from *other*
    folds.  Returns an arbitrary pytree ``aux`` (default ``()``).
``fold_state(f_idx, h_tr_f, g_tr_f, aux, bk)``
    The heavy λ-independent per-fold stage (factorizations, SVDs, fits).
    Runs under ``vmap`` over folds, sharded over the fold mesh axis.
``fold_errors(state, f_idx, h_tr_f, g_tr_f, x_f, y_f, lams, aux, bk)``
    The per-(fold, λ) stage: evaluate/solve/score on a (possibly λ-sharded)
    grid chunk.  Returns the (q_local,) hold-out error curve.

``MChol`` (§6.2) stays a host-side driver in :mod:`repro.core.cv`: its
binary search is decision-dependent and factorizes three shifts per level,
so there is no dense grid to batch.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import (Any, Callable, Iterator, Optional, Protocol, Union,
                    runtime_checkable)

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.distributed import sharding as shardlib

from . import factor_cache as cachelib
from . import packing, picholesky, solvers
from . import sketch as sketchlib
from .backends import BackendLike, LinalgBackend, resolve_backend
from .folds import CVResult, FoldData, holdout_nrmse
from .precision import PrecisionLike

__all__ = [
    "CVStrategy", "CVEngine", "SweepChunk", "make_strategy", "STRATEGIES",
    "ExactCholesky", "PiCholeskyStrategy", "PiCholeskySketched",
    "PiCholeskyWarmstart", "SVDStrategy", "PinrmseStrategy",
    "LowRankStrategy",
]


def _sample_grid(lams: jax.Array, g: int) -> jax.Array:
    """g log-spaced sample shifts spanning the dense grid (traced-safe).

    Same nodes as the host drivers and the ``extras['sample_lams']`` the
    wrappers report — one definition, so they cannot drift apart.
    """
    return picholesky.choose_sample_lambdas(lams[0], lams[-1], g
                                            ).astype(lams.dtype)


def _errors_from_thetas(thetas: jax.Array, x_f: jax.Array,
                        y_f: jax.Array) -> jax.Array:
    return jax.vmap(lambda t: holdout_nrmse(t, x_f, y_f))(thetas)


# ------------------------------------------------------------------ protocol


@runtime_checkable
class CVStrategy(Protocol):
    name: str

    def n_exact_chol(self, k: int, q: int) -> int: ...

    def prepare(self, x_folds, y_folds, h_tr, g_tr, lams,
                bk: LinalgBackend) -> Any: ...

    def fold_state(self, f_idx, h_tr_f, g_tr_f, aux,
                   bk: LinalgBackend) -> Any: ...

    def fold_errors(self, state, f_idx, h_tr_f, g_tr_f, x_f, y_f, lams, aux,
                    bk: LinalgBackend) -> jax.Array: ...


class StrategyBase:
    """Default no-op prepare/fold_state for strategies that don't need them."""

    #: True when ``fold_state`` reads the per-fold train Hessian — the
    #: pipelined sweep donates each fold's Hessian slice into the per-fold
    #: state stage only then (donating an unread buffer is an XLA warning,
    #: not a win).
    state_uses_hessian: bool = False

    #: True when ``fold_state`` is a pure PER-FOLD function of
    #: (h_tr_f, g_tr_f, anchors, params, backend) — independent of the fold
    #: index and of every *other* fold — AND ``prepare`` depends only on
    #: the λ grid.  That is what lets :meth:`CVEngine.run_batch` stack
    #: several tenants' fold axes into ONE ``fold_state`` dispatch and
    #: slice the batched state back per problem.  Strategies coupling
    #: folds (warmstart's fold-0 anchor fit) or reading the fold index
    #: must leave this False.
    batchable_state: bool = False

    def prepare(self, x_folds, y_folds, h_tr, g_tr, lams, bk):
        return ()

    def fold_state(self, f_idx, h_tr_f, g_tr_f, aux, bk):
        return ()

    def cache_meta(self, lams) -> Optional[dict]:
        """Warm-replay cache support (None = not cacheable).

        Cacheable strategies return ``dict(anchors=<(g,) λ grid the fit
        factorizes at>, params=<static fit parameters>)`` — the λ-dependent
        and static halves of the :class:`~repro.core.factor_cache.CacheKey`.
        Contract for a non-None return: ``fold_state`` is a pure function
        of (per-fold train Hessian, anchors, params, backend), and
        ``fold_errors`` must not read ``aux`` (a replayed sweep runs with
        ``aux=()``, skipping ``prepare`` entirely).
        """
        return None


# ---------------------------------------------------------------- strategies


@dataclasses.dataclass(frozen=True, eq=False)
class ExactCholesky(StrategyBase):
    """Chol baseline: factorize at every (fold, λ) — k·q factorizations.

    All the work sits in ``fold_errors`` so it parallelizes over *both* mesh
    axes: each device factorizes only its own (fold, λ) sub-grid.
    """

    chol_fn: Optional[Callable] = None
    name: str = "exact"

    def n_exact_chol(self, k, q):
        return k * q

    def fold_errors(self, state, f_idx, h_tr_f, g_tr_f, x_f, y_f, lams, aux, bk):
        thetas = solvers.solve_cholesky_sweep(h_tr_f, g_tr_f, lams,
                                              self.chol_fn, bk)
        return _errors_from_thetas(thetas, x_f, y_f)


class _InterpolantErrors:
    """Shared λ-stage for the piCholesky family: fused interpolant
    evaluation + substitution at the local λ chunk, entirely in the packed
    domain — no (q_loc, h, h) factor batch is ever materialized (the
    pre-packed-pipeline eval_factor → dense-trsm route survives only as the
    ``PiCholesky.eval_factor`` debug escape hatch).

    Under a refining precision policy (``bf16_refined``) each chunk's
    low-precision solves are corrected by
    :func:`~repro.core.picholesky.refine_solutions` — an fp32 residual
    sweep per λ chunk, riding inside the same O(chunk · P) budget."""

    def fold_errors(self, state, f_idx, h_tr_f, g_tr_f, x_f, y_f, lams, aux, bk):
        thetas = state.solve(lams, g_tr_f, backend=bk)       # (q_loc, h)
        if bk.precision.refine_iters:
            thetas = picholesky.refine_solutions(state, h_tr_f, g_tr_f,
                                                 lams, thetas, backend=bk)
        return _errors_from_thetas(thetas, x_f, y_f)


@dataclasses.dataclass(frozen=True, eq=False)
class PiCholeskyStrategy(_InterpolantErrors, StrategyBase):
    """Algorithm 1 per fold: g exact factorizations + a polynomial fit;
    the dense sweep reads the interpolant only."""

    g: int = 4
    degree: int = 2
    block: int = 128
    basis: str = "monomial"
    chol_fn: Optional[Callable] = None
    name: str = "picholesky"
    state_uses_hessian = True
    batchable_state = True

    def n_exact_chol(self, k, q):
        return k * self.g

    def prepare(self, x_folds, y_folds, h_tr, g_tr, lams, bk):
        return _sample_grid(lams, self.g)

    def fold_state(self, f_idx, h_tr_f, g_tr_f, aux, bk):
        return picholesky.fit(h_tr_f, aux, self.degree, block=self.block,
                              basis=self.basis, chol_fn=self.chol_fn,
                              backend=bk)

    def cache_meta(self, lams):
        if self.chol_fn is not None:     # opaque override — unkeyable
            return None
        anchors = _sample_grid(jnp.asarray(lams), self.g)
        return dict(anchors=anchors,
                    params=dict(strategy=self.name, g=self.g,
                                degree=self.degree, block=self.block,
                                basis=self.basis))

    def fold_state_and_anchors(self, f_idx, h_tr_f, g_tr_f, aux, bk):
        """``fold_state`` that also surfaces the tile-packed anchor factors
        (g, P) so the engine can cache them — a later fit with a different
        degree/basis over the same anchors then refits from these targets
        with zero factorizations (``picholesky.fit(factors=...)``)."""
        h = h_tr_f.shape[-1]
        eye = jnp.eye(h, dtype=h_tr_f.dtype)
        factors = jax.vmap(lambda lam: bk.cholesky(h_tr_f + lam * eye))(aux)
        vec = bk.pack_tril(factors, self.block)
        pf = packing.PackedFactor(vec=vec, h=h, block=self.block)
        model = picholesky.fit(h_tr_f, aux, self.degree, block=self.block,
                               basis=self.basis, factors=pf, backend=bk)
        # fit from the full-precision targets, cache at the storage dtype
        return model, vec.astype(bk.precision.store_dtype(vec.dtype))

    def anchor_hessian(self, f_idx, h_tr_f, x_folds, bk):
        """Hessian the anchor factorizations run on — the exact per-fold
        training Hessian here; the sketched subclass substitutes its
        sketched gram so interpolant selection scores the same targets
        the sweep will actually fit."""
        return h_tr_f


@dataclasses.dataclass(frozen=True, eq=False)
class PiCholeskySketched(PiCholeskyStrategy):
    """Algorithm 1 over **sketched** anchor Hessians — Iterative Hessian
    Sketch (Pilanci & Wainwright, arXiv:1411.0347) behind the piCholesky
    seam.

    Each fold's anchor factorizations run on ``H̃_f = (S X_tr)ᵀ (S X_tr)``
    built from ``m ≪ n`` sketched rows of the fold's training design
    (reconstructed from the *other* folds' raw blocks, like
    :class:`SVDStrategy`), so forming the anchor Hessian costs O(m·h²)
    instead of O(n·h²) — the win at n ≫ h geometries.  The interpolated
    solves are then IHS-corrected in ``fold_errors``: the sketched factor
    is the *preconditioner* and the residuals are exact (dense ``H_f``),
    so the solve error contracts geometrically with
    ``sketch.ihs_iters`` — reusing the precision policy's
    :func:`~repro.core.picholesky.refine_solutions` loop with an explicit
    iteration override.

    Everything downstream of :func:`~repro.core.picholesky.fit` — packed
    trsm, fused ``interp_solve``, λ-chunking, warm-replay cache, async
    sweep, ``search()`` — consumes the sketched state unchanged.  The
    plan's :meth:`~repro.core.sketch.SketchPlan.descriptor` rides in
    ``cache_meta`` → :class:`~repro.core.factor_cache.CacheKey`, so a
    sketched factor can never silently serve an exact request (nor one
    sketched under a different method/m/seed/iteration count).

    ``fold_state`` reads raw fold rows from ``aux`` and the fold index, so
    it is neither Hessian-donatable nor admission-batchable; ``run_batch``
    degrades to per-problem runs.
    """

    sketch: Optional[sketchlib.SketchPlan] = None
    name: str = "picholesky_sketched"
    state_uses_hessian = False
    batchable_state = False

    def __post_init__(self):
        object.__setattr__(self, "sketch", sketchlib.as_plan(self.sketch))

    def _plan(self) -> sketchlib.SketchPlan:
        if self.sketch is None:
            raise ValueError(
                "picholesky_sketched needs a SketchPlan: pass "
                "CVEngine(sketch=...) or PiCholeskySketched(sketch=...)")
        return self.sketch

    @staticmethod
    def _train_rows(f_idx, x_folds):
        k, n_f, h = x_folds.shape
        others = (f_idx + 1 + jnp.arange(k - 1)) % k
        return x_folds[others].reshape((k - 1) * n_f, h)

    def _sketched_hessian(self, f_idx, x_folds, bk):
        x_tr = self._train_rows(f_idx, x_folds)
        ad = bk.precision.accum_dtype(x_tr.dtype)
        h_sk = sketchlib.sketched_gram(self._plan(), x_tr, f_idx,
                                       accum_dtype=ad)
        return h_sk.astype(x_tr.dtype)

    def anchor_hessian(self, f_idx, h_tr_f, x_folds, bk):
        return self._sketched_hessian(f_idx, x_folds, bk)

    def prepare(self, x_folds, y_folds, h_tr, g_tr, lams, bk):
        self._plan()    # fail at trace time, not mid-vmap
        return dict(anchors=_sample_grid(lams, self.g), x=x_folds)

    def fold_state(self, f_idx, h_tr_f, g_tr_f, aux, bk):
        h_sk = self._sketched_hessian(f_idx, aux["x"], bk)
        return picholesky.fit(h_sk, aux["anchors"], self.degree,
                              block=self.block, basis=self.basis,
                              chol_fn=self.chol_fn, backend=bk)

    def fold_state_and_anchors(self, f_idx, h_tr_f, g_tr_f, aux, bk):
        h_sk = self._sketched_hessian(f_idx, aux["x"], bk)
        h = h_sk.shape[-1]
        eye = jnp.eye(h, dtype=h_sk.dtype)
        factors = jax.vmap(
            lambda lam: bk.cholesky(h_sk + lam * eye))(aux["anchors"])
        vec = bk.pack_tril(factors, self.block)
        pf = packing.PackedFactor(vec=vec, h=h, block=self.block)
        model = picholesky.fit(h_sk, aux["anchors"], self.degree,
                               block=self.block, basis=self.basis,
                               factors=pf, backend=bk)
        return model, vec.astype(bk.precision.store_dtype(vec.dtype))

    def fold_errors(self, state, f_idx, h_tr_f, g_tr_f, x_f, y_f, lams, aux, bk):
        # The IHS loop IS refine_solutions with the exact Hessian: the
        # sketched interpolant preconditions, the residual is dense-exact.
        # Never reads aux — warm replay runs with aux=().
        thetas = state.solve(lams, g_tr_f, backend=bk)
        iters = self._plan().ihs_iters + bk.precision.refine_iters
        if iters:
            thetas = picholesky.refine_solutions(state, h_tr_f, g_tr_f,
                                                 lams, thetas, backend=bk,
                                                 iters=iters)
        return _errors_from_thetas(thetas, x_f, y_f)

    def cache_meta(self, lams):
        meta = super().cache_meta(lams)
        if meta is None:
            return None
        meta["sketch"] = self._plan().descriptor()
        return meta


@dataclasses.dataclass(frozen=True, eq=False)
class PiCholeskyWarmstart(_InterpolantErrors, StrategyBase):
    """Cross-fold warm-starting (paper §7 future work).

    An anchor fit on fold 0 (``g_first`` factorizations over the full λ
    range) provides the coefficient prior Θ⁰.  Later folds' training
    Hessians differ from fold 0's by only two fold blocks (H−H_f vs H−H_0),
    so their factor curves are close to the anchor's: each fold refits only
    the **residual** from ``g_rest`` fresh factorizations at full-range
    nodes,

        Θ_f = Θ⁰ + argmin_Δ ‖V_r Δ − (T_f − V_r Θ⁰)‖² + μ‖S Δ‖²

    with S² = diag(V_rᵀV_r) making the damping scale-relative per monomial
    order (the λ grid spans decades, so absolute Tikhonov either crushes
    the constant term or ignores the quadratic one).  Because the residual
    targets are small, the correction degrades gracefully: with
    ``g_rest ≤ degree`` the unseen directions simply stay at the anchor
    value instead of extrapolating wildly — the failure mode that made the
    original host driver select edge-of-grid λ's.
    """

    g_first: int = 4
    g_rest: int = 2
    degree: int = 2
    mu: float = 1e-6
    block: int = 128
    chol_fn: Optional[Callable] = None
    name: str = "picholesky_warmstart"
    state_uses_hessian = True

    def n_exact_chol(self, k, q):
        # anchor fit + one refresh per fold (fold 0's refresh included:
        # the sweep stays uniform across folds, so it is performed)
        return self.g_first + k * max(self.g_rest, 1)

    def prepare(self, x_folds, y_folds, h_tr, g_tr, lams, bk):
        chol = self.chol_fn or bk.cholesky
        sample_full = _sample_grid(lams, self.g_first)
        base = picholesky.fit(h_tr[0], sample_full, self.degree,
                              block=self.block, chol_fn=chol, backend=bk)
        sample_rest = _sample_grid(lams, max(self.g_rest, 1))
        # residual regression runs at the policy's fit dtype (bf16-stored
        # anchors must not degrade the damped least squares)
        fit_dtype = bk.precision.fit_dtype(h_tr.dtype)
        v_rest = picholesky.vandermonde(sample_rest, self.degree
                                        ).astype(fit_dtype)
        gram = v_rest.T @ v_rest
        lhs = gram + self.mu * jnp.diag(jnp.diag(gram))
        return dict(sample_rest=sample_rest, v_rest=v_rest, lhs=lhs,
                    base_theta=base.theta, center=base.center)

    def fold_state(self, f_idx, h_tr_f, g_tr_f, aux, bk):
        chol = self.chol_fn or bk.cholesky
        h = h_tr_f.shape[-1]
        eye = jnp.eye(h, dtype=h_tr_f.dtype)
        factors = jax.vmap(lambda lam: chol(h_tr_f + lam * eye)
                           )(aux["sample_rest"])
        fit_dtype = aux["v_rest"].dtype
        t = bk.pack_tril(factors, self.block).astype(fit_dtype)
        resid = t - aux["v_rest"] @ aux["base_theta"].astype(fit_dtype)
        dtheta = jnp.linalg.solve(aux["lhs"], aux["v_rest"].T @ resid)
        theta = (aux["base_theta"].astype(fit_dtype) + dtheta
                 ).astype(aux["base_theta"].dtype)
        return picholesky.PiCholesky(theta=theta, center=aux["center"],
                                     h=h, block=self.block)

    def cache_meta(self, lams):
        if self.chol_fn is not None:
            return None
        # Θ_f depends on both node sets: the fold-0 anchor fit and the
        # per-fold residual refresh grid.
        lams = jnp.asarray(lams)
        anchors = jnp.concatenate([
            _sample_grid(lams, self.g_first),
            _sample_grid(lams, max(self.g_rest, 1))])
        return dict(anchors=anchors,
                    params=dict(strategy=self.name, g_first=self.g_first,
                                g_rest=self.g_rest, degree=self.degree,
                                mu=self.mu, block=self.block))


@dataclasses.dataclass(frozen=True, eq=False)
class SVDStrategy(StrategyBase):
    """SVD / t-SVD / r-SVD baselines on the raw design matrix.

    Training rows come from the k−1 *other* folds, so the raw fold blocks
    ride along replicated in ``aux`` while the heavy per-fold SVD shards
    over the fold axis.
    """

    mode: str = "full"                 # full | truncated | randomized
    k_trunc: int = 0
    key: Optional[jax.Array] = None    # r-SVD projection key (shared by folds)
    name: str = "svd"

    def n_exact_chol(self, k, q):
        return 0

    def prepare(self, x_folds, y_folds, h_tr, g_tr, lams, bk):
        return dict(x=x_folds, y=y_folds)

    def fold_state(self, f_idx, h_tr_f, g_tr_f, aux, bk):
        k, n_f, h = aux["x"].shape
        others = (f_idx + 1 + jnp.arange(k - 1)) % k
        x_tr = aux["x"][others].reshape((k - 1) * n_f, h)
        y_tr = aux["y"][others].reshape(-1)
        s, vt, uty = solvers.svd_ridge_factors(x_tr, y_tr, self.mode,
                                               self.k_trunc, self.key)
        return dict(s=s, vt=vt, uty=uty)

    def fold_errors(self, state, f_idx, h_tr_f, g_tr_f, x_f, y_f, lams, aux, bk):
        thetas = solvers.svd_ridge_sweep(
            (state["s"], state["vt"], state["uty"]), lams)
        return _errors_from_thetas(thetas, x_f, y_f)


@dataclasses.dataclass(frozen=True, eq=False)
class LowRankStrategy(StrategyBase):
    """Low-rank ACV (Stephenson, Udell & Broderick, arXiv:2008.10547) for
    the n ≪ h / rank-r regime the dense pipeline can't touch.

    ``fold_state`` SVDs the fold's raw (n_tr, h) training design — O(n²h),
    vs g·O(h³) anchor Cholesky factorizations — into
    :class:`~repro.core.solvers.LowRankFactors`; ``fold_errors`` sweeps any
    λ grid through the Woodbury identity

        θ(λ) = V (1/(e+λ) − 1/λ) Vᵀg + g/λ,

    exactly equal to the exact ridge path whenever ``rank ≥ rank(X)``
    (zero-eigenvalue directions self-cancel) and the rank-r ACV
    approximation below it.  The state is **λ-independent** — its cache
    entry carries an empty anchor grid, so *any* grid over the same
    problem replays it — and y-independent, so the Hessian-fingerprint
    content addressing is exactly valid (V, e are the eigenpairs of
    ``H_tr``).  ``cache_meta``'s sketch descriptor (``lowrank/r…``) keeps
    rank-truncated factors from ever serving an exact or differently
    truncated request.
    """

    rank: Optional[int] = None      # None = full min(n_tr, h)
    name: str = "low_rank"
    state_uses_hessian = False
    batchable_state = False

    def n_exact_chol(self, k, q):
        return 0

    def descriptor(self) -> str:
        return f"lowrank/r{'full' if self.rank is None else int(self.rank)}"

    def prepare(self, x_folds, y_folds, h_tr, g_tr, lams, bk):
        return dict(x=x_folds)

    def fold_state(self, f_idx, h_tr_f, g_tr_f, aux, bk):
        k, n_f, h = aux["x"].shape
        others = (f_idx + 1 + jnp.arange(k - 1)) % k
        x_tr = aux["x"][others].reshape((k - 1) * n_f, h)
        return solvers.lowrank_ridge_factors(x_tr, self.rank,
                                             precision=bk.precision)

    def fold_errors(self, state, f_idx, h_tr_f, g_tr_f, x_f, y_f, lams, aux, bk):
        # never reads aux — warm replay runs with aux=()
        thetas = solvers.lowrank_ridge_sweep(
            state, g_tr_f, lams,
            compute_dtype=bk.precision.accum_dtype(g_tr_f.dtype))
        return _errors_from_thetas(thetas, x_f, y_f)

    def cache_meta(self, lams):
        lams = jnp.asarray(lams)
        # λ-independent state: empty anchor grid, so every grid over the
        # same problem derives the same key — any-grid warm replay.
        # block=0 rides in params because the engine's make_key call sites
        # read the packing block from there; the low-rank state is unpacked.
        return dict(anchors=jnp.zeros((0,), lams.dtype),
                    params=dict(strategy=self.name, block=0,
                                rank=-1 if self.rank is None
                                else int(self.rank)),
                    sketch=self.descriptor())


@dataclasses.dataclass(frozen=True, eq=False)
class PinrmseStrategy(StrategyBase):
    """PINRMSE straw-man (§6.5): interpolate the hold-out-error *curve*
    itself from g exact evaluations — the paper shows it selects wrong λ's.

    The k·g exact evaluations need every fold's statistics at the same g
    nodes plus a cross-fold mean, so they live in ``prepare`` (replicated —
    at engine scale this stage is the cheap one; the dense sweep it replaces
    is the cost being amortized).
    """

    g: int = 4
    degree: int = 2
    chol_fn: Optional[Callable] = None
    name: str = "pinrmse"

    def n_exact_chol(self, k, q):
        return k * self.g

    def prepare(self, x_folds, y_folds, h_tr, g_tr, lams, bk):
        sample = _sample_grid(lams, self.g)

        def fold_curve(h_f, g_f, x_f, y_f):
            thetas = solvers.solve_cholesky_sweep(h_f, g_f, sample,
                                                  self.chol_fn, bk)
            return _errors_from_thetas(thetas, x_f, y_f)

        mean_err = jax.vmap(fold_curve)(h_tr, g_tr, x_folds, y_folds).mean(0)
        # the curve fit runs at the policy's fit dtype (fp32 floor — the
        # interpolated *errors* must not quantize), one definition shared
        # with the factor fits instead of a local jax_enable_x64 probe
        fit_dtype = bk.precision.fit_dtype(mean_err.dtype)
        v = picholesky.vandermonde(sample, self.degree).astype(fit_dtype)
        theta = jnp.linalg.solve(v.T @ v, v.T @ mean_err.astype(fit_dtype))
        return theta

    def fold_errors(self, state, f_idx, h_tr_f, g_tr_f, x_f, y_f, lams, aux, bk):
        v = picholesky.vandermonde(lams, self.degree).astype(aux.dtype)
        return v @ aux  # identical on every fold ⇒ mean is the curve itself


STRATEGIES = {
    "exact": ExactCholesky,
    "picholesky": PiCholeskyStrategy,
    "picholesky_sketched": PiCholeskySketched,
    "picholesky_warmstart": PiCholeskyWarmstart,
    "svd": SVDStrategy,
    "low_rank": LowRankStrategy,
    "pinrmse": PinrmseStrategy,
}


def make_strategy(name: str, **params) -> CVStrategy:
    try:
        return STRATEGIES[name](**params)
    except KeyError:
        raise ValueError(
            f"unknown strategy {name!r}; have {sorted(STRATEGIES)}") from None


# -------------------------------------------------------------------- engine


MeshLike = Union[None, str, Mesh]


@dataclasses.dataclass
class SweepChunk:
    """One completed λ chunk of a pipelined sweep — a partial error curve.

    Yielded by :meth:`CVEngine.sweep_async` as each chunk's hold-out errors
    land on the host; ``best_lam`` / ``best_error`` track the running
    minimum over everything streamed so far, and ``stopped`` marks the
    chunk at which the early-stop search terminated the stream.
    """

    index: int               # chunk position in the stream
    start: int               # global λ-grid offset of this chunk's first λ
    n_chunks: int            # chunks the full stream would have
    lams: np.ndarray         # (c,) this chunk's λs (padding stripped)
    fold_errors: np.ndarray  # (k, c) per-fold hold-out errors
    errors: np.ndarray       # (c,) fold-mean partial curve
    best_lam: float          # running argmin λ over all streamed chunks
    best_error: float        # running min mean error
    stopped: bool            # early stop fired at this chunk
    n_exact_chol: int        # factorizations for the grid evaluated so far
    cache: Optional[dict]    # warm-replay cache info (None without a cache)


#: HBM/VMEM budget (bytes) the ``lam_chunk='auto'`` heuristic sizes the
#: per-chunk packed-factor working set against — one VMEM's worth, so the
#: streamed sweep's λ-dependent footprint matches what a TPU core can hold.
LAM_CHUNK_BUDGET_BYTES = 16 * 1024 * 1024


@dataclasses.dataclass
class CVEngine:
    """Batched/sharded k-fold × λ sweep runner.

    Parameters
    ----------
    strategy:  a :class:`CVStrategy` instance or registry name.
    backend:   ``'auto'`` (Pallas on TPU, reference elsewhere) | ``'pallas'``
               | ``'reference'`` | a :class:`LinalgBackend`.
    mesh:      ``None`` (single device), ``'auto'`` (2-D folds × lams mesh
               over all local devices), or an explicit 2-D Mesh whose axes
               are ``(CV_FOLD_AXIS, CV_LAM_AXIS)``.
    donate:    donate the per-fold training Hessians into the jitted sweep
               (``None`` = on except on CPU, where XLA cannot alias).
    block:     Pallas kernel tile size override for small test problems.
    lam_chunk: λ-axis streaming: the per-device λ shard is processed in
               fixed-size chunks under an outer ``lax.map``, so the sweep's
               peak memory is O(chunk · P) regardless of the grid size q.
               ``'auto'`` (default) sizes the chunk so one chunk's packed
               factors fit :data:`LAM_CHUNK_BUDGET_BYTES`; an ``int`` fixes
               it; ``None`` disables streaming (whole shard in one call).
               Requires ``fold_errors`` to be λ-elementwise — true of every
               built-in strategy (each λ's solve/score is independent).
    cache:     a :class:`~repro.core.factor_cache.FactorCache` enabling the
               warm-replay path (strategies advertising ``cache_meta``,
               i.e. the piCholesky family).  On a fingerprint hit the heavy
               ``fold_state`` stage is skipped entirely and the sweep
               replays the cached Θ through the fused ``interp_solve``
               chunked stream (still O(chunk · P)); on a miss the cold
               stage runs and populates the cache.  ``None`` (default)
               keeps the original single-jit fused sweep.
    reuse:     cache read policy: ``'exact'`` (default — the requested
               grid must derive the very anchor set the entry was fitted
               on), ``'covering'`` (also accept a cached Θ whose anchor
               range covers the requested grid), or ``False`` (write-only:
               never read, always repopulate — the cold baseline for
               warm-vs-cold measurements).
    cache_anchors: also cache the per-(fold, λ_s) tile-packed anchor
               factors; a later run over the same anchors with a different
               degree/basis then refits Θ from them with zero
               factorizations.
    precision: the pipeline's :class:`~repro.core.precision.PrecisionPolicy`
               (a preset name, a policy object, or ``None`` = environment
               default, normally ``native``).  One policy governs every
               layer: factorizations run at its accumulation dtype, fitted
               Θ / cached anchors are stored at its storage dtype (bf16
               halves them, and the VMEM-auto ``lam_chunk`` doubles to
               match), the fused solves feed the MXU at its compute dtype,
               and ``refine_iters`` > 0 adds an fp32 residual-refinement
               sweep per λ chunk on top of the low-precision
               ``interp_solve`` (``bf16_refined`` reproduces the fp32
               hold-out argmin at half the factor bytes).  The policy is
               part of the cache fingerprint: a bf16 entry can never
               silently serve an fp32 request.  When an explicit backend
               *instance* is passed without ``precision``, the backend's
               own policy is adopted — one policy per pipeline, resolved
               once.
    tune:      roofline-guided compile-time autotuning
               (:mod:`repro.distributed.autotune`).  ``False`` (default)
               runs the configured block / λ-chunk / mesh as-is.
               ``'auto'`` searches the legal configuration lattice on the
               first sweep of each problem geometry — every candidate is
               AOT-lowered and scored against the roofline model; nothing
               executes — and runs the predicted-fastest configuration
               (kernel tiles, packing block, λ-chunk and mesh shape all
               follow the choice).  A
               :class:`~repro.distributed.autotune.TunedConfig` pins a
               previously chosen configuration.  Tuning never changes
               *what* is computed — only tiling, chunking and layout —
               and a repeat geometry hits the content-addressed
               ``tune_cache`` without re-lowering anything.
    tune_cache: a :class:`~repro.distributed.autotune.TuningCache` shared
               across engines (the serving layer passes one per server);
               ``None`` with ``tune='auto'`` creates a private one.
    tune_lattice: optional lattice overrides forwarded to
               :func:`~repro.distributed.autotune.tune` (``blocks=``,
               ``chunks=``, ``mesh_shapes=``, ``hw=``) — benches and
               tests shrink the search with this.
    sketch:    a :class:`~repro.core.sketch.SketchPlan` (or its dict form)
               switching anchor factorization to the sketched route:
               ``CVEngine(strategy='picholesky', sketch=plan)`` upgrades
               the strategy to :class:`PiCholeskySketched` — anchor
               Hessians built from ``m ≪ n`` sketched rows, IHS-refined
               solves, cache entries keyed by the plan's descriptor.
               ``None`` (default) keeps exact anchors.
    """

    strategy: Union[CVStrategy, str]
    backend: BackendLike = None
    mesh: MeshLike = None
    donate: Optional[bool] = None
    block: Optional[int] = None
    lam_chunk: Union[None, int, str] = "auto"
    cache: Optional[cachelib.FactorCache] = None
    reuse: Union[bool, str] = "exact"
    cache_anchors: bool = False
    precision: PrecisionLike = None
    tune: Any = False
    tune_cache: Optional[Any] = None
    tune_lattice: Optional[dict] = None
    sketch: Optional[Any] = None

    def __post_init__(self):
        if isinstance(self.strategy, str):
            self.strategy = make_strategy(self.strategy)
        if self.sketch is not None:
            plan = sketchlib.as_plan(self.sketch)
            strat = self.strategy
            if isinstance(strat, PiCholeskySketched):
                if strat.sketch is None:
                    self.strategy = dataclasses.replace(strat, sketch=plan)
                elif strat.sketch != plan:
                    raise ValueError(
                        f"conflicting sketch plans: engine sketch= is "
                        f"{plan.descriptor()} but the strategy carries "
                        f"{strat.sketch.descriptor()}")
            elif isinstance(strat, PiCholeskyStrategy) and \
                    type(strat) is PiCholeskyStrategy:
                self.strategy = PiCholeskySketched(
                    g=strat.g, degree=strat.degree, block=strat.block,
                    basis=strat.basis, chol_fn=strat.chol_fn, sketch=plan)
            else:
                raise ValueError(
                    "sketch= needs the picholesky strategy, got "
                    f"{getattr(strat, 'name', strat)!r}")
            self.sketch = plan
        if isinstance(self.strategy, PiCholeskySketched) \
                and self.strategy.sketch is None:
            raise ValueError(
                "picholesky_sketched needs a SketchPlan: pass "
                "CVEngine(sketch=...) or a strategy instance with sketch=")
        if self.reuse is True:
            self.reuse = "exact"
        if self.reuse not in (False, "exact", "covering"):
            raise ValueError(f"reuse must be 'exact', 'covering' or False; "
                             f"got {self.reuse!r}")
        if self.tune not in (False, "auto") \
                and type(self.tune).__name__ != "TunedConfig":
            raise ValueError(f"tune must be False, 'auto' or a TunedConfig; "
                             f"got {self.tune!r}")
        self._bk = resolve_backend(self.backend, block=self.block,
                                   precision=self.precision)
        self._prec = self._bk.precision   # one policy per pipeline
        self._tuned_engines: dict = {}    # TunedConfig.key() -> derived engine
        if self.donate is None:
            self.donate = jax.default_backend() != "cpu"
        self._sweeps: dict = {}   # mesh-key -> jitted fused sweep fn
        self._states: dict = {}   # (mesh-key, with_anchors) -> jitted state fn
        self._replays: dict = {}  # mesh-key -> jitted replay fn
        self._chunks: dict = {}   # mesh-key -> jitted per-chunk errors fn
        self._fold_states: dict = {}   # with_anchors -> jitted 1-fold state fn
        self._prepare = None      # jitted replicated prepare stage
        self._interp_engines: dict = {}  # (degree, basis) -> derived engine
        self._anchor_targets = None      # jitted anchor-factorize stage
        self._split = jax.jit(
            lambda hess, grad, fh, fg: (hess[None] - fh, grad[None] - fg))

    # -- mesh -------------------------------------------------------------

    def _resolve_mesh(self, k: int) -> Optional[Mesh]:
        if self.mesh is None:
            return None
        if isinstance(self.mesh, Mesh):
            return self.mesh
        if self.mesh == "auto":
            if len(jax.devices()) == 1:
                return None
            return shardlib.make_cv_mesh(k)
        raise ValueError(f"mesh must be None, 'auto' or a Mesh; got {self.mesh!r}")

    @staticmethod
    def _check_fold_axis(mesh: Optional[Mesh], k: int) -> None:
        """Fail with the engine's error, not a shard_map internal one, when
        the fold count does not tile the mesh's fold axis (folds cannot be
        padded — the count is fixed by the problem)."""
        if mesh is None:
            return
        n_fold = mesh.shape[shardlib.CV_FOLD_AXIS]
        if k % n_fold:
            raise ValueError(
                f"{k} folds not divisible by mesh axis "
                f"{shardlib.CV_FOLD_AXIS}={n_fold}")

    # -- λ-grid validation -------------------------------------------------

    @staticmethod
    def _check_lams(lams, min_q: int = 1, what: str = "sweep") -> jax.Array:
        """Validate a λ grid at the engine's entry points.

        Degenerate grids used to die deep inside the machinery with opaque
        shape errors (``q=0`` in ``pad_to_multiple``/``reshape``, an
        ``IndexError`` on an empty chunk stream) — fail here instead, with
        a message naming the actual problem.  ``q=1`` is legal for a sweep
        (one λ, trivially) but not for :meth:`search` (``min_q=2`` — a
        bracketing search needs a range).
        """
        lams = jnp.asarray(lams)
        if lams.ndim != 1:
            raise ValueError(
                f"λ grid must be 1-D, got shape {tuple(lams.shape)}")
        q = int(lams.shape[0])
        if q == 0:
            raise ValueError(
                f"empty λ grid (q=0): the {what} needs at least "
                f"{min_q} candidate λ value(s)")
        if q < min_q:
            raise ValueError(
                f"λ grid has {q} value(s) but the {what} needs at least "
                f"{min_q} (a single λ defines no range to refine — "
                "use run() for a point evaluation)")
        return lams

    # -- λ chunking --------------------------------------------------------

    def _resolve_chunk(self, q_loc: int, h: int, dtype) -> Optional[int]:
        """Static chunk size for a (q_loc,) λ shard, or None (no streaming).

        The VMEM-auto heuristic budgets the chunk's packed working set at
        the policy's *storage* dtype — bf16 storage doubles the chunk at
        the same byte budget.
        """
        if self.lam_chunk is None:
            return None
        if self.lam_chunk == "auto":
            block = getattr(self.strategy, "block", None) or self.block or 128
            return shardlib.auto_lam_chunk(
                h, block, self._prec.store_dtype(dtype),
                LAM_CHUNK_BUDGET_BYTES)
        chunk = int(self.lam_chunk)
        if chunk <= 0:
            raise ValueError(f"lam_chunk must be positive, got {chunk}")
        return chunk

    # -- roofline-guided autotuning ---------------------------------------
    #
    # tune='auto' inserts one step before the first sweep of a geometry:
    # the autotuner AOT-lowers the fused sweep for every point of the legal
    # (block × λ-chunk × mesh) lattice, scores the compiled HLO against the
    # roofline model, and the engine delegates the actual run to a DERIVED
    # engine carrying the winning configuration.  The derived engine is a
    # full CVEngine (same strategy math, same cache, tune=False) so every
    # path — run, the pipelined sweep, batched admission — works tuned
    # without per-path plumbing; it is memoized per chosen config so its
    # jit caches warm up exactly like an untuned engine's.

    def _apply_tuned(self, cfg) -> "CVEngine":
        """The derived engine that *runs* a tuned configuration: strategy
        packing block and Pallas kernel tiles re-sized to ``cfg.block``,
        λ-chunk pinned, mesh built from ``cfg.mesh_shape`` (reusing this
        engine's explicit mesh when the shape matches, so jit caches keyed
        on device identity survive).  Shares the factor cache and the
        precision policy; ``tune=False`` on the result is the recursion
        guard."""
        key = cfg.key()
        if key in self._tuned_engines:
            return self._tuned_engines[key]
        from .backends import retile_backend
        strat = self.strategy
        if dataclasses.is_dataclass(strat) and any(
                f.name == "block" for f in dataclasses.fields(strat)) \
                and strat.block != cfg.block:
            strat = dataclasses.replace(strat, block=cfg.block)
        bk = retile_backend(self._bk, chol_block=cfg.block,
                            trsm_block=cfg.block)
        if cfg.mesh_shape is None:
            mesh = None
        else:
            n_fold, n_lam = cfg.mesh_shape
            if isinstance(self.mesh, Mesh) and \
                    (self.mesh.shape.get(shardlib.CV_FOLD_AXIS),
                     self.mesh.shape.get(shardlib.CV_LAM_AXIS)) == \
                    (n_fold, n_lam):
                mesh = self.mesh
            else:
                dev = np.asarray(
                    jax.devices()[: n_fold * n_lam]).reshape(n_fold, n_lam)
                mesh = Mesh(dev, (shardlib.CV_FOLD_AXIS, shardlib.CV_LAM_AXIS))
        derived = CVEngine(
            strategy=strat, backend=bk, mesh=mesh, donate=self.donate,
            block=cfg.block, lam_chunk=int(cfg.lam_chunk), cache=self.cache,
            reuse=self.reuse, cache_anchors=self.cache_anchors,
            tune=False, tune_cache=self.tune_cache)
        self._tuned_engines[key] = derived
        return derived

    def _tuned_engine(self, folds: FoldData, lams):
        """(derived engine, chosen config) for this problem geometry —
        the tune dispatch shared by every public entry point."""
        from repro.distributed import autotune
        if isinstance(self.tune, autotune.TunedConfig):
            cfg = self.tune
        else:
            if self.tune_cache is None:
                self.tune_cache = autotune.TuningCache()
            cfg = autotune.tune(self, folds, jnp.asarray(lams),
                                cache=self.tune_cache,
                                **(self.tune_lattice or {}))
        return self._apply_tuned(cfg), cfg

    # -- sweep construction ----------------------------------------------

    def _stream_errors(self, errors_at, lams, k_loc, h, dtype):
        """Stream ``errors_at`` over the local λ shard in ``lam_chunk``-sized
        chunks under a sequential ``lax.map`` — only one chunk's
        interpolants/factors are live at a time, so peak memory is
        O(chunk · P) however dense the grid.  Composes with the
        folds × lams ``shard_map``: chunking happens per device on the
        local λ shard.  Shared by the fused cold sweep and the
        warm-replay path, so the memory contract has one implementation.
        """
        q_loc = lams.shape[0]
        chunk = self._resolve_chunk(q_loc, h, dtype)
        if chunk is None or chunk >= q_loc:
            return errors_at(lams)
        chunks, _ = shardlib.chunk_lams(lams, chunk)    # (n_c, chunk)
        errs = jax.lax.map(errors_at, chunks)           # (n_c, k_loc, chunk)
        return jnp.moveaxis(errs, 1, 0).reshape(k_loc, -1)[:, :q_loc]

    def _core(self, h_tr, g_tr, x_folds, y_folds, f_idx, lams, aux):
        """(k_loc folds) × (q_loc λs) error grid — runs per device shard."""
        strat, bk = self.strategy, self._bk
        state = jax.vmap(
            lambda f, h, g: strat.fold_state(f, h, g, aux, bk)
        )(f_idx, h_tr, g_tr)

        def errors_at(lams_c):
            return jax.vmap(
                lambda st, f, h, g, x, y: strat.fold_errors(
                    st, f, h, g, x, y, lams_c, aux, bk)
            )(state, f_idx, h_tr, g_tr, x_folds, y_folds)

        return self._stream_errors(errors_at, lams, h_tr.shape[0],
                                   h_tr.shape[-1], h_tr.dtype)

    def _build_sweep(self, mesh: Optional[Mesh]):
        strat, bk = self.strategy, self._bk

        def sweep(h_tr, g_tr, x_folds, y_folds, lams):
            k = h_tr.shape[0]
            f_idx = jnp.arange(k)
            aux = strat.prepare(x_folds, y_folds, h_tr, g_tr, lams, bk)
            if mesh is None:
                return self._core(h_tr, g_tr, x_folds, y_folds, f_idx,
                                  lams, aux)
            fold_ax, lam_ax = shardlib.CV_FOLD_AXIS, shardlib.CV_LAM_AXIS
            repl = jax.tree.map(lambda _: P(), aux)
            sharded = shard_map(
                self._core, mesh=mesh,
                in_specs=(P(fold_ax), P(fold_ax), P(fold_ax), P(fold_ax),
                          P(fold_ax), P(lam_ax), repl),
                out_specs=P(fold_ax, lam_ax),
                check_rep=False,
            )
            return sharded(h_tr, g_tr, x_folds, y_folds, f_idx, lams, aux)

        donate = (0, 1) if self.donate else ()
        return jax.jit(sweep, donate_argnums=donate)

    @staticmethod
    def _mesh_key(mesh: Optional[Mesh]):
        return None if mesh is None else (tuple(mesh.shape.items()),
                                          tuple(map(id, mesh.devices.flat)))

    def _sweep_fn(self, mesh: Optional[Mesh]):
        key = self._mesh_key(mesh)
        if key not in self._sweeps:
            self._sweeps[key] = self._build_sweep(mesh)
        return self._sweeps[key]

    # -- warm-replay path (factor cache) ----------------------------------
    #
    # With a cache, the sweep splits at the PR-1 seam into two jitted
    # stages: the λ-independent ``fold_state`` stage (skipped entirely on a
    # hit) and the replay stage, which streams any λ grid through the
    # fused interp_solve chunked pipeline from a given state.  Neither
    # donates the train Hessians — the state fn's output must outlive the
    # call (it goes into the cache) and the replay reads h_tr/g_tr again.

    def _replay_core(self, state, f_idx, h_tr, g_tr, x_folds, y_folds, lams):
        """Per-shard replay: fold_errors from a cached per-fold state.

        Runs with ``aux=()`` — ``prepare`` is never called, so a strategy
        is only cacheable if its ``fold_errors`` ignores ``aux`` (the
        ``cache_meta`` contract).
        """
        strat, bk = self.strategy, self._bk

        def errors_at(lams_c):
            return jax.vmap(
                lambda st, f, h, g, x, y: strat.fold_errors(
                    st, f, h, g, x, y, lams_c, (), bk)
            )(state, f_idx, h_tr, g_tr, x_folds, y_folds)

        return self._stream_errors(errors_at, lams, h_tr.shape[0],
                                   h_tr.shape[-1], h_tr.dtype)

    def _build_replay(self, mesh: Optional[Mesh]):
        def replay(state, h_tr, g_tr, x_folds, y_folds, lams):
            k = h_tr.shape[0]
            f_idx = jnp.arange(k)
            if mesh is None:
                return self._replay_core(state, f_idx, h_tr, g_tr,
                                         x_folds, y_folds, lams)
            fold_ax, lam_ax = shardlib.CV_FOLD_AXIS, shardlib.CV_LAM_AXIS
            sharded = shard_map(
                self._replay_core, mesh=mesh,
                in_specs=(shardlib.cv_state_specs(state), P(fold_ax),
                          P(fold_ax), P(fold_ax), P(fold_ax), P(fold_ax),
                          P(lam_ax)),
                out_specs=P(fold_ax, lam_ax),
                check_rep=False,
            )
            return sharded(state, f_idx, h_tr, g_tr, x_folds, y_folds, lams)

        return jax.jit(replay)

    def _replay_fn(self, mesh: Optional[Mesh]):
        key = self._mesh_key(mesh)
        if key not in self._replays:
            self._replays[key] = self._build_replay(mesh)
        return self._replays[key]

    def _build_state(self, mesh: Optional[Mesh], with_anchors: bool):
        strat, bk = self.strategy, self._bk

        def core(f_idx, h_tr, g_tr, aux):
            def one(f, h_f, g_f):
                if with_anchors:
                    return strat.fold_state_and_anchors(f, h_f, g_f, aux, bk)
                return strat.fold_state(f, h_f, g_f, aux, bk), \
                    jnp.zeros((0,), h_f.dtype)
            return jax.vmap(one)(f_idx, h_tr, g_tr)

        def statef(h_tr, g_tr, x_folds, y_folds, lams):
            k = h_tr.shape[0]
            f_idx = jnp.arange(k)
            aux = strat.prepare(x_folds, y_folds, h_tr, g_tr, lams, bk)
            if mesh is None:
                return core(f_idx, h_tr, g_tr, aux)
            fold_ax = shardlib.CV_FOLD_AXIS
            repl = jax.tree.map(lambda _: P(), aux)
            sharded = shard_map(
                core, mesh=mesh,
                in_specs=(P(fold_ax), P(fold_ax), P(fold_ax), repl),
                out_specs=(P(fold_ax), P(fold_ax)),
                check_rep=False,
            )
            return sharded(f_idx, h_tr, g_tr, aux)

        return jax.jit(statef)

    def _state_fn(self, mesh: Optional[Mesh], with_anchors: bool):
        key = (self._mesh_key(mesh), with_anchors)
        if key not in self._states:
            self._states[key] = self._build_state(mesh, with_anchors)
        return self._states[key]

    def _refit_from_anchors(self, pf: packing.PackedFactor, meta: dict):
        """Θ from cached packed anchor factors — a batched GEMM least-
        squares per fold, zero factorizations (the anchor-hit path)."""
        strat, bk = self.strategy, self._bk
        anchors = jnp.asarray(meta["anchors"])

        def one(vec_f):
            pf_f = packing.PackedFactor(vec=vec_f, h=pf.h, block=pf.block)
            return picholesky.fit(None, anchors, strat.degree,
                                  block=strat.block, basis=strat.basis,
                                  factors=pf_f, backend=bk)

        return jax.jit(jax.vmap(one))(jnp.asarray(pf.vec))

    # -- pipelined staged sweep -------------------------------------------
    #
    # The fold_state / fold_errors seam, driven from the host: per-fold
    # state stages dispatch without blocking (bounded by a depth-2
    # StageRing so at most two donated Hessian slices are in flight), the
    # λ grid streams through one jitted chunk stage, and each completed
    # chunk surfaces as a partial hold-out curve the early-stop search can
    # act on.  `pipelined=False` runs the *same* jitted stage functions
    # with a block after every dispatch — the serial reference the parity
    # tests compare bit-for-bit against.

    def _stage_scope(self, label: str):
        """Counting scope for stage-granular backends (CountingBackend);
        a no-op context for plain backends."""
        stage = getattr(self._bk, "stage", None)
        return stage(label) if callable(stage) else contextlib.nullcontext()

    def _prepare_fn(self):
        if self._prepare is None:
            strat, bk = self.strategy, self._bk
            self._prepare = jax.jit(
                lambda h_tr, g_tr, x, y, lams: strat.prepare(
                    x, y, h_tr, g_tr, lams, bk))
        return self._prepare

    def _fold_state_fn(self, with_anchors: bool):
        """Jitted single-fold ``fold_state`` — the pipelined sweep's unit of
        dispatch.  The fold's Hessian slice (an engine-owned copy) is
        donated when the strategy actually consumes it."""
        if with_anchors not in self._fold_states:
            strat, bk = self.strategy, self._bk

            def one(f, h_f, g_f, aux):
                if with_anchors:
                    return strat.fold_state_and_anchors(f, h_f, g_f, aux, bk)
                return (strat.fold_state(f, h_f, g_f, aux, bk),
                        jnp.zeros((0,), h_f.dtype))

            donate = ((1,) if self.donate
                      and getattr(strat, "state_uses_hessian", False) else ())
            self._fold_states[with_anchors] = jax.jit(one,
                                                      donate_argnums=donate)
        return self._fold_states[with_anchors]

    def _build_chunk_errors(self, mesh: Optional[Mesh]):
        strat, bk = self.strategy, self._bk

        def core(state, f_idx, h_tr, g_tr, x_folds, y_folds, lams_c, aux):
            return jax.vmap(
                lambda st, f, h, g, x, y: strat.fold_errors(
                    st, f, h, g, x, y, lams_c, aux, bk)
            )(state, f_idx, h_tr, g_tr, x_folds, y_folds)

        def chunk_errors(state, f_idx, h_tr, g_tr, x_folds, y_folds,
                         lams_c, aux):
            if mesh is None:
                return core(state, f_idx, h_tr, g_tr, x_folds, y_folds,
                            lams_c, aux)
            sharded = shard_map(
                core, mesh=mesh,
                in_specs=shardlib.cv_chunk_in_specs(state, aux),
                out_specs=P(shardlib.CV_FOLD_AXIS, shardlib.CV_LAM_AXIS),
                check_rep=False,
            )
            return sharded(state, f_idx, h_tr, g_tr, x_folds, y_folds,
                           lams_c, aux)

        return jax.jit(chunk_errors)

    def _chunk_errors_fn(self, mesh: Optional[Mesh]):
        key = self._mesh_key(mesh)
        if key not in self._chunks:
            self._chunks[key] = self._build_chunk_errors(mesh)
        return self._chunks[key]

    def _pipelined_state(self, mesh, h_tr, g_tr, folds: FoldData, lams,
                         with_anchors: bool, pipelined: bool):
        """Cold ``fold_state`` stage of the staged sweep.

        Unsharded: per-fold jitted dispatches through a depth-2
        :class:`~repro.distributed.sharding.StageRing` — fold f+1's anchor
        factorizations sit in the device queue (with their donated Hessian
        slices) while fold f's output is still being computed, and the ring
        bounds in-flight donated buffers to two.  With a mesh, the stage is
        one fold-sharded batched call: the folds factorize in parallel
        across the fold axis instead of in dispatch order (no donation —
        the chunk stage reads ``h_tr`` again).

        Returns ``(batched state, packed anchors | None, aux)``.
        """
        strat = self.strategy
        with self._stage_scope("prepare"):
            aux = self._prepare_fn()(h_tr, g_tr, folds.x_folds,
                                     folds.y_folds, lams)
        if not pipelined:
            jax.block_until_ready(aux)
        if mesh is not None:
            with self._stage_scope("fold_state"):
                state, avec = self._staged_state_fn(mesh, with_anchors)(
                    jnp.arange(h_tr.shape[0]), h_tr, g_tr, aux)
            if not pipelined:
                jax.block_until_ready((state, avec))
        else:
            fn = self._fold_state_fn(with_anchors)
            ring = shardlib.StageRing(depth=2)
            outs = []
            with self._stage_scope("fold_state"):
                for f in range(h_tr.shape[0]):
                    staged = fn(jnp.asarray(f), h_tr[f], g_tr[f], aux)
                    outs.append(ring.admit(staged))
                    if not pipelined:
                        jax.block_until_ready(staged)
            state = jax.tree.map(lambda *xs: jnp.stack(xs),
                                 *[s for s, _ in outs])
            avec = jnp.stack([a for _, a in outs])
        pf = (packing.PackedFactor(vec=avec, h=h_tr.shape[-1],
                                   block=strat.block)
              if with_anchors else None)
        return state, pf, aux

    def _staged_state_fn(self, mesh: Mesh, with_anchors: bool):
        """Fold-sharded batched state stage taking a precomputed ``aux``
        (unlike :meth:`_state_fn`, which runs ``prepare`` inside its jit —
        the staged sweep computes ``aux`` once and shares it with the chunk
        stage, so ``prepare``'s factorizations are never traced twice)."""
        key = ("staged", self._mesh_key(mesh), with_anchors)
        if key not in self._states:
            strat, bk = self.strategy, self._bk

            def core(f_idx, h_tr, g_tr, aux):
                def one(f, h_f, g_f):
                    if with_anchors:
                        return strat.fold_state_and_anchors(f, h_f, g_f,
                                                            aux, bk)
                    return strat.fold_state(f, h_f, g_f, aux, bk), \
                        jnp.zeros((0,), h_f.dtype)
                return jax.vmap(one)(f_idx, h_tr, g_tr)

            def statef(f_idx, h_tr, g_tr, aux):
                fold_ax = shardlib.CV_FOLD_AXIS
                repl = jax.tree.map(lambda _: P(), aux)
                sharded = shard_map(
                    core, mesh=mesh,
                    in_specs=(P(fold_ax), P(fold_ax), P(fold_ax), repl),
                    out_specs=(P(fold_ax), P(fold_ax)),
                    check_rep=False,
                )
                return sharded(f_idx, h_tr, g_tr, aux)

            self._states[key] = jax.jit(statef)
        return self._states[key]

    def _staged_state_for(self, mesh, h_tr, g_tr, folds: FoldData, lams,
                          pipelined: bool):
        """State stage of the staged sweep, cache dispatch included —
        shared by :meth:`sweep_async` and :meth:`search` so the two λ
        streams acquire their fitted state identically (fingerprint →
        hit | anchor refit | cold populate) and can never drift.

        Returns ``(batched state, aux, warm, cache_info)``.
        """
        strat, bk = self.strategy, self._bk
        meta = (strat.cache_meta(lams)
                if self.cache is not None and hasattr(strat, "cache_meta")
                else None)
        aux: Any = ()
        warm = False
        if meta is not None:
            key = cachelib.make_key(
                h_tr, meta["anchors"], block=meta["params"]["block"],
                backend=bk.name, params=meta["params"],
                precision=self._prec.descriptor(),
                sketch=meta.get("sketch", "exact"))

            def cold_state(with_anchors):
                state, pf, _ = self._pipelined_state(
                    mesh, h_tr, g_tr, folds, lams, with_anchors, pipelined)
                return state, pf

            entry, status = self._acquire_cached_state(meta, key, cold_state)
            state = entry.state
            warm = status != "miss"
            cache_info = dict(status=status, digest=entry.key.digest()[:12],
                              policy=self.reuse, **self.cache.stats)
            # replay contract: fold_errors of a cacheable strategy never
            # reads aux, so the chunk stage streams with aux=() on both the
            # warm and the just-populated cold path
        else:
            state, _, aux = self._pipelined_state(
                mesh, h_tr, g_tr, folds, lams, False, pipelined)
            cache_info = (None if self.cache is None
                          else dict(status="bypass"))
        return state, aux, warm, cache_info

    def sweep_async(self, folds: FoldData, lams: jax.Array, *,
                    stop_tol: Optional[float] = None, stop_patience: int = 2,
                    pipelined: bool = True) -> Iterator[SweepChunk]:
        """Pipelined staged sweep — yields a :class:`SweepChunk` per λ chunk.

        Parameters
        ----------
        stop_tol:      ``None`` disables early stopping.  A float ≥ 0
                       enables the early-stop λ-search: a chunk *improves*
                       when its minimum mean error drops below
                       ``best · (1 − stop_tol)``; after ``stop_patience``
                       consecutive non-improving chunks the stream stops.
                       ``stop_tol=0`` stops only on strict non-improvement,
                       so on a unimodal hold-out curve the returned minimum
                       is exactly the full grid's argmin.  A chunk whose
                       mean hold-out error is non-finite (singular fold,
                       bf16 overflow) raises ``FloatingPointError`` — the
                       search refuses to rank errors it cannot compare
                       rather than silently counting the chunk as
                       non-improving and "stopping" on a ``nan`` λ*.
        stop_patience: consecutive non-improving chunks tolerated before
                       stopping (default 2).
        pipelined:     ``True`` dispatches stages without blocking — the
                       device queue overlaps fold f+1's factorizations with
                       fold f's chunk streaming, and full sweeps keep one
                       chunk of dispatch lookahead.  ``False`` blocks after
                       every stage (the serial reference).  Both orders run
                       the *same* jitted stage functions on the same
                       inputs, so their error curves are **bit-for-bit
                       identical** — pipelining reorders dispatch, never
                       math.

        Composes with the warm-replay cache exactly like :meth:`run`: a hit
        skips the state stage and streams the cached Θ through the chunk
        stage; a miss runs the cold stage and populates the cache *before*
        the λ stream starts, so an early-stopped sweep still leaves a
        complete, replayable entry (the fit is λ-grid independent — only
        the curve evaluation is truncated).
        """
        if stop_tol is not None and stop_tol < 0:
            raise ValueError(f"stop_tol must be >= 0 or None, got {stop_tol}")
        if stop_patience < 1:
            raise ValueError(
                f"stop_patience must be >= 1, got {stop_patience}")
        if self.tune:
            derived, _ = self._tuned_engine(folds, lams)
            yield from derived.sweep_async(
                folds, lams, stop_tol=stop_tol, stop_patience=stop_patience,
                pipelined=pipelined)
            return
        lams = self._check_lams(lams)
        lams_np = np.asarray(lams)
        k = folds.fold_hess.shape[0]
        q = int(lams.shape[0])
        h = folds.fold_hess.shape[-1]
        mesh = self._resolve_mesh(k)
        self._check_fold_axis(mesh, k)
        h_tr, g_tr = self._split(folds.hess, folds.grad,
                                 folds.fold_hess, folds.fold_grad)
        strat = self.strategy

        # fixed-size chunk schedule (last chunk edge-padded) so one jitted
        # chunk stage serves the whole stream
        chunk = self._resolve_chunk(q, h, h_tr.dtype)
        if chunk is None or chunk > q:
            chunk = q
        if mesh is not None:
            chunk += (-chunk) % mesh.shape[shardlib.CV_LAM_AXIS]
        chunks, _ = shardlib.chunk_lams(lams, chunk)
        n_c = chunks.shape[0]

        # ---- state stage (cache dispatch identical to run()) ------------
        state, aux, warm, cache_info = self._staged_state_for(
            mesh, h_tr, g_tr, folds, lams, pipelined)

        # ---- λ-chunk stream ---------------------------------------------
        f_idx = jnp.arange(k)
        chunk_fn = self._chunk_errors_fn(mesh)

        def dispatch(c):
            with self._stage_scope("fold_errors"):
                return chunk_fn(state, f_idx, h_tr, g_tr, folds.x_folds,
                                folds.y_folds, chunks[c], aux)

        # full pipelined sweeps keep one chunk of dispatch lookahead; the
        # early-stop search dispatches chunk-by-chunk (the decision is the
        # sync point), and the serial reference blocks on every stage
        lookahead = pipelined and stop_tol is None
        best = np.inf
        best_lam = float("nan")
        streak = 0
        n_eval = 0
        nxt = dispatch(0) if lookahead else None
        for c in range(n_c):
            e = nxt if nxt is not None else dispatch(c)
            nxt = dispatch(c + 1) if lookahead and c + 1 < n_c else None
            if not pipelined:
                jax.block_until_ready(e)
            width = min(chunk, q - c * chunk)
            fold_errs = np.asarray(e)[:, :width]    # syncs this chunk only
            mean = fold_errs.mean(0)
            finite = np.isfinite(mean)
            if not finite.all() and stop_tol is not None:
                # `mean[i] < best` is False for NaN, so a non-finite chunk
                # (singular fold, bf16 overflow) would silently feed the
                # non-improvement streak and the search could "stop" on a
                # curve it never actually ranked — refuse instead
                bad = lams_np[c * chunk + np.flatnonzero(~finite)]
                raise FloatingPointError(
                    f"non-finite hold-out mean at λ={bad[:4].tolist()} "
                    f"(chunk {c}): the early-stop search cannot rank "
                    "non-finite errors; fix the fold/precision (singular "
                    "fold? bf16 overflow → 'bf16_refined') or sweep the "
                    "full grid with stop_tol=None")
            n_eval += width
            if finite.any():
                # argmin over the FINITE entries only — np.argmin would
                # return the first NaN's index and poison best/best_lam
                i = int(np.flatnonzero(finite)[np.argmin(mean[finite])])
                improved = (bool(mean[i] < best * (1.0 - stop_tol))
                            if stop_tol is not None and np.isfinite(best)
                            else bool(mean[i] < best))
                if mean[i] < best:   # strict: ties keep the earlier λ,
                    best = float(mean[i])  # matching argmin on the full curve
                    best_lam = float(lams_np[c * chunk + i])
            else:
                improved = False    # an all-non-finite chunk never improves
            streak = 0 if improved else streak + 1
            stopped = (stop_tol is not None and streak >= stop_patience
                       and c + 1 < n_c)
            yield SweepChunk(
                index=c, start=c * chunk, n_chunks=n_c,
                lams=lams_np[c * chunk: c * chunk + width],
                fold_errors=fold_errs, errors=mean,
                best_lam=best_lam, best_error=float(best),
                stopped=stopped,
                n_exact_chol=0 if warm else strat.n_exact_chol(k, n_eval),
                cache=cache_info)
            if stopped:
                return
        if not np.isfinite(best):
            # the FINISHED stream ranked no finite λ (every chunk's mean
            # was NaN/inf — e.g. a singular fold poisons every λ).  With
            # early stopping this already raised mid-stream; without it the
            # old behavior was to silently yield best_lam=nan.  Refuse the
            # same way: the consumer has seen every partial curve by now,
            # but the sweep as a whole produced nothing rankable.
            raise FloatingPointError(
                "sweep finished with no finite hold-out mean at any λ "
                "(singular fold? overflow → try precision='bf16_refined' "
                "or fp64); refusing to report a nan λ* selection")

    def run_async(self, folds: FoldData, lams: jax.Array, *,
                  stop_tol: Optional[float] = None, stop_patience: int = 2,
                  pipelined: bool = True) -> CVResult:
        """Consume :meth:`sweep_async` into a :class:`CVResult`.

        With early stopping the result covers the evaluated prefix of the
        grid (``extras['engine']['async']`` records how far the stream ran
        and whether it stopped); without it this is the staged equivalent
        of :meth:`run`.
        """
        if self.tune:
            derived, cfg = self._tuned_engine(folds, lams)
            res = derived.run_async(folds, lams, stop_tol=stop_tol,
                                    stop_patience=stop_patience,
                                    pipelined=pipelined)
            res.extras["engine"]["tune"] = cfg.to_json()
            return res
        parts = list(self.sweep_async(folds, lams, stop_tol=stop_tol,
                                      stop_patience=stop_patience,
                                      pipelined=pipelined))
        last = parts[-1]
        errors = np.concatenate([p.errors for p in parts])
        lams_eval = np.concatenate([p.lams for p in parts])
        mesh = self._resolve_mesh(folds.fold_hess.shape[0])
        meta = dict(
            strategy=self.strategy.name, backend=self._bk.name,
            precision=self._prec.name,
            mesh=None if mesh is None else dict(mesh.shape),
            donated=bool(self.donate), lam_chunk=self.lam_chunk,
            cache=last.cache)
        meta["async"] = dict(
            pipelined=pipelined, stop_tol=stop_tol,
            stop_patience=stop_patience, stopped=last.stopped,
            chunks_evaluated=len(parts), chunks_total=last.n_chunks,
            lams_evaluated=int(errors.shape[0]))
        return CVResult.from_errors(lams_eval, errors, last.n_exact_chol,
                                    engine=meta)

    # -- adaptive λ-search -------------------------------------------------
    #
    # The dense grid spends one interp_solve per grid point whether or not
    # the point is informative; the search spends them where the hold-out
    # minimum actually is.  It reuses the staged sweep's machinery whole:
    # the state stage (cache dispatch included) runs ONCE over the grid's
    # λ range, then fixed-width refinement waves stream through the same
    # jitted chunk stage `sweep_async` uses — every wave has the same shape,
    # so the whole search compiles exactly one chunk signature, no matter
    # how many refinement levels it takes.

    def search(self, folds: FoldData, lams: jax.Array, *,
               wave: Optional[int] = None, tol_decades: float = 0.05,
               plateau_tol: Optional[float] = None,
               plateau_patience: int = 2, max_waves: int = 32,
               select_interp: bool = False,
               pipelined: bool = True) -> CVResult:
        """Adaptive λ-refinement search over the grid's range.

        Drop-in for :meth:`run`: takes the same dense candidate grid, but
        only its *range* (and density, as the comparison baseline) matter —
        instead of evaluating all q points, the search covers [λ_min,
        λ_max] with one coarse log-spaced wave of ``wave`` points, then
        repeatedly places ``wave`` new points strictly inside the bracket
        formed by the evaluated neighbors of the running minimum
        (trisection generalized to a batched wave: each level shrinks the
        bracket by ≈ 2/(wave+1)).  On a unimodal hold-out curve the final
        bracket contains the dense grid's argmin, so the returned λ* agrees
        with it to within the bracket width.

        Parameters
        ----------
        wave:         λ points per dispatch wave (default: the engine's
                      resolved λ-chunk, capped to 8, floored at 3 — every
                      wave reuses one jitted chunk-stage signature).  With
                      a mesh, padded up to the λ-axis multiple.
        tol_decades:  stop when the bracket around the minimum is narrower
                      than this many log₁₀-decades (default 0.05).
        plateau_tol:  optional error-plateau stop: after
                      ``plateau_patience`` consecutive waves in which the
                      best error improved by less than
                      ``best · plateau_tol`` (relative), stop.  ``None``
                      (default) disables it — interval width terminates.
        max_waves:    hard cap on refinement waves.
        select_interp: run :meth:`select_interpolant` first and search with
                      the chosen (degree, basis) — on a warm anchor cache
                      the selection performs zero factorizations; the
                      choice is recorded under
                      ``extras['engine']['interp_selection']``.

        A wave whose mean hold-out error is non-finite at *every* point
        raises ``FloatingPointError`` (same refusal as the early-stop
        sweep); partially-finite waves rank the finite points only.

        Composes unchanged with the cache (the state stage is acquired
        exactly like :meth:`sweep_async`: hit → zero factorizations, anchor
        refit, or cold populate *before* any wave runs), precision
        policies, mesh sharding, and ``tune='auto'``.  Returns a
        :class:`CVResult` over every evaluated λ (sorted), with the search
        trace under ``extras['engine']['search']``.
        """
        if tol_decades <= 0:
            raise ValueError(f"tol_decades must be > 0, got {tol_decades}")
        if plateau_tol is not None and plateau_tol < 0:
            raise ValueError(
                f"plateau_tol must be >= 0 or None, got {plateau_tol}")
        if plateau_patience < 1:
            raise ValueError(
                f"plateau_patience must be >= 1, got {plateau_patience}")
        if max_waves < 1:
            raise ValueError(f"max_waves must be >= 1, got {max_waves}")
        if self.tune:
            derived, cfg = self._tuned_engine(folds, lams)
            res = derived.search(
                folds, lams, wave=wave, tol_decades=tol_decades,
                plateau_tol=plateau_tol, plateau_patience=plateau_patience,
                max_waves=max_waves, select_interp=select_interp,
                pipelined=pipelined)
            res.extras["engine"]["tune"] = cfg.to_json()
            return res
        if select_interp:
            sel = self.select_interpolant(folds, lams)
            eng = self.with_interpolant(sel["degree"], sel["basis"])
            res = eng.search(
                folds, lams, wave=wave, tol_decades=tol_decades,
                plateau_tol=plateau_tol, plateau_patience=plateau_patience,
                max_waves=max_waves, select_interp=False,
                pipelined=pipelined)
            res.extras["engine"]["interp_selection"] = sel
            return res
        lams = self._check_lams(lams, min_q=2, what="adaptive λ-search")
        lams_np = np.asarray(lams)
        if np.any(lams_np <= 0):
            raise ValueError("adaptive λ-search refines over log-λ: "
                             "every grid value must be positive")
        k = folds.fold_hess.shape[0]
        q = int(lams.shape[0])
        h = folds.fold_hess.shape[-1]
        mesh = self._resolve_mesh(k)
        self._check_fold_axis(mesh, k)
        h_tr, g_tr = self._split(folds.hess, folds.grad,
                                 folds.fold_hess, folds.fold_grad)
        strat = self.strategy

        chunk = self._resolve_chunk(q, h, h_tr.dtype)
        if wave is None:
            w = max(3, min(8, chunk if chunk else 8))
        else:
            w = int(wave)
            if w < 3:
                raise ValueError(
                    f"wave must be >= 3 (a refinement wave needs interior "
                    f"points on both sides of the minimum), got {w}")
        if mesh is not None:
            w += (-w) % mesh.shape[shardlib.CV_LAM_AXIS]

        # state stage once, over the full λ range — identical cache
        # dispatch to sweep_async / run (hit → zero factorizations here)
        state, aux, warm, cache_info = self._staged_state_for(
            mesh, h_tr, g_tr, folds, lams, pipelined)

        f_idx = jnp.arange(k)
        chunk_fn = self._chunk_errors_fn(mesh)
        dtype = lams.dtype

        def eval_wave(xs):
            """Mean hold-out error at 10**xs — one fixed-shape dispatch."""
            lam_w = np.asarray(10.0 ** xs, dtype=dtype)
            with self._stage_scope("fold_errors"):
                e = chunk_fn(state, f_idx, h_tr, g_tr, folds.x_folds,
                             folds.y_folds, jnp.asarray(lam_w), aux)
            return lam_w, np.asarray(e).mean(0)

        lo = float(np.log10(lams_np.min()))
        hi = float(np.log10(lams_np.max()))
        xs_all = np.empty(0)
        lams_all = np.empty(0, dtype=lams_np.dtype)
        errs_all = np.empty(0)
        best = np.inf
        best_x = lo
        waves = 0
        streak = 0
        width = hi - lo
        stopped_on = "max_waves"
        next_xs = np.linspace(lo, hi, w)    # coarse wave spans the range
        while True:
            lam_w, mean = eval_wave(next_xs)
            waves += 1
            finite = np.isfinite(mean)
            if not finite.any():
                raise FloatingPointError(
                    f"adaptive λ-search wave {waves} produced no finite "
                    f"hold-out mean (λ∈[{lam_w.min():.3g}, "
                    f"{lam_w.max():.3g}]): cannot rank the bracket "
                    "(singular fold? overflow → 'bf16_refined'/fp64)")
            xs_all = np.concatenate([xs_all, next_xs])
            lams_all = np.concatenate([lams_all, lam_w])
            errs_all = np.concatenate([errs_all, mean])
            prev_best = best
            j = int(np.flatnonzero(finite)[np.argmin(mean[finite])])
            if mean[j] < best:
                best = float(mean[j])
                best_x = float(next_xs[j])
            improved = (bool(best < prev_best * (1.0 - plateau_tol))
                        if plateau_tol is not None and np.isfinite(prev_best)
                        else bool(best < prev_best))
            streak = 0 if improved else streak + 1
            # bracket: the evaluated neighbors of the running minimum
            order = np.argsort(xs_all)
            xs_sorted = xs_all[order]
            pos = int(np.searchsorted(xs_sorted, best_x))
            left = xs_sorted[pos - 1] if pos > 0 else xs_sorted[0]
            right = (xs_sorted[pos + 1] if pos + 1 < xs_sorted.shape[0]
                     else xs_sorted[-1])
            width = float(right - left)
            if width <= tol_decades:
                stopped_on = "interval"
                break
            if plateau_tol is not None and streak >= plateau_patience:
                stopped_on = "plateau"
                break
            if waves >= max_waves:
                break
            # next wave: w points strictly inside the bracket (log-spaced;
            # the endpoints are already evaluated, so nothing repeats)
            next_xs = np.linspace(left, right, w + 2)[1:-1]

        order = np.argsort(xs_all)
        n_eval = int(xs_all.shape[0])
        n_chol = 0 if warm else strat.n_exact_chol(k, n_eval)
        meta = dict(
            strategy=strat.name, backend=self._bk.name,
            precision=self._prec.name,
            mesh=None if mesh is None else dict(mesh.shape),
            donated=bool(self.donate), lam_chunk=self.lam_chunk,
            cache=cache_info)
        meta["search"] = dict(
            wave=w, waves=waves, lams_evaluated=n_eval, dense_q=q,
            evals_vs_grid=n_eval / q, tol_decades=tol_decades,
            plateau_tol=plateau_tol, plateau_patience=plateau_patience,
            interval_decades=width, stopped_on=stopped_on)
        return CVResult.from_errors(lams_all[order], errs_all[order],
                                    n_chol, engine=meta)

    # -- self-tuning interpolation ----------------------------------------

    def with_interpolant(self, degree: int, basis: str) -> "CVEngine":
        """Derived engine running this engine's piCholesky strategy at a
        different (degree, basis) — shares the cache, backend, precision
        and tuning cache, memoized per choice so its jit caches warm up
        like any engine's.  Same anchors ⇒ on a cache with
        ``cache_anchors`` the derived engine's first sweep refits Θ from
        the cached anchor targets with zero factorizations."""
        strat = self.strategy
        if not isinstance(strat, PiCholeskyStrategy):
            raise ValueError(
                "with_interpolant needs the picholesky strategy, got "
                f"{getattr(strat, 'name', strat)!r}")
        key = (int(degree), str(basis))
        if key == (strat.degree, strat.basis):
            return self
        if key not in self._interp_engines:
            self._interp_engines[key] = CVEngine(
                strategy=dataclasses.replace(strat, degree=key[0],
                                             basis=key[1]),
                backend=self._bk, mesh=self.mesh, donate=self.donate,
                block=self.block, lam_chunk=self.lam_chunk,
                cache=self.cache, reuse=self.reuse,
                cache_anchors=self.cache_anchors,
                tune=False, tune_cache=self.tune_cache)
        return self._interp_engines[key]

    def _anchor_targets_fn(self):
        """Jitted (k, g, P) anchor-factorize stage for interpolant
        selection: per fold, Cholesky at each anchor shift, tile-packed.
        The anchor Hessian goes through the strategy's ``anchor_hessian``
        hook, so sketched strategies select against the sketched targets
        the sweep will actually fit."""
        if self._anchor_targets is None:
            strat, bk = self.strategy, self._bk

            def targets(h_tr, anchors, x_folds):
                def per_fold(f, h_f):
                    h_eff = strat.anchor_hessian(f, h_f, x_folds, bk)
                    eye = jnp.eye(h_eff.shape[-1], dtype=h_eff.dtype)
                    factors = jax.vmap(
                        lambda lam: bk.cholesky(h_eff + lam * eye))(anchors)
                    return bk.pack_tril(factors, strat.block)
                return jax.vmap(per_fold)(jnp.arange(h_tr.shape[0]), h_tr)

            self._anchor_targets = jax.jit(targets)
        return self._anchor_targets

    def select_interpolant(self, folds: FoldData, lams: jax.Array, *,
                           degrees=None,
                           bases=("monomial", "centered")) -> dict:
        """Choose the interpolant (degree, basis) by leave-one-anchor-out
        CV against the packed anchor targets
        (:func:`~repro.core.picholesky.select_interpolant`).

        The anchor targets come from the factor cache when its anchor
        fingerprint matches (``cache_anchors=`` entries are degree/basis-
        independent) — **zero factorizations** in that case; otherwise the
        g anchor factorizations run once here and, with ``cache_anchors``,
        are parked as an anchors-only cache entry so the sweep that follows
        (whatever degree won) refits from them without factorizing either.
        Every candidate score after that is GEMMs only.

        Returns the :func:`~repro.core.picholesky.select_interpolant` dict
        plus ``anchor_status`` ∈ {'anchors' (cache hit), 'cold',
        'cold+cached'} and the anchor grid.
        """
        strat, bk = self.strategy, self._bk
        if not isinstance(strat, PiCholeskyStrategy):
            raise ValueError(
                "interpolant selection needs the picholesky strategy, got "
                f"{getattr(strat, 'name', strat)!r}")
        lams = self._check_lams(lams, min_q=2, what="interpolant selection")
        anchors = _sample_grid(lams, strat.g)
        h_tr, _ = self._split(folds.hess, folds.grad,
                              folds.fold_hess, folds.fold_grad)
        meta = strat.cache_meta(lams)
        key = None
        if self.cache is not None and meta is not None:
            key = cachelib.make_key(
                h_tr, meta["anchors"], block=strat.block, backend=bk.name,
                params=meta["params"], precision=self._prec.descriptor(),
                sketch=meta.get("sketch", "exact"))
        pf = (self.cache.get_anchors(key)
              if key is not None and self.reuse else None)
        status = "anchors"
        if pf is None:
            with self._stage_scope("fold_state"):
                vec = self._anchor_targets_fn()(h_tr, anchors,
                                                folds.x_folds)
            vec = vec.astype(self._prec.store_dtype(vec.dtype))
            pf = packing.PackedFactor(vec=vec, h=int(h_tr.shape[-1]),
                                      block=strat.block)
            status = "cold"
            if key is not None and self.cache_anchors:
                self.cache.put(key, None, pf)   # anchors-only entry
                status = "cold+cached"
        sel = picholesky.select_interpolant(jnp.asarray(pf.vec), anchors,
                                            degrees, bases=bases, backend=bk)
        sel["anchor_status"] = status
        sel["g"] = strat.g
        sel["anchors"] = np.asarray(anchors).tolist()
        return sel

    def advise_anchor(self, folds: FoldData, lams: jax.Array, *,
                      probe_dim: int = 32, n_grid: int = 5) -> dict:
        """Bound-guided anchor placement: score the strategy's anchor
        intervals with the Thm 4.4 machinery
        (:func:`~repro.core.bound.anchor_advisor`) and propose the next
        anchor at the log-midpoint of the weakest interval.

        The bound operators are exact but O(d⁶) (M is d²×d²), so the
        advisor works on a **probe**: the leading ``probe_dim`` principal
        submatrix of the fold-mean training Hessian.  That makes the
        advice a documented heuristic — it guides anchor *placement*,
        it never enters the sweep math.
        """
        strat = self.strategy
        g = getattr(strat, "g", None)
        if g is None:
            raise ValueError(
                "anchor advice needs an anchored interpolant strategy "
                f"(with g sample shifts); {getattr(strat, 'name', strat)!r} "
                "has none")
        lams = self._check_lams(lams, min_q=2, what="anchor advisor")
        from . import bound
        anchors = _sample_grid(lams, g)
        h_tr, _ = self._split(folds.hess, folds.grad,
                              folds.fold_hess, folds.fold_grad)
        d = min(int(probe_dim), int(h_tr.shape[-1]))
        probe = jnp.mean(h_tr, axis=0)[:d, :d]
        out = bound.anchor_advisor(probe, np.asarray(anchors), n_grid=n_grid)
        out["probe_dim"] = d
        out["anchors"] = np.asarray(anchors).tolist()
        return out

    # -- public API -------------------------------------------------------

    def sweep_temp_bytes(self, folds: FoldData, lams: jax.Array) -> int:
        """Live-buffer proxy for the jitted (unsharded) sweep: XLA temp
        allocation in bytes, excluding inputs/outputs.

        This is the measurable form of the O(chunk · P) memory contract —
        the packed-pipeline acceptance test and the committed
        ``BENCH_table3.json`` record both read it, so there is exactly one
        definition of "the sweep's peak memory".
        """
        lams = jnp.asarray(lams)
        h_tr, g_tr = self._split(folds.hess, folds.grad, folds.fold_hess,
                                 folds.fold_grad)
        lowered = self._sweep_fn(None).lower(h_tr, g_tr, folds.x_folds,
                                             folds.y_folds, lams)
        return int(lowered.compile().memory_analysis().temp_size_in_bytes)

    def replay_temp_bytes(self, folds: FoldData, lams: jax.Array) -> int:
        """XLA temp bytes of the λ-stream (replay) stage alone, from a
        fitted state — the policy-governed O(chunk · P) working set without
        the ``fold_state`` factorization buffers.  This is the quantity the
        precision policy's storage dtype halves (the committed
        ``precision_sweep`` bench record reads it), measured the same way
        as :meth:`sweep_temp_bytes`."""
        lams = jnp.asarray(lams)
        h_tr, g_tr = self._split(folds.hess, folds.grad, folds.fold_hess,
                                 folds.fold_grad)
        state, _ = self._state_fn(None, False)(
            h_tr, g_tr, folds.x_folds, folds.y_folds, lams)
        lowered = self._replay_fn(None).lower(
            state, h_tr, g_tr, folds.x_folds, folds.y_folds, lams)
        return int(lowered.compile().memory_analysis().temp_size_in_bytes)

    def _acquire_cached_state(self, meta: dict, key, cold_state_fn):
        """Cache dispatch shared by :meth:`run` and :meth:`sweep_async`:
        fingerprint → (hit | anchor refit | cold populate).

        ``cold_state_fn(with_anchors)`` computes the batched cold state,
        returning ``(state, packed_anchors | None)``.  Returns
        ``(entry, status)``.
        """
        strat, cache = self.strategy, self.cache
        if self.reuse:
            entry = cache.lookup(key, self.reuse)
        else:
            entry = None
            cache.misses += 1     # write-only runs are misses by definition
        status = "hit"
        if entry is None:
            with_anchors = (self.cache_anchors
                            and hasattr(strat, "fold_state_and_anchors"))
            cached_pf = (cache.get_anchors(key)
                         if self.reuse and with_anchors else None)
            if cached_pf is not None:
                # same anchor factors, different polynomial: refit Θ from
                # the cached packed targets — still zero factorizations
                state = self._refit_from_anchors(cached_pf, meta)
                entry = cache.put(key, state, cached_pf)
                status = "refit"
            else:
                state, pf = cold_state_fn(with_anchors)
                entry = cache.put(key, state, pf)
                status = "miss"
        return entry, status

    def _run_cached(self, meta: dict, mesh, h_tr, g_tr, folds: FoldData,
                    lams_run: jax.Array, q: int):
        """Warm-replay dispatch: fingerprint → (hit | anchor refit | cold
        populate) → replay.  Returns (error grid, cache_info, n_chol)."""
        key = cachelib.make_key(
            h_tr, meta["anchors"], block=meta["params"]["block"],
            backend=self._bk.name, params=meta["params"],
            precision=self._prec.descriptor(),
            sketch=meta.get("sketch", "exact"))
        k = h_tr.shape[0]

        def cold_state(with_anchors):
            state, avec = self._state_fn(mesh, with_anchors)(
                h_tr, g_tr, folds.x_folds, folds.y_folds, lams_run)
            pf = (packing.PackedFactor(vec=avec, h=h_tr.shape[-1],
                                       block=meta["params"]["block"])
                  if with_anchors else None)
            return state, pf

        entry, status = self._acquire_cached_state(meta, key, cold_state)
        n_chol = (self.strategy.n_exact_chol(k, q) if status == "miss" else 0)
        errs = self._replay_fn(mesh)(entry.state, h_tr, g_tr, folds.x_folds,
                                     folds.y_folds, lams_run)
        # digest of the entry actually SERVED (≠ the requested key's under
        # a covering hit), so results are attributable to their Θ
        info = dict(status=status, digest=entry.key.digest()[:12],
                    policy=self.reuse, **self.cache.stats)
        return errs, info, n_chol

    def run(self, folds: FoldData, lams: jax.Array) -> CVResult:
        if self.tune:
            derived, cfg = self._tuned_engine(folds, lams)
            res = derived.run(folds, lams)
            res.extras["engine"]["tune"] = cfg.to_json()
            return res
        lams = self._check_lams(lams)
        k = folds.fold_hess.shape[0]
        q = lams.shape[0]
        mesh = self._resolve_mesh(k)
        self._check_fold_axis(mesh, k)
        if mesh is not None:
            lams_run, _ = shardlib.pad_to_multiple(
                lams, mesh.shape[shardlib.CV_LAM_AXIS])
        else:
            lams_run = lams

        # engine-owned train-stat buffers: safe to donate into the sweep
        h_tr, g_tr = self._split(folds.hess, folds.grad,
                                 folds.fold_hess, folds.fold_grad)
        meta = (self.strategy.cache_meta(lams)
                if self.cache is not None
                and hasattr(self.strategy, "cache_meta") else None)
        if meta is not None:
            errs, cache_info, n_chol = self._run_cached(
                meta, mesh, h_tr, g_tr, folds, lams_run, q)
        else:
            errs = self._sweep_fn(mesh)(h_tr, g_tr, folds.x_folds,
                                        folds.y_folds, lams_run)
            cache_info = (None if self.cache is None
                          else dict(status="bypass"))
            n_chol = self.strategy.n_exact_chol(k, q)
        errs = np.asarray(errs)[:, :q]
        return CVResult.from_errors(
            lams, errs.mean(0), n_chol,
            engine=dict(
                strategy=self.strategy.name, backend=self._bk.name,
                precision=self._prec.name,
                mesh=None if mesh is None else dict(mesh.shape),
                donated=bool(self.donate), lam_chunk=self.lam_chunk,
                cache=cache_info))

    # -- batched admission (multi-tenant serving) ---------------------------

    def _cache_scope(self, tenant: Optional[str]):
        """Tenant-attribution scope on the attached cache (no-op without
        one) — the serving layer's per-tenant hit-rate partitioning."""
        if self.cache is None or tenant is None:
            return contextlib.nullcontext()
        return self.cache.tenant_scope(tenant)

    def run_batch(self, problems, *, tenants=None):
        """Admission-batched sweep: N compatible CV problems, ONE stacked
        ``fold_state`` dispatch, per-problem λ streams — the multi-tenant
        serving entry point (:mod:`repro.serving`).

        ``problems`` is a sequence of ``(FoldData, lams)`` pairs;
        ``tenants`` an optional parallel sequence of tenant labels for the
        cache's per-tenant stat partitioning.  Returns one
        :class:`~repro.core.folds.CVResult` per problem, in order, each
        bit-for-bit equal to what a solo :meth:`run` of that problem
        against the same cache state would produce (the per-fold math is
        identical — stacking reorders *batching*, never arithmetic).

        Dispatch per problem: content fingerprint → cache hit (λ stream
        only) | anchor refit | cold.  All the batch's cold problems are
        concatenated along the fold axis and factorized in **one** batched
        ``fold_state`` call, then sliced back and cached under their own
        per-problem keys — so cross-tenant sharing still works request-by-
        request afterwards.  A problem whose fingerprint duplicates an
        earlier problem *in the same batch* is looked up again after the
        cold stage populates, and served as a genuine hit.

        The fused stacking path engages when every problem shares the fold
        geometry (h, n_f, dtype), derives the same anchor set, the strategy
        advertises ``batchable_state`` (and ``cache_meta``), a cache is
        attached, and no mesh is configured; otherwise the batch degrades
        gracefully to per-problem :meth:`run` calls (same results, no
        stacked dispatch).
        """
        problems = [(f, self._check_lams(l)) for f, l in problems]
        if tenants is None:
            tenants = [None] * len(problems)
        if len(tenants) != len(problems):
            raise ValueError(f"{len(tenants)} tenant labels for "
                             f"{len(problems)} problems")
        if not problems:
            return []
        if self.tune:
            # admission groups share a geometry (the server's admission
            # key), so one tune on the batch head covers the batch
            derived, cfg = self._tuned_engine(*problems[0])
            results = derived.run_batch(problems, tenants=tenants)
            for r in results:
                r.extras["engine"]["tune"] = cfg.to_json()
            return results
        strat = self.strategy
        metas = [strat.cache_meta(l) if hasattr(strat, "cache_meta") else None
                 for _, l in problems]
        fusable = (self.cache is not None and self.reuse is not False
                   and self.mesh is None
                   and getattr(strat, "batchable_state", False)
                   and all(m is not None for m in metas))
        if fusable:
            a0 = np.asarray(metas[0]["anchors"])
            f0 = problems[0][0]
            fusable = all(
                np.array_equal(np.asarray(m["anchors"]), a0)
                and f.fold_hess.shape[1:] == f0.fold_hess.shape[1:]
                and f.x_folds.shape[1:] == f0.x_folds.shape[1:]
                and f.fold_hess.dtype == f0.fold_hess.dtype
                for (f, _), m in zip(problems, metas))
        if not fusable:
            # incompatible admission: same cache/engine, per-problem runs
            out = []
            for (f, l), t in zip(problems, tenants):
                with self._cache_scope(t):
                    out.append(self.run(f, l))
            return out

        cache = self.cache
        splits = [self._split(f.hess, f.grad, f.fold_hess, f.fold_grad)
                  for f, _ in problems]
        keys = [cachelib.make_key(
            h_tr, m["anchors"], block=m["params"]["block"],
            backend=self._bk.name, params=m["params"],
            precision=self._prec.descriptor(),
            sketch=m.get("sketch", "exact"))
            for (h_tr, _), m in zip(splits, metas)]
        with_anchors = (self.cache_anchors
                        and hasattr(strat, "fold_state_and_anchors"))

        # pass 1 — fingerprint lookup; first occurrence of each digest
        # resolves now, duplicates defer until the cold stage has populated
        n = len(problems)
        entries: list = [None] * n
        statuses: list = [None] * n
        first_of: dict = {}
        cold_idx: list = []
        for i, key in enumerate(keys):
            digest = key.digest()
            if digest in first_of:
                continue                      # deferred to pass 3
            first_of[digest] = i
            with self._cache_scope(tenants[i]):
                entry = cache.lookup(key, self.reuse)
                if entry is not None:
                    entries[i], statuses[i] = entry, "hit"
                    continue
                pf = (cache.get_anchors(key)
                      if with_anchors else None)
            if pf is not None:
                state = self._refit_from_anchors(pf, metas[i])
                with self._cache_scope(tenants[i]):
                    entries[i] = cache.put(key, state, pf)
                statuses[i] = "refit"
            else:
                cold_idx.append(i)

        # pass 2 — ONE stacked fold_state dispatch for every cold problem
        if cold_idx:
            h_stack = jnp.concatenate([splits[i][0] for i in cold_idx])
            g_stack = jnp.concatenate([splits[i][1] for i in cold_idx])
            x_stack = jnp.concatenate(
                [problems[i][0].x_folds for i in cold_idx])
            y_stack = jnp.concatenate(
                [problems[i][0].y_folds for i in cold_idx])
            with self._stage_scope("fold_state"):
                state, avec = self._state_fn(None, with_anchors)(
                    h_stack, g_stack, x_stack, y_stack,
                    problems[cold_idx[0]][1])
            off = 0
            for i in cold_idx:
                k_i = splits[i][0].shape[0]
                st_i = jax.tree.map(lambda x: x[off:off + k_i], state)
                pf_i = (packing.PackedFactor(
                    vec=avec[off:off + k_i], h=splits[i][0].shape[-1],
                    block=metas[i]["params"]["block"])
                    if with_anchors else None)
                off += k_i
                with self._cache_scope(tenants[i]):
                    entries[i] = cache.put(keys[i], st_i, pf_i)
                statuses[i] = "miss"

        # pass 3 — in-batch duplicates are genuine hits now.  If LRU
        # pressure already evicted the first occurrence's entry, its
        # in-memory object is still referenced in `entries` — serve from
        # that (the miss the lookup just counted is accurate: the cache
        # no longer holds it).
        for i, key in enumerate(keys):
            if entries[i] is not None:
                continue
            with self._cache_scope(tenants[i]):
                entry = cache.lookup(key, self.reuse)
            entries[i] = entry if entry is not None \
                else entries[first_of[key.digest()]]
            statuses[i] = "hit"

        # λ streams — per problem (grids differ), through the shared
        # chunked replay stage; O(chunk · P) as everywhere else
        replay = self._replay_fn(None)
        results = []
        for i, ((folds_i, lams_i), (h_tr, g_tr)) in enumerate(
                zip(problems, splits)):
            with self._stage_scope("fold_errors"):
                errs = replay(entries[i].state, h_tr, g_tr, folds_i.x_folds,
                              folds_i.y_folds, lams_i)
            k_i, q_i = h_tr.shape[0], int(lams_i.shape[0])
            n_chol = (strat.n_exact_chol(k_i, q_i)
                      if statuses[i] == "miss" else 0)
            info = dict(status=statuses[i],
                        digest=entries[i].key.digest()[:12],
                        policy=self.reuse, tenant=tenants[i], **cache.stats)
            results.append(CVResult.from_errors(
                lams_i, np.asarray(errs).mean(0), n_chol,
                engine=dict(strategy=strat.name, backend=self._bk.name,
                            precision=self._prec.name, mesh=None,
                            donated=bool(self.donate),
                            lam_chunk=self.lam_chunk, cache=info,
                            batch=dict(size=n, index=i,
                                       cold=len(cold_idx)))))
        return results
