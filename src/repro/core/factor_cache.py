"""Warm-replay factor cache: reuse fitted Θ / packed anchors across sweeps.

The paper's premise is that factorization over the λ grid dominates CV cost;
once the anchor Cholesky factors are fitted, the interpolant Θ — (r+1, P),
q-independent — answers *any* later grid over the same anchor range at zero
factorization cost.  This module is that seam made concrete: a content-
addressed cache of per-fold fitted :class:`~repro.core.picholesky.PiCholesky`
states (and optionally the per-(fold, λ_s) packed anchor factors), consumed
by :class:`~repro.core.engine.CVEngine` via its ``cache=`` / ``reuse=``
wiring.  On a hit the engine skips ``fold_state`` entirely and replays the
sweep through the fused ``interp_solve`` chunked stream.

Keying — a :class:`CacheKey` is a content fingerprint, never an object id:

* ``fold_hashes``   sha256 of each fold's training Hessian (shape + dtype
                    + bytes), so a perturbed problem can never hit,
* ``anchors``       the anchor-λ grid the fit factorized at,
* ``h, block``      packed-layout geometry,
* ``dtype``         of the training Hessians,
* ``backend``       name of the :class:`~repro.core.backends.LinalgBackend`
                    that produced the factors,
* ``params``        the strategy's static fit parameters (degree, basis, …),
* ``precision``     the :class:`~repro.core.precision.PrecisionPolicy`
                    descriptor the state was fitted/stored under — a bf16
                    entry can never silently serve an fp32 request,
* ``sketch``        how the anchor factors were *produced*
                    (:meth:`~repro.core.sketch.SketchPlan.descriptor`, a
                    low-rank descriptor, or ``'exact'``) — a sketched or
                    rank-truncated factor can never silently serve an
                    exact request, on any of the three lookup routes.

Three derived digests serve three lookups:

* :meth:`CacheKey.digest`        — exact hit (everything matches),
* :meth:`CacheKey.base_digest`   — everything but the anchor grid; the
  ``'covering'`` reuse policy accepts a cached Θ whose anchor range covers
  the requested grid,
* :meth:`CacheKey.anchor_digest` — only what the anchor *factors* depend on
  (Hessians, anchor λs, geometry, dtype, backend); a Θ miss with an anchor
  hit refits the polynomial from the cached
  :class:`~repro.core.packing.PackedFactor` targets without factorizing.

Persistence goes through :class:`~repro.checkpoint.CheckpointManager`
(Θ and PackedFactor are already pytrees): each entry is one checkpoint step
plus an ``index.json`` sidecar recording the key and leaf specs, so caches
survive across processes and torn writes are skipped on load.

Service-shaped deployments bound residency with ``FactorCache(max_bytes=)``
— a byte-budget LRU over the entries' array payload (eviction counters in
:attr:`FactorCache.stats`); an evicted entry can only miss and repopulate,
never serve stale.  Population is stage-aligned with the engine's pipelined
sweep: the entry is written as soon as the ``fold_state`` stage completes,
*before* the λ stream starts, so an early-stopped sweep
(:meth:`~repro.core.engine.CVEngine.sweep_async` with ``stop_tol=``) still
leaves a complete, replayable entry — Θ is λ-grid independent; only the
curve evaluation is truncated.
"""
from __future__ import annotations

import contextlib
import dataclasses
import hashlib
import json
import os
import shutil
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.checkpoint import CheckpointManager

from . import packing, picholesky, solvers

__all__ = ["CacheKey", "CacheEntry", "FactorCache", "array_hash",
           "hessian_fingerprint", "make_key", "INDEX_FILENAME"]


INDEX_FILENAME = "index.json"

#: Relative slack when testing whether a cached anchor range covers a
#: requested λ range under the ``'covering'`` reuse policy — exactly the
#: float noise of recomputing grid endpoints, not a semantic tolerance.
COVER_RTOL = 1e-12


def array_hash(arr) -> str:
    """sha256 of an array's shape + dtype + raw bytes (host transfer)."""
    a = np.ascontiguousarray(np.asarray(arr))
    h = hashlib.sha256()
    h.update(str(a.shape).encode())
    h.update(str(a.dtype).encode())
    h.update(a.tobytes())
    return h.hexdigest()


def hessian_fingerprint(h_tr) -> Tuple[str, ...]:
    """Per-fold content hash of the (k, h, h) training-Hessian stack."""
    a = np.asarray(h_tr)
    if a.ndim != 3:
        raise ValueError(f"expected (k, h, h) fold Hessians, got {a.shape}")
    return tuple(array_hash(f) for f in a)


def _digest(payload: dict) -> str:
    return hashlib.sha256(
        json.dumps(payload, sort_keys=True).encode()).hexdigest()


@dataclasses.dataclass(frozen=True)
class CacheKey:
    """Content fingerprint of one fitted fold×anchor state (see module doc)."""

    fold_hashes: Tuple[str, ...]
    anchors: Tuple[float, ...]
    h: int
    block: int
    dtype: str
    backend: str
    params: Tuple[Tuple[str, Any], ...]
    precision: str = "native"
    #: anchor-production descriptor — ``'exact'`` for dense Cholesky,
    #: ``SketchPlan.descriptor()`` for sketched anchors, ``'lowrank/r…'``
    #: for the low-rank path.  A first-class field (not a ``params``
    #: entry) so :meth:`anchor_digest` — which deletes ``params`` for
    #: degree/basis-independent anchor reuse — still separates sketched
    #: from exact factors.
    sketch: str = "exact"

    def _payload(self) -> dict:
        return dict(fold_hashes=list(self.fold_hashes),
                    anchors=list(self.anchors), h=self.h, block=self.block,
                    dtype=self.dtype, backend=self.backend,
                    params=[list(p) for p in self.params],
                    precision=self.precision, sketch=self.sketch)

    def digest(self) -> str:
        return _digest(self._payload())

    def base_digest(self) -> str:
        p = self._payload()
        del p["anchors"]
        return _digest(p)

    def anchor_digest(self) -> str:
        """What the anchor *factors* L_s = chol(H_f + λ_s I) depend on —
        independent of the polynomial degree/basis, so cached anchors can
        re-fit a different interpolant without any factorization."""
        p = self._payload()
        del p["params"]
        return _digest(p)

    def to_json(self) -> dict:
        return self._payload()

    @classmethod
    def from_json(cls, rec: dict) -> "CacheKey":
        return cls(fold_hashes=tuple(rec["fold_hashes"]),
                   anchors=tuple(float(a) for a in rec["anchors"]),
                   h=int(rec["h"]), block=int(rec["block"]),
                   dtype=str(rec["dtype"]), backend=str(rec["backend"]),
                   params=tuple((str(k), v) for k, v in rec["params"]),
                   precision=str(rec.get("precision", "native")),
                   sketch=str(rec.get("sketch", "exact")))


def make_key(h_tr, anchors, *, block: int, backend: str,
             params: Dict[str, Any], precision: str = "native",
             sketch: str = "exact") -> CacheKey:
    """Fingerprint a sweep's λ-independent inputs.

    ``h_tr``: (k, h, h) per-fold training Hessians (hashed on host — one
    device sync per ``run``, the price of content addressing).
    ``anchors``: the anchor-λ grid the fit would factorize at.
    ``params``: the strategy's static fit parameters (degree, basis, g, …).
    ``precision``: the policy descriptor the state is fitted/stored under
    (:meth:`~repro.core.precision.PrecisionPolicy.descriptor`).
    ``sketch``: the anchor-production descriptor (``'exact'`` | a
    :meth:`~repro.core.sketch.SketchPlan.descriptor` | ``'lowrank/r…'``).
    """
    h_tr = np.asarray(h_tr)
    return CacheKey(
        fold_hashes=hessian_fingerprint(h_tr),
        anchors=tuple(float(a) for a in np.asarray(anchors).ravel()),
        h=int(h_tr.shape[-1]), block=int(block),
        dtype=str(h_tr.dtype), backend=str(backend),
        params=tuple(sorted(params.items())),
        precision=str(precision), sketch=str(sketch))


def _tree_nbytes(tree) -> int:
    """Total bytes of every array leaf (aval-based — never syncs a
    device buffer that is still being computed).  Reflects the leaves'
    *actual* dtypes — a post-``astype`` bf16 state counts its bf16 bytes,
    so ``max_bytes`` LRU budgets stay honest under mixed precision."""
    total = 0
    for leaf in jax.tree.leaves(tree):
        nbytes = getattr(leaf, "nbytes", None)
        total += int(nbytes if nbytes is not None
                     else np.asarray(leaf).nbytes)
    return total


def _tree_nbytes_at(tree, dtype) -> int:
    """What the same leaves would weigh if every float leaf were stored at
    ``dtype`` — the baseline the ``bytes_saved`` counter compares against
    (the training-Hessian dtype the problem arrived in)."""
    import jax.numpy as jnp
    item = np.dtype(dtype).itemsize
    total = 0
    for leaf in jax.tree.leaves(tree):
        a_dt = getattr(leaf, "dtype", None)
        size = int(getattr(leaf, "size", np.asarray(leaf).size))
        if a_dt is not None and jnp.issubdtype(a_dt, jnp.inexact):
            total += size * item
        else:
            total += size * np.dtype(a_dt or np.float64).itemsize
    return total


@dataclasses.dataclass
class CacheEntry:
    """One cached fit: the batched-over-folds Θ state, and optionally the
    per-(fold, λ_s) tile-packed anchor factors that produced it.

    ``state=None`` marks an **anchors-only** entry: the interpolant
    selection path (:meth:`~repro.core.engine.CVEngine.select_interpolant`)
    factorizes the anchors before any Θ has been fitted and parks them
    here so whichever (degree, basis) the caller settles on refits with
    zero factorizations.  Such entries serve :meth:`FactorCache.get_anchors`
    but can never satisfy a state ``lookup``."""

    key: CacheKey
    #: fitted per-fold state: a :class:`~repro.core.picholesky.PiCholesky`
    #: (theta (k, r+1, P), center (k,)) or, for the low-rank strategy, a
    #: :class:`~repro.core.solvers.LowRankFactors` (vt (k, r, h), evals
    #: (k, r)).  ``None`` marks an anchors-only entry.
    state: Optional[Any]
    anchors: Optional[packing.PackedFactor] = None   # vec (k, g, P)
    hits: int = 0
    nbytes: int = 0                       # array payload (state + anchors),
    #                                       at the leaves' POST-astype dtypes
    bytes_saved: int = 0                  # vs storing at the Hessian dtype
    last_used: int = 0                    # LRU clock tick of last touch


class FactorCache:
    """In-memory, content-addressed store of fitted interpolant states.

    ``lookup`` policies:

    * ``'exact'``    — the full :meth:`CacheKey.digest` must match (the
      requested grid derives the same anchor set the entry was fitted on).
    * ``'covering'`` — accept any entry matching on :meth:`base_digest`
      whose anchor range covers the requested range (the cached Θ answers
      the sub-range, at the wider fit's interpolation accuracy).

    ``max_bytes`` bounds the resident array payload for service-shaped
    deployments: every write evicts least-recently-used entries (the LRU
    clock ticks on hits, anchor reads, and writes) until the total fits
    the budget.  The entry being written always survives — a cache whose
    budget is smaller than one entry degrades to capacity one, never to
    refusing writes.  Eviction is invalidation-safe by construction: an
    evicted digest simply misses and repopulates (all lookup indexes are
    purged with the entry), so a stale hit is impossible.

    Counters (``hits`` / ``misses`` / ``anchor_hits`` / ``evictions`` /
    ``bytes_saved``) are cumulative over the cache's lifetime — eviction
    never rewrites history (the *resident* saving is the separate
    :attr:`live_bytes_saved`); tests and the warm-vs-cold bench read them
    via :attr:`stats`.

    Multi-tenant deployments partition the read/write counters per tenant
    with :meth:`tenant_scope`: every ``lookup`` / ``get_anchors`` / ``put``
    inside the scope is also attributed to that tenant's row in
    :attr:`tenant_stats`.  Attribution is bookkeeping only — the *entries*
    are deliberately shared (cross-tenant reuse is the serving layer's
    whole hit-rate story), and content addressing already guarantees a
    tenant can never read a state its own bytes did not fingerprint.
    """

    def __init__(self, max_bytes: Optional[int] = None):
        if max_bytes is not None and max_bytes <= 0:
            raise ValueError(f"max_bytes must be positive or None, "
                             f"got {max_bytes}")
        self.max_bytes = max_bytes
        self.entries: Dict[str, CacheEntry] = {}
        self._by_base: Dict[str, List[str]] = {}
        self._by_anchor: Dict[str, str] = {}
        self.hits = 0
        self.misses = 0
        self.anchor_hits = 0
        self.evictions = 0
        #: cumulative bytes mixed-precision storage has saved across every
        #: ``put`` over the cache's lifetime (NOT shrunk by eviction — the
        #: old live-entries-only accounting made an eviction retroactively
        #: rewrite the reported saving)
        self.bytes_saved = 0
        self.tenant_stats: Dict[str, Dict[str, int]] = {}
        self._tenant: Optional[str] = None
        self._tick = 0

    def __len__(self) -> int:
        return len(self.entries)

    @property
    def total_bytes(self) -> int:
        return sum(e.nbytes for e in self.entries.values())

    @property
    def live_bytes_saved(self) -> int:
        """Bytes mixed-precision storage is saving *right now* vs keeping
        every resident entry at its problem's (training-Hessian) dtype —
        shrinks when a reduced-precision entry is evicted, unlike the
        cumulative :attr:`bytes_saved` counter."""
        return sum(e.bytes_saved for e in self.entries.values())

    @property
    def stats(self) -> dict:
        return dict(entries=len(self.entries), hits=self.hits,
                    misses=self.misses, anchor_hits=self.anchor_hits,
                    evictions=self.evictions, bytes=self.total_bytes,
                    bytes_saved=self.bytes_saved,
                    live_bytes_saved=self.live_bytes_saved,
                    max_bytes=self.max_bytes)

    # ------------------------------------------------- per-tenant counters

    @contextlib.contextmanager
    def tenant_scope(self, tenant: Optional[str]):
        """Attribute every cache operation inside the scope to ``tenant``'s
        partition of the counters (``None`` = unattributed).  Scopes nest;
        the innermost wins — the engine's batched-admission path switches
        the scope per problem while the entries stay shared."""
        prev, self._tenant = self._tenant, tenant
        try:
            yield self
        finally:
            self._tenant = prev

    def _tenant_count(self, field: str, amount: int = 1) -> None:
        if self._tenant is None:
            return
        rec = self.tenant_stats.setdefault(
            self._tenant, dict(hits=0, misses=0, anchor_hits=0, puts=0))
        rec[field] += amount

    def hit_rate(self, tenant: Optional[str] = None) -> float:
        """hits / (hits + misses), overall or for one tenant's partition."""
        if tenant is None:
            hits, misses = self.hits, self.misses
        else:
            rec = self.tenant_stats.get(
                tenant, dict(hits=0, misses=0))
            hits, misses = rec["hits"], rec["misses"]
        total = hits + misses
        return hits / total if total else 0.0

    def _touch(self, entry: CacheEntry) -> None:
        self._tick += 1
        entry.last_used = self._tick

    # ---------------------------------------------------------------- read

    def lookup(self, key: CacheKey, policy: str = "exact"
               ) -> Optional[CacheEntry]:
        if policy not in ("exact", "covering"):
            raise ValueError(f"unknown reuse policy {policy!r}; "
                             "expected 'exact' or 'covering'")
        entry = self.entries.get(key.digest())
        if entry is not None and entry.state is None:
            entry = None        # anchors-only entry: no Θ to serve
        if entry is None and policy == "covering" and key.anchors:
            lo, hi = min(key.anchors), max(key.anchors)
            best_width = None
            for digest in self._by_base.get(key.base_digest(), ()):
                cand = self.entries[digest]
                if cand.state is None:
                    continue    # anchors-only — cannot cover a state read
                c_lo, c_hi = min(cand.key.anchors), max(cand.key.anchors)
                if (c_lo <= lo + abs(lo) * COVER_RTOL
                        and hi <= c_hi + abs(c_hi) * COVER_RTOL):
                    # tightest covering range wins: a Θ fitted over fewer
                    # decades answers the sub-range more accurately
                    width = c_hi - c_lo
                    if best_width is None or width < best_width:
                        best_width, entry = width, cand
        if entry is None:
            self.misses += 1
            self._tenant_count("misses")
            return None
        self.hits += 1
        self._tenant_count("hits")
        entry.hits += 1
        self._touch(entry)
        return entry

    def get_anchors(self, key: CacheKey) -> Optional[packing.PackedFactor]:
        """Cached packed anchor factors for ``key``'s anchor fingerprint
        (degree/basis-independent), or None.  Counts as an anchor hit."""
        digest = self._by_anchor.get(key.anchor_digest())
        if digest is None:
            return None
        entry = self.entries[digest]
        if entry.anchors is not None:  # entry may have been repopulated bare
            self.anchor_hits += 1
            self._tenant_count("anchor_hits")
            self._touch(entry)
        return entry.anchors

    # --------------------------------------------------------------- write

    def put(self, key: CacheKey, state: Optional[picholesky.PiCholesky],
            anchors: Optional[packing.PackedFactor] = None) -> CacheEntry:
        """Write one entry.  ``state=None`` with ``anchors`` stores an
        anchors-only entry (served by :meth:`get_anchors` only — the
        interpolant-selection path's pre-Θ write)."""
        if state is None and anchors is None:
            raise ValueError("refusing to cache an empty entry: "
                             "need a fitted state, packed anchors, or both")
        digest = key.digest()
        nbytes = _tree_nbytes((state, anchors))
        baseline = _tree_nbytes_at((state, anchors), key.dtype)
        entry = CacheEntry(key=key, state=state, anchors=anchors,
                           nbytes=nbytes,
                           bytes_saved=max(0, baseline - nbytes))
        self.bytes_saved += entry.bytes_saved
        self._tenant_count("puts")
        if digest not in self.entries:
            self._by_base.setdefault(key.base_digest(), []).append(digest)
        self.entries[digest] = entry
        if anchors is not None:
            self._by_anchor[key.anchor_digest()] = digest
        self._touch(entry)
        self._evict_to_budget(keep=digest)
        return entry

    # ------------------------------------------------------ byte-budget LRU

    def _evict(self, digest: str) -> None:
        """Drop one entry and purge every lookup index that could serve it
        (exact, covering and anchor routes) — an evicted digest can only
        MISS afterwards, never return a stale state."""
        entry = self.entries.pop(digest)
        base = entry.key.base_digest()
        siblings = self._by_base.get(base)
        if siblings is not None:
            siblings[:] = [d for d in siblings if d != digest]
            if not siblings:
                del self._by_base[base]
        anchor = entry.key.anchor_digest()
        if self._by_anchor.get(anchor) == digest:
            del self._by_anchor[anchor]
        self.evictions += 1

    def _evict_to_budget(self, keep: str) -> None:
        if self.max_bytes is None:
            return
        while self.total_bytes > self.max_bytes and len(self.entries) > 1:
            victim = min((d for d in self.entries if d != keep),
                         key=lambda d: self.entries[d].last_used)
            self._evict(victim)

    # --------------------------------------------------- persistence (disk)

    @staticmethod
    def _leaf_spec(arr) -> dict:
        a = np.asarray(arr)
        return dict(shape=list(a.shape), dtype=str(a.dtype))

    @staticmethod
    def _leaf_like(spec: dict) -> np.ndarray:
        return np.zeros(tuple(spec["shape"]), dtype=np.dtype(spec["dtype"]))

    def save(self, directory: str) -> str:
        """Persist every entry through :class:`CheckpointManager` (one step
        per entry, ``keep=None`` so nothing is garbage-collected) plus an
        ``index.json`` sidecar.  Crash-safe end to end: new saves always
        take FRESH step numbers (never rewriting a step an existing index
        may reference), the index flips last via ``os.replace``, and only
        then are steps the new index doesn't reference pruned — a torn
        save leaves the previous index valid and self-consistent."""
        mgr = CheckpointManager(directory, keep=None)
        base = max(mgr.all_steps(), default=-1) + 1
        index = {"schema": "factor_cache/v1", "entries": []}
        for offset, (digest, e) in enumerate(sorted(self.entries.items())):
            step = base + offset
            tree = {}
            if isinstance(e.state, solvers.LowRankFactors):
                tree["vt"] = e.state.vt
                tree["evals"] = e.state.evals
                srec_out = {"kind": "low_rank",
                            "vt": self._leaf_spec(e.state.vt),
                            "evals": self._leaf_spec(e.state.evals)}
            elif e.state is not None:
                tree["theta"] = e.state.theta
                tree["center"] = e.state.center
                srec_out = {"h": e.state.h, "block": e.state.block,
                            "theta": self._leaf_spec(e.state.theta),
                            "center": self._leaf_spec(e.state.center)}
            else:
                srec_out = None
            if e.anchors is not None:
                tree["anchors_vec"] = e.anchors.vec
            mgr.save(step, tree)
            rec = {
                "step": step, "digest": digest, "key": e.key.to_json(),
                "state": srec_out,
                "anchors": None if e.anchors is None else {
                    "h": e.anchors.h, "block": e.anchors.block,
                    "vec": self._leaf_spec(e.anchors.vec)},
            }
            index["entries"].append(rec)
        path = os.path.join(directory, INDEX_FILENAME)
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(index, f, indent=2, sort_keys=True)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
        # only after the flip is it safe to drop steps the live index no
        # longer references (a crash mid-prune just leaves harmless extras)
        referenced = {rec["step"] for rec in index["entries"]}
        for s in mgr.all_steps():
            if s not in referenced:
                shutil.rmtree(mgr.step_dir(s), ignore_errors=True)
        return path

    @classmethod
    def load(cls, directory: str,
             max_bytes: Optional[int] = None) -> "FactorCache":
        """Rebuild a cache from :meth:`save` output.  Entries whose
        checkpoint fails the manager's hash verification (torn writes) are
        skipped, never half-loaded; a stale digest (index/payload mismatch)
        is likewise dropped.  ``max_bytes`` applies the byte-budget LRU to
        the reloaded cache (entries beyond the budget are evicted in index
        order — oldest first — during the load)."""
        cache = cls(max_bytes=max_bytes)
        path = os.path.join(directory, INDEX_FILENAME)
        if not os.path.exists(path):
            return cache
        with open(path) as f:
            index = json.load(f)
        mgr = CheckpointManager(directory, keep=None)
        for rec in index.get("entries", ()):
            key = CacheKey.from_json(rec["key"])
            if key.digest() != rec["digest"]:
                continue
            srec = rec["state"]
            kind = (srec or {}).get("kind", "picholesky")
            like = {}
            if srec is not None and kind == "low_rank":
                like["vt"] = cls._leaf_like(srec["vt"])
                like["evals"] = cls._leaf_like(srec["evals"])
            elif srec is not None:
                like["theta"] = cls._leaf_like(srec["theta"])
                like["center"] = cls._leaf_like(srec["center"])
            arec = rec.get("anchors")
            if arec is not None:
                like["anchors_vec"] = cls._leaf_like(arec["vec"])
            try:
                tree = mgr.restore(rec["step"], like)
            except IOError:
                continue
            if any(np.asarray(tree[name]).shape != np.asarray(ref).shape
                   or np.asarray(tree[name]).dtype != np.asarray(ref).dtype
                   for name, ref in like.items()):
                continue     # index/payload mismatch — drop, never mis-serve
            if srec is None:
                state = None
            elif kind == "low_rank":
                state = solvers.LowRankFactors(
                    vt=tree["vt"], evals=tree["evals"])
            else:
                state = picholesky.PiCholesky(
                    theta=tree["theta"], center=tree["center"],
                    h=int(srec["h"]), block=int(srec["block"]))
            anchors = None
            if arec is not None:
                anchors = packing.PackedFactor(
                    vec=tree["anchors_vec"], h=int(arec["h"]),
                    block=int(arec["block"]))
            cache.put(key, state, anchors)
        return cache
