"""Shared CV data types: fold statistics, hold-out metric, result record.

Lives below both :mod:`repro.core.cv` (the compatibility drivers) and
:mod:`repro.core.engine` (the batched/sharded sweep) so neither imports the
other for these definitions.

The fold trick: with ``H_f = X_fᵀX_f`` per fold, the training Hessian of
fold f is ``H − H_f`` (one pass over the data, §1's O(nd²) paid once).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["FoldData", "make_folds", "holdout_nrmse", "CVResult"]


class FoldData(NamedTuple):
    """Per-fold sufficient statistics + raw held-out blocks."""
    hess: jax.Array        # (h, h) total XᵀX
    grad: jax.Array        # (h,)   total Xᵀy
    fold_hess: jax.Array   # (k, h, h)
    fold_grad: jax.Array   # (k, h)
    x_folds: jax.Array     # (k, n_f, h)
    y_folds: jax.Array     # (k, n_f)


def make_folds(x: jax.Array, y: jax.Array, k: int) -> FoldData:
    n = x.shape[0]
    n_f = n // k
    x = x[: n_f * k].reshape(k, n_f, -1)
    y = y[: n_f * k].reshape(k, n_f)
    fold_hess = jnp.einsum("kni,knj->kij", x, x)
    fold_grad = jnp.einsum("kni,kn->ki", x, y)
    return FoldData(fold_hess.sum(0), fold_grad.sum(0), fold_hess, fold_grad, x, y)


def holdout_nrmse(theta: jax.Array, x_hold: jax.Array, y_hold: jax.Array) -> jax.Array:
    """Normalized RMSE on the held-out fold (paper's hold-out error)."""
    pred = x_hold @ theta
    mse = jnp.mean((pred - y_hold) ** 2)
    denom = jnp.std(y_hold) + 1e-30
    return jnp.sqrt(mse) / denom


@dataclasses.dataclass
class CVResult:
    lams: np.ndarray           # dense candidate grid
    errors: np.ndarray         # (q,) mean hold-out error across folds
    best_lam: float
    best_error: float
    n_exact_chol: int          # factorizations actually performed
    extras: dict = dataclasses.field(default_factory=dict)

    @staticmethod
    def from_errors(lams, errors, n_exact, **extras) -> "CVResult":
        """Rank a hold-out curve into a result.

        The argmin runs over the FINITE entries only — ``np.argmin`` on a
        partially-NaN curve returns the first NaN's index, which would
        silently report ``best_lam=nan``.  A curve with *no* finite entry
        cannot be ranked at all (every λ hit a singular fold / overflow):
        that raises ``FloatingPointError`` — the same refusal the engine's
        early-stop search makes mid-stream — instead of returning a
        ``nan``/``inf`` selection the caller would deploy.
        """
        lams = np.asarray(lams)
        errors = np.asarray(errors)
        if errors.size == 0:
            raise ValueError("cannot rank an empty hold-out curve "
                             "(no λ was evaluated)")
        finite = np.isfinite(errors)
        if not finite.any():
            raise FloatingPointError(
                "hold-out curve has no finite value: every λ produced a "
                "non-finite mean error (singular fold? overflow → try "
                "precision='bf16_refined' or fp64); refusing to rank a "
                "curve that cannot be compared")
        i = int(np.flatnonzero(finite)[np.argmin(errors[finite])])
        return CVResult(lams, errors, float(lams[i]), float(errors[i]),
                        n_exact, dict(extras))
