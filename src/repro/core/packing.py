"""Tile-major triangular packing — the TPU adaptation of piCholesky §5.

The paper's recursive vectorization exists to make the L ↔ vector conversion
memory-aligned (cache lines on CPU).  On TPU the natural unit of alignment is
the (8,128) VREG tile / 128-lane HBM burst, so instead of the paper's
divide-and-conquer recursion we pack the lower triangle of ``L`` as the
sequence of its ``B×B`` tiles in *tile-column-major* order (the order a
right-looking blocked Cholesky produces them).  Properties:

* every copy is a full aligned ``B×B`` tile (no unaligned access — the
  paper's requirement (i)),
* only ``n_t(n_t+1)/2`` of ``n_t²`` tiles are stored, so the fit/interp GEMMs
  do ~half the work of full-matrix vectorization (requirement (ii)); the
  only redundancy is the zero upper half of the ``n_t`` diagonal tiles,
  an overhead factor of ``1 + B/h`` — negligible for ``h ≫ B``.

This module is the pure-jnp reference; ``repro.kernels.tri_pack`` is the
Pallas kernel with the same layout.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "num_tiles",
    "tile_index_pairs",
    "packed_size",
    "pack_tril",
    "unpack_tril",
    "pack_tril_rowwise",
    "pack_tril_full",
    "tril_mask_packed",
]


def num_tiles(h: int, block: int) -> int:
    """Number of ``block``-sized tile rows covering an ``h×h`` matrix."""
    return -(-h // block)


@functools.lru_cache(maxsize=None)
def tile_index_pairs(h: int, block: int) -> Tuple[np.ndarray, np.ndarray]:
    """(i, j) tile coordinates of the lower-triangular tiles, column-major.

    Column-major over tile columns matches the panel order of a
    right-looking blocked Cholesky, so factorization can stream tiles
    straight into the packed buffer.
    """
    nt = num_tiles(h, block)
    ii, jj = [], []
    for j in range(nt):
        for i in range(j, nt):
            ii.append(i)
            jj.append(j)
    return np.asarray(ii, dtype=np.int32), np.asarray(jj, dtype=np.int32)


def packed_size(h: int, block: int) -> int:
    nt = num_tiles(h, block)
    return (nt * (nt + 1) // 2) * block * block


def _padded(mat: jax.Array, block: int) -> jax.Array:
    h = mat.shape[-1]
    nt = num_tiles(h, block)
    pad = nt * block - h
    if pad:
        mat = jnp.pad(mat, [(0, 0)] * (mat.ndim - 2) + [(0, pad), (0, pad)])
    return mat


def pack_tril(mat: jax.Array, block: int = 128) -> jax.Array:
    """Pack the lower triangle of ``mat`` (…, h, h) into (…, P) tile-major.

    Diagonal tiles are stored with their upper half zeroed (alignment
    padding).  Works under vmap/jit; the tile gather is a static reshape +
    take, no dynamic indexing.
    """
    h = mat.shape[-1]
    nt = num_tiles(h, block)
    m = _padded(jnp.tril(mat), block)
    lead = m.shape[:-2]
    # (…, nt, B, nt, B) -> (…, nt, nt, B, B) -> take lower tiles
    t = m.reshape(*lead, nt, block, nt, block)
    t = jnp.moveaxis(t, -2, -3)  # (…, nt, nt, B, B)
    ii, jj = tile_index_pairs(h, block)
    flat = t.reshape(*lead, nt * nt, block, block)
    tiles = jnp.take(flat, jnp.asarray(ii) * nt + jnp.asarray(jj), axis=-3)
    return tiles.reshape(*lead, -1)


@functools.lru_cache(maxsize=None)
def _unpack_gather_indices(h: int, block: int) -> np.ndarray:
    """(nt²,) packed-tile index per dense tile; sentinel = n_blocks (zero)."""
    nt = num_tiles(h, block)
    ii, jj = tile_index_pairs(h, block)
    n_blocks = len(ii)
    pmap = np.full((nt, nt), n_blocks, np.int32)
    for p, (i, j) in enumerate(zip(ii, jj)):
        pmap[i, j] = p
    return pmap.reshape(-1)


def unpack_tril(vec: jax.Array, h: int, block: int = 128) -> jax.Array:
    """Inverse of :func:`pack_tril`: (…, P) -> (…, h, h) lower-triangular.

    Gather-based (one take per call): scatters are slow and vmap badly on
    CPU/TPU; a gather with a zero-tile sentinel is a single fused DMA.
    """
    nt = num_tiles(h, block)
    lead = vec.shape[:-1]
    tiles = vec.reshape(*lead, -1, block, block)
    zero = jnp.zeros((*lead, 1, block, block), vec.dtype)
    tiles = jnp.concatenate([tiles, zero], axis=-3)
    idx = jnp.asarray(_unpack_gather_indices(h, block))
    flat = jnp.take(tiles, idx, axis=-3)           # (…, nt², B, B)
    t = flat.reshape(*lead, nt, nt, block, block)
    t = jnp.moveaxis(t, -3, -2)  # (…, nt, B, nt, B)
    m = t.reshape(*lead, nt * block, nt * block)
    return jnp.tril(m[..., :h, :h])


@functools.lru_cache(maxsize=None)
def _tril_flat_indices(h: int) -> np.ndarray:
    r, c = np.tril_indices(h)
    return (r * h + c).astype(np.int32)


def pack_tril_rowwise(mat: jax.Array) -> jax.Array:
    """Paper's row-wise baseline: concatenate tril entries row by row.

    Exact size D = h(h+1)/2 but every row copy is unaligned — the strategy
    Table 1 shows losing to the recursive scheme.
    """
    h = mat.shape[-1]
    lead = mat.shape[:-2]
    flat = mat.reshape(*lead, h * h)
    return jnp.take(flat, jnp.asarray(_tril_flat_indices(h)), axis=-1)


def unpack_tril_rowwise(vec: jax.Array, h: int) -> jax.Array:
    lead = vec.shape[:-1]
    flat = jnp.zeros((*lead, h * h), vec.dtype)
    flat = flat.at[..., jnp.asarray(_tril_flat_indices(h))].set(vec)
    return flat.reshape(*lead, h, h)


def pack_tril_full(mat: jax.Array) -> jax.Array:
    """Paper's full-matrix baseline: vec of the whole (zeroed-upper) matrix —
    aligned but 2× the interpolation work."""
    lead = mat.shape[:-2]
    return jnp.tril(mat).reshape(*lead, -1)


def tril_mask_packed(h: int, block: int = 128, dtype=jnp.float32) -> jax.Array:
    """Mask of 'real' (non-padding) entries in the tile-packed layout."""
    return pack_tril(jnp.ones((h, h), dtype), block)
