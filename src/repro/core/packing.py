"""Tile-major triangular packing — the TPU adaptation of piCholesky §5.

The paper's recursive vectorization exists to make the L ↔ vector conversion
memory-aligned (cache lines on CPU).  On TPU the natural unit of alignment is
the (8,128) VREG tile / 128-lane HBM burst, so instead of the paper's
divide-and-conquer recursion we pack the lower triangle of ``L`` as the
sequence of its ``B×B`` tiles in *tile-column-major* order (the order a
right-looking blocked Cholesky produces them).  Properties:

* every copy is a full aligned ``B×B`` tile (no unaligned access — the
  paper's requirement (i)),
* only ``n_t(n_t+1)/2`` of ``n_t²`` tiles are stored, so the fit/interp GEMMs
  do ~half the work of full-matrix vectorization (requirement (ii)); the
  only redundancy is the zero upper half of the ``n_t`` diagonal tiles,
  an overhead factor of ``1 + B/h`` — negligible for ``h ≫ B``.

This module is the pure-jnp reference; ``repro.kernels.tri_pack`` is the
Pallas kernel with the same layout.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "num_tiles",
    "tile_index_pairs",
    "tile_pos_map",
    "column_starts",
    "packed_size",
    "packed_nbytes",
    "pack_tril",
    "unpack_tril",
    "pack_tril_rowwise",
    "pack_tril_full",
    "tril_mask_packed",
    "PackedFactor",
    "invert_diag_tiles",
    "solve_lower_packed",
    "solve_packed_ref",
]


def num_tiles(h: int, block: int) -> int:
    """Number of ``block``-sized tile rows covering an ``h×h`` matrix."""
    return -(-h // block)


@functools.lru_cache(maxsize=None)
def tile_index_pairs(h: int, block: int) -> Tuple[np.ndarray, np.ndarray]:
    """(i, j) tile coordinates of the lower-triangular tiles, column-major.

    Column-major over tile columns matches the panel order of a
    right-looking blocked Cholesky, so factorization can stream tiles
    straight into the packed buffer.
    """
    nt = num_tiles(h, block)
    ii, jj = [], []
    for j in range(nt):
        for i in range(j, nt):
            ii.append(i)
            jj.append(j)
    return np.asarray(ii, dtype=np.int32), np.asarray(jj, dtype=np.int32)


@functools.lru_cache(maxsize=None)
def tile_pos_map(h: int, block: int) -> np.ndarray:
    """(nt, nt) dense-tile → packed-tile index map; 0 for upper (unused) tiles.

    The 0 sentinel aliases the (0, 0) diagonal tile — callers must mask
    upper positions before use (every consumer walks only ``i ≥ j``).
    """
    nt = num_tiles(h, block)
    ii, jj = tile_index_pairs(h, block)
    pmap = np.zeros((nt, nt), np.int32)
    for p, (i, j) in enumerate(zip(ii, jj)):
        pmap[i, j] = p
    return pmap


@functools.lru_cache(maxsize=None)
def column_starts(h: int, block: int) -> np.ndarray:
    """Packed index of the *diagonal* tile of each tile column.

    Column ``j`` of the tile-column-major layout is the contiguous run of
    tiles ``(j, j), (j+1, j), …, (nt−1, j)`` starting at
    ``j·nt − j(j−1)/2`` — the property that lets the packed triangular
    solves walk panels with plain slices.
    """
    nt = num_tiles(h, block)
    j = np.arange(nt, dtype=np.int64)
    return (j * nt - j * (j - 1) // 2).astype(np.int32)


def packed_size(h: int, block: int) -> int:
    nt = num_tiles(h, block)
    return (nt * (nt + 1) // 2) * block * block


def packed_nbytes(h: int, block: int, dtype=jnp.float32) -> int:
    """Bytes one packed factor weighs at ``dtype`` — the quantity the
    precision policy's storage dtype halves (bf16 vs fp32) and the
    VMEM-auto λ-chunk heuristic budgets against."""
    return packed_size(h, block) * jnp.dtype(dtype).itemsize


def _padded(mat: jax.Array, block: int) -> jax.Array:
    h = mat.shape[-1]
    nt = num_tiles(h, block)
    pad = nt * block - h
    if pad:
        mat = jnp.pad(mat, [(0, 0)] * (mat.ndim - 2) + [(0, pad), (0, pad)])
    return mat


def pack_tril(mat: jax.Array, block: int = 128) -> jax.Array:
    """Pack the lower triangle of ``mat`` (…, h, h) into (…, P) tile-major.

    Diagonal tiles are stored with their upper half zeroed (alignment
    padding).  Works under vmap/jit; the tile gather is a static reshape +
    take, no dynamic indexing.
    """
    h = mat.shape[-1]
    nt = num_tiles(h, block)
    m = _padded(jnp.tril(mat), block)
    lead = m.shape[:-2]
    # (…, nt, B, nt, B) -> (…, nt, nt, B, B) -> take lower tiles
    t = m.reshape(*lead, nt, block, nt, block)
    t = jnp.moveaxis(t, -2, -3)  # (…, nt, nt, B, B)
    ii, jj = tile_index_pairs(h, block)
    flat = t.reshape(*lead, nt * nt, block, block)
    tiles = jnp.take(flat, jnp.asarray(ii) * nt + jnp.asarray(jj), axis=-3)
    return tiles.reshape(*lead, -1)


@functools.lru_cache(maxsize=None)
def _unpack_gather_indices(h: int, block: int) -> np.ndarray:
    """(nt²,) packed-tile index per dense tile; sentinel = n_blocks (zero)."""
    nt = num_tiles(h, block)
    ii, jj = tile_index_pairs(h, block)
    n_blocks = len(ii)
    pmap = np.full((nt, nt), n_blocks, np.int32)
    for p, (i, j) in enumerate(zip(ii, jj)):
        pmap[i, j] = p
    return pmap.reshape(-1)


def unpack_tril(vec: jax.Array, h: int, block: int = 128) -> jax.Array:
    """Inverse of :func:`pack_tril`: (…, P) -> (…, h, h) lower-triangular.

    Gather-based (one take per call): scatters are slow and vmap badly on
    CPU/TPU; a gather with a zero-tile sentinel is a single fused DMA.
    """
    nt = num_tiles(h, block)
    lead = vec.shape[:-1]
    tiles = vec.reshape(*lead, -1, block, block)
    zero = jnp.zeros((*lead, 1, block, block), vec.dtype)
    tiles = jnp.concatenate([tiles, zero], axis=-3)
    idx = jnp.asarray(_unpack_gather_indices(h, block))
    flat = jnp.take(tiles, idx, axis=-3)           # (…, nt², B, B)
    t = flat.reshape(*lead, nt, nt, block, block)
    t = jnp.moveaxis(t, -3, -2)  # (…, nt, B, nt, B)
    m = t.reshape(*lead, nt * block, nt * block)
    return jnp.tril(m[..., :h, :h])


@functools.lru_cache(maxsize=None)
def _tril_flat_indices(h: int) -> np.ndarray:
    r, c = np.tril_indices(h)
    return (r * h + c).astype(np.int32)


def pack_tril_rowwise(mat: jax.Array) -> jax.Array:
    """Paper's row-wise baseline: concatenate tril entries row by row.

    Exact size D = h(h+1)/2 but every row copy is unaligned — the strategy
    Table 1 shows losing to the recursive scheme.
    """
    h = mat.shape[-1]
    lead = mat.shape[:-2]
    flat = mat.reshape(*lead, h * h)
    return jnp.take(flat, jnp.asarray(_tril_flat_indices(h)), axis=-1)


def unpack_tril_rowwise(vec: jax.Array, h: int) -> jax.Array:
    lead = vec.shape[:-1]
    flat = jnp.zeros((*lead, h * h), vec.dtype)
    flat = flat.at[..., jnp.asarray(_tril_flat_indices(h))].set(vec)
    return flat.reshape(*lead, h, h)


def pack_tril_full(mat: jax.Array) -> jax.Array:
    """Paper's full-matrix baseline: vec of the whole (zeroed-upper) matrix —
    aligned but 2× the interpolation work."""
    lead = mat.shape[:-2]
    return jnp.tril(mat).reshape(*lead, -1)


def tril_mask_packed(h: int, block: int = 128, dtype=jnp.float32) -> jax.Array:
    """Mask of 'real' (non-padding) entries in the tile-packed layout."""
    return pack_tril(jnp.ones((h, h), dtype), block)


# --------------------------------------------------------- packed currency


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class PackedFactor:
    """A Cholesky factor that lives in the tile-packed ``(…, P)`` layout.

    The native currency of the factor pipeline: ``PiCholesky.fit`` packs
    once, interpolation and the triangular solves consume the packed vector
    directly, and nothing on the hot path materializes the dense ``(h, h)``
    matrix.  ``dense()`` is the explicit debug escape hatch.
    """

    vec: jax.Array
    h: int = dataclasses.field(metadata=dict(static=True))
    block: int = dataclasses.field(metadata=dict(static=True))

    def __post_init__(self):
        # A vec whose length disagrees with (h, block) would fail deep in a
        # tile reshape; fail at construction instead.  Guarded on having a
        # real shape: tree ops rebuild this dataclass with non-array leaves
        # (PartitionSpecs, tracers during transpose rules), which must pass.
        shape = getattr(self.vec, "shape", None)
        if shape and shape[-1] != packed_size(self.h, self.block):
            raise ValueError(
                f"packed vec last dim {shape[-1]} != packed_size(h={self.h},"
                f" block={self.block}) = {packed_size(self.h, self.block)}")

    @property
    def nt(self) -> int:
        return num_tiles(self.h, self.block)

    @property
    def n_blocks(self) -> int:
        return self.nt * (self.nt + 1) // 2

    @property
    def dtype(self):
        return self.vec.dtype

    @property
    def nbytes(self) -> int:
        """Array-payload bytes (post-``astype`` — what a cache entry or a
        streamed chunk actually weighs)."""
        return int(self.vec.size) * jnp.dtype(self.vec.dtype).itemsize

    def astype(self, dtype) -> "PackedFactor":
        """Same factor, re-stored at ``dtype`` — round-trips the pytree
        (static ``h``/``block`` survive; only ``vec`` is cast).  The
        precision policy's storage cast: ``astype('bfloat16')`` halves
        :attr:`nbytes` for fp32 factors."""
        return PackedFactor(vec=self.vec.astype(dtype), h=self.h,
                            block=self.block)

    @classmethod
    def from_dense(cls, mat: jax.Array, block: int = 128) -> "PackedFactor":
        return cls(vec=pack_tril(mat, block), h=mat.shape[-1], block=block)

    def tiles(self) -> jax.Array:
        """(…, n_blocks, B, B) view of the packed tiles."""
        lead = self.vec.shape[:-1]
        return self.vec.reshape(*lead, -1, self.block, self.block)

    def dense(self) -> jax.Array:
        """Debug escape hatch: materialize the dense factor (…, h, h)."""
        return unpack_tril(self.vec, self.h, self.block)


@functools.lru_cache(maxsize=None)
def _identity_tail(h: int, block: int) -> np.ndarray:
    """(B, B) identity on the padding rows of the last diagonal tile — the
    one rule making padded block solves nonsingular when h % block ≠ 0
    (all-zero when there is no padding).  Shared by every packed solver."""
    pad = num_tiles(h, block) * block - h
    tail = np.zeros((block, block), np.float64)
    if pad:
        idx = np.arange(block - pad, block)
        tail[idx, idx] = 1.0
    return tail


def _diag_tiles(tiles: jax.Array, h: int, block: int) -> jax.Array:
    """(nt, B, B) diagonal tiles, identity-padded via :func:`_identity_tail`."""
    nt = num_tiles(h, block)
    diag = tiles[..., column_starts(h, block), :, :]
    tail = _identity_tail(h, block)
    if tail.any():
        diag = diag.at[..., nt - 1, :, :].add(jnp.asarray(tail, diag.dtype))
    return diag


def invert_diag_tiles(diag: jax.Array) -> jax.Array:
    """Pre-invert lower-triangular diagonal tiles (…, B, B).

    Shared by the packed trsm and fused interp-solve kernels; one inversion
    serves both sweeps since ``inv(L_jj)ᵀ = inv(L_jjᵀ)``.
    """
    b = diag.shape[-1]
    eye = jnp.eye(b, dtype=diag.dtype)
    return jax.lax.linalg.triangular_solve(
        diag, jnp.broadcast_to(eye, diag.shape), left_side=True, lower=True)


def solve_lower_packed(vec: jax.Array, g: jax.Array, h: int, block: int, *,
                       transpose: bool = False,
                       accum_dtype=None) -> jax.Array:
    """Solve ``L w = g`` (or ``Lᵀ w = g``) from the tile-packed factor.

    Pure-jnp reference for :mod:`repro.kernels.packed_trsm`: walks the
    tile-column-major panels (column sweep forward, reverse column sweep for
    the transpose — column ``i`` of packed ``L`` holds exactly row ``i`` of
    ``Lᵀ``) without ever unpacking the dense matrix.  ``g``: (h,) or (h, q).

    ``accum_dtype``: the substitution/solution dtype.  Defaults to the
    factor's own dtype, promoted to fp32 for 16-bit factors — the packed
    ``vec`` is consumed AT its storage dtype (each ``B×B`` tile promotes
    inside its GEMM), so a bf16-stored factor batch never materializes a
    full-width upcast copy: that is the reference path's half of the
    mixed-precision memory contract.
    """
    from .precision import default_accum_dtype

    nt = num_tiles(h, block)
    hp = nt * block
    ad = (jnp.dtype(accum_dtype) if accum_dtype is not None
          else default_accum_dtype(vec.dtype))
    squeeze = g.ndim == 1
    g2 = (g[:, None] if squeeze else g).astype(ad)
    if hp != h:
        g2 = jnp.pad(g2, ((0, hp - h), (0, 0)))
    tiles = vec.reshape(-1, block, block)
    pmap = tile_pos_map(h, block)
    diag = _diag_tiles(tiles, h, block).astype(ad)

    w = [None] * nt
    order = range(nt - 1, -1, -1) if transpose else range(nt)
    for i in order:
        acc = g2[i * block:(i + 1) * block]
        if transpose:      # row i of Lᵀ = column i of packed L, transposed
            for t in range(i + 1, nt):
                acc = acc - (tiles[pmap[t, i]].T @ w[t]).astype(ad)
        else:
            for j in range(i):
                acc = acc - (tiles[pmap[i, j]] @ w[j]).astype(ad)
        w[i] = jax.lax.linalg.triangular_solve(
            diag[i], acc, left_side=True, lower=True, transpose_a=transpose)
    out = jnp.concatenate(w, axis=0)[:h]
    return out[:, 0] if squeeze else out


def solve_packed_ref(vec: jax.Array, g: jax.Array, h: int, block: int,
                     accum_dtype=None) -> jax.Array:
    """L Lᵀ θ = g entirely in the packed domain (forward + back sweep)."""
    w = solve_lower_packed(vec, g, h, block, accum_dtype=accum_dtype)
    return solve_lower_packed(vec, w, h, block, transpose=True,
                              accum_dtype=accum_dtype)
