"""piCholesky (Algorithm 1): polynomial interpolation of Cholesky factors.

Given a Hessian ``H`` and a sparse set of shifts ``{λ_s}``, factorize
``L^s = chol(H + λ_s I)`` exactly, fit an order-``r`` polynomial to every
entry of ``L`` via one batched least-squares solve, and evaluate the fit at
any dense λ grid for ``O(r d²)`` per value.

Layout: the target matrix ``T`` (g × D) holds tile-packed factors
(:mod:`repro.core.packing`), so the fit ``Θ = (VᵀV)⁻¹VᵀT`` and the
evaluation ``τ(λ)ᵀΘ`` are dense GEMMs (BLAS-3 / MXU, per paper §5).

Basis options (paper uses raw monomials; centered monomials are a
numerically safer drop-in that leaves Algorithm 1 unchanged — see
Thm 4.6's M-matrix change of basis):

* ``basis='monomial'``   — V[s,k] = λ_s^k          (paper, Algorithm 1)
* ``basis='centered'``   — V[s,k] = (λ_s − λ_c)^k  (λ_c = mean of samples)
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp

from . import packing
from .backends import BackendLike, resolve_backend

__all__ = ["PiCholesky", "fit", "evaluate", "evaluate_packed", "vandermonde",
           "choose_sample_lambdas", "refine_solutions", "loo_interp_scores",
           "select_interpolant"]


def vandermonde(lams: jax.Array, degree: int, center: float | jax.Array = 0.0) -> jax.Array:
    """g × (degree+1) observation matrix V (leading columns of Vandermonde)."""
    x = jnp.asarray(lams) - center
    return jnp.power(x[:, None], jnp.arange(degree + 1)[None, :].astype(x.dtype))


def choose_sample_lambdas(lo: float, hi: float, g: int, spacing: str = "log") -> jax.Array:
    """Pick the g sparse sample shifts from [lo, hi] (paper: subset of the
    exponentially spaced candidate grid)."""
    if spacing == "log":
        return jnp.logspace(jnp.log10(lo), jnp.log10(hi), g)
    return jnp.linspace(lo, hi, g)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class PiCholesky:
    """Fitted interpolant. ``theta``: (r+1, P) coefficients over the packed
    layout.  The packed ``(P,)`` representation is the pipeline's native
    currency: :meth:`eval_packed` / :meth:`eval_packed_factor` stay in it
    and :meth:`solve` fuses evaluation with the substitution, so the λ
    sweep never materializes dense factors; :meth:`eval_factor` is the
    explicit dense escape hatch for debugging and dense consumers."""

    theta: jax.Array
    center: jax.Array
    h: int = dataclasses.field(metadata=dict(static=True))
    block: int = dataclasses.field(metadata=dict(static=True))

    @property
    def degree(self) -> int:
        return self.theta.shape[0] - 1

    def eval_packed(self, lam: jax.Array) -> jax.Array:
        """Horner evaluation at scalar or vector λ -> (…, P) packed rows."""
        lam = jnp.asarray(lam)
        x = (lam - self.center).astype(self.theta.dtype)
        scalar = x.ndim == 0
        x = jnp.atleast_1d(x)

        def horner(acc, coeffs):  # over degrees, highest first
            return acc * x[:, None] + coeffs[None, :], None

        acc = jnp.zeros((x.shape[0], self.theta.shape[1]), self.theta.dtype)
        acc, _ = jax.lax.scan(horner, acc, self.theta[::-1])
        return acc[0] if scalar else acc

    def eval_packed_factor(self, lam: jax.Array) -> "packing.PackedFactor":
        """Interpolated factor(s) in the packed layout: vec is (…, P)."""
        return packing.PackedFactor(vec=self.eval_packed(lam), h=self.h,
                                    block=self.block)

    def solve(self, lam: jax.Array, g: jax.Array,
              backend: BackendLike = "reference") -> jax.Array:
        """θ(λ) = (H + λI)⁻¹ g for a λ chunk via the fused packed pipeline:
        Horner evaluation + forward/back substitution with no dense L(λ)."""
        return resolve_backend(backend).interp_solve(
            self.theta, lam, g, h=self.h, block=self.block,
            center=self.center)

    def eval_factor(self, lam: jax.Array,
                    backend: BackendLike = "reference") -> jax.Array:
        """Dense interpolated factor(s) L(λ): (…, h, h).

        Debug escape hatch — the sweep hot path uses :meth:`solve` /
        :meth:`eval_packed_factor` instead.  On the Pallas backend this is
        the fused Horner+unpack kernel (one pass over Θ), not the two-pass
        eval_packed → unpack_tril route.
        """
        lam = jnp.asarray(lam)
        out = resolve_backend(backend).interp_factors(
            self.theta, lam, h=self.h, block=self.block, center=self.center)
        return out[0] if lam.ndim == 0 else out


def fit(
    hessian: Optional[jax.Array],
    sample_lams: jax.Array,
    degree: int = 2,
    *,
    block: int = 128,
    basis: str = "monomial",
    chol_fn: Optional[Callable[[jax.Array], jax.Array]] = None,
    factors: "jax.Array | packing.PackedFactor | None" = None,
    backend: BackendLike = "reference",
) -> PiCholesky:
    """Algorithm 1.  ``hessian``: (h, h) SPD; ``sample_lams``: (g,) with
    g > degree.  ``backend`` selects the factorize/pack implementation
    (Pallas kernels vs ``jnp.linalg``); ``chol_fn`` overrides just the
    factorization; ``factors`` skips factorization if the caller already
    has L^s — either dense (g, h, h) or a
    :class:`~repro.core.packing.PackedFactor` with batched vec (g, P),
    which is consumed without any unpack.  With ``factors`` given the
    Hessian itself is not needed (the factor-cache refit path hands in
    cached anchors only): pass ``hessian=None`` and the geometry is taken
    from the factors.

    Precision: the backend's policy governs the fit — the normal equations
    ``Θ = (VᵀV)⁻¹VᵀT`` run at the policy's *fit* dtype (floored at fp32, so
    bf16-stored anchor targets never degrade the regression itself), and
    the returned Θ is cast to the *storage* dtype (bf16 halves the cached
    state).  The ``native`` policy inherits the target dtype end to end —
    bit-compatible with the pre-policy fit.
    """
    if hessian is None and factors is None:
        raise ValueError("fit needs a hessian to factorize or "
                         "precomputed factors; got neither")
    if hessian is not None:
        h = hessian.shape[-1]
    elif isinstance(factors, packing.PackedFactor):
        h = factors.h
    else:
        h = factors.shape[-1]
    g = sample_lams.shape[0]
    if g <= degree:
        raise ValueError(f"need g > r: got g={g}, r={degree}")
    bk = resolve_backend(backend)
    chol_fn = chol_fn or bk.cholesky

    if isinstance(factors, packing.PackedFactor):
        if factors.block != block or factors.h != h:
            raise ValueError(
                f"packed factors have (h={factors.h}, block={factors.block}); "
                f"fit called with (h={h}, block={block})")
        targets = factors.vec
    else:
        if factors is None:
            eye = jnp.eye(h, dtype=hessian.dtype)
            factors = jax.vmap(lambda lam: chol_fn(hessian + lam * eye)
                               )(sample_lams)
        # Step 2: tile-packed target matrix T (g × P) — aligned BLAS-3 layout.
        targets = bk.pack_tril(factors, block)

    center = jnp.mean(sample_lams) if basis == "centered" else jnp.zeros((), sample_lams.dtype)
    fit_dtype = bk.precision.fit_dtype(targets.dtype)
    store_dtype = bk.precision.store_dtype(targets.dtype)
    v = vandermonde(sample_lams, degree, center).astype(fit_dtype)

    # Steps 5–6: Θ = (VᵀV)⁻¹ VᵀT — normal equations exactly as in the
    # paper, at the fit dtype; Θ is then stored at the storage dtype.
    h_lam = v.T @ v
    g_lam = v.T @ targets.astype(fit_dtype)
    theta = jnp.linalg.solve(h_lam, g_lam)
    return PiCholesky(theta=theta.astype(store_dtype),
                      center=center.astype(fit_dtype), h=h, block=block)


def loo_interp_scores(
    targets: jax.Array,
    sample_lams: jax.Array,
    degrees: Sequence[int],
    *,
    bases: Sequence[str] = ("monomial",),
    backend: BackendLike = "reference",
) -> dict:
    """Leave-one-anchor-out CV scores for candidate (degree, basis) pairs.

    ``targets``: tile-packed anchor factors, ``(g, P)`` or batched
    ``(k, g, P)`` — exactly what :meth:`~repro.core.factor_cache.FactorCache`
    stores under the anchor digest, so scoring candidates against a warm
    cache performs **zero factorizations**: each candidate fit is a weighted
    normal-equations solve on ``g−1`` anchors plus one Horner row at the
    held-out anchor (GEMMs only, the pyapprox ``cross_validate_pce_degree``
    idiom transplanted to factor space).

    The score of a candidate is the mean (over anchors and folds) relative
    Frobenius error of the held-out packed factor prediction.  Candidates
    need ``g − 1 > degree`` (the reduced fit must still be overdetermined
    enough to solve); offering a degree that violates this raises.

    Returns ``{(degree, basis): float}``.
    """
    t = jnp.asarray(targets)
    if t.ndim == 2:
        t = t[None]                                    # (k=1, g, P)
    lam = jnp.asarray(sample_lams)
    g = int(lam.shape[0])
    for r in degrees:
        if g - 1 <= int(r):
            raise ValueError(
                f"leave-one-out selection needs g - 1 > degree: "
                f"g={g} anchors cannot score degree {r}")
    bk = resolve_backend(backend)
    fit_dtype = bk.precision.fit_dtype(t.dtype)
    t = t.astype(fit_dtype)
    lam = lam.astype(fit_dtype)
    eps = jnp.asarray(jnp.finfo(fit_dtype).tiny, fit_dtype)
    norms = jnp.linalg.norm(t, axis=-1) + eps          # (k, g)

    scores: dict = {}
    for basis in bases:
        if basis not in ("monomial", "centered"):
            raise ValueError(f"unknown basis {basis!r}; "
                             "expected 'monomial' or 'centered'")
        center = (jnp.mean(lam) if basis == "centered"
                  else jnp.zeros((), fit_dtype))
        for r in degrees:
            v = vandermonde(lam, int(r), center)       # (g, r+1)

            def loo_err(s):
                w = (jnp.arange(g) != s).astype(fit_dtype)
                vw = v * w[:, None]                    # zero the held-out row
                gram = vw.T @ v                        # (r+1, r+1)
                rhs = jnp.einsum("gr,kgp->krp", vw, t)
                theta = jax.vmap(
                    lambda b: jnp.linalg.solve(gram, b))(rhs)
                pred = jnp.einsum("r,krp->kp", v[s], theta)
                return jnp.linalg.norm(pred - t[:, s], axis=-1) / norms[:, s]

            errs = jax.vmap(loo_err)(jnp.arange(g))    # (g, k)
            scores[(int(r), basis)] = float(jnp.mean(errs))
    return scores


def select_interpolant(
    targets: jax.Array,
    sample_lams: jax.Array,
    degrees: Optional[Sequence[int]] = None,
    *,
    bases: Sequence[str] = ("monomial", "centered"),
    backend: BackendLike = "reference",
) -> dict:
    """Choose the interpolant (degree, basis) by :func:`loo_interp_scores`.

    ``degrees=None`` tries every LOO-scorable degree ``1 .. g−2``.  Ties
    break toward the *lowest* degree (candidates are scored in ascending
    order and only a strictly better score displaces the incumbent), so
    exactly-polynomial targets select the generating degree, not an
    equally-zero-error overfit.

    Returns ``dict(degree=, basis=, score=, scores={'basis/r': float})``.
    """
    lam = jnp.asarray(sample_lams)
    g = int(lam.shape[0])
    if degrees is None:
        degrees = tuple(range(1, g - 1))
    degrees = tuple(int(r) for r in degrees)
    if not degrees:
        raise ValueError(f"no candidate degrees to select from "
                         f"(g={g} anchors admit degrees 1..{g - 2})")
    scores = loo_interp_scores(targets, lam, degrees, bases=bases,
                               backend=backend)
    best_key, best = None, None
    for basis in bases:                 # stable order: basis-major,
        for r in degrees:               # ascending degree — ties keep the
            s = scores[(r, basis)]      # simplest candidate
            if best is None or s < best:
                best_key, best = (r, basis), s
    return dict(degree=best_key[0], basis=best_key[1], score=best,
                scores={f"{b}/r{r}": s for (r, b), s in scores.items()})


def evaluate_packed(model: PiCholesky, lams: jax.Array) -> "packing.PackedFactor":
    """Interpolated factors at a dense λ grid, still tile-packed: (q, P)."""
    return model.eval_packed_factor(lams)


def evaluate(model: PiCholesky, lams: jax.Array) -> jax.Array:
    """Dense interpolated factors (q, h, h) — debug escape hatch; the sweep
    path consumes :func:`evaluate_packed` / :meth:`PiCholesky.solve`."""
    return model.eval_factor(lams)


def refine_solutions(model: PiCholesky, hessian: jax.Array, g: jax.Array,
                     lams: jax.Array, thetas: jax.Array,
                     backend: BackendLike = "reference",
                     iters: Optional[int] = None) -> jax.Array:
    """Iterative refinement of ``interp_solve`` solutions — the accuracy
    half of the ``bf16_refined`` policy.

    The low-precision interpolated factor is a *preconditioner*: each
    sweep forms the true residual ``r(λ) = g − (H + λI)θ(λ)`` at the
    policy's accumulation dtype (exact λ — never the bf16-quantized one the
    Horner evaluation used) and corrects through one more fused interpolant
    solve with the per-λ residuals as RHS.  One iteration contracts the
    solve error by O(κ·ε_bf16), which is what lets a bf16-stored factor
    reproduce the fp32 hold-out argmin (Wilson et al.: hold-out selection
    tolerates controlled solve error; refinement makes the control
    explicit).  Runs per λ chunk inside ``fold_errors``, so its transient
    (q_chunk, h) residuals ride inside the existing O(chunk · P) budget.

    No-op (returns ``thetas`` unchanged) when the backend policy's
    ``refine_iters`` is 0.  ``iters=`` overrides the policy count — the
    sketched-anchor path uses this to run its IHS contraction loop
    (exact residuals against the dense Hessian, sketched factor as the
    preconditioner) through the same fused solve.
    """
    bk = resolve_backend(backend)
    iters = bk.precision.refine_iters if iters is None else int(iters)
    if iters <= 0:
        return thetas
    ad = bk.precision.accum_dtype(model.theta.dtype)
    hs = hessian.astype(ad)
    gs = g.astype(ad)
    lam_col = jnp.atleast_1d(lams).astype(ad)[:, None]
    th = jnp.atleast_2d(thetas).astype(ad)              # (q, h)
    for _ in range(iters):
        resid = gs[None, :] - (th @ hs + lam_col * th)  # H symmetric
        delta = bk.interp_solve(model.theta, jnp.atleast_1d(lams), resid,
                                h=model.h, block=model.block,
                                center=model.center, rhs_per_lam=True)
        th = th + delta.astype(ad)
    return th.reshape(thetas.shape) if thetas.ndim == 1 else th
