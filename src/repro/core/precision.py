"""PrecisionPolicy — the factor pipeline's one mixed-precision contract.

The paper's implementation claim is that piCholesky "maximally exploits the
compute power of modern architectures"; on TPU that means bf16 MXU
throughput and halved HBM/VMEM traffic for every packed factor the sweep
streams.  Before this module each layer silently inherited whatever dtype
the Hessian arrived in; now one :class:`PrecisionPolicy` names four dtype
roles plus a refinement count, and every layer — Pallas kernels, packed
currency, backends, ``picholesky.fit``, the CV engine, the factor cache —
consumes the policy instead of an implicit dtype:

``store``
    What fitted state weighs: Θ coefficients, cached packed anchor
    factors, and the streamed ``(chunk, P)`` interpolant rows.  ``bfloat16``
    halves every cache entry and doubles the VMEM-auto λ chunk.
``compute``
    The dtype fed to the MXU GEMMs (substitution sweeps, Horner tiles).
``accum``
    The dtype GEMMs accumulate in and solutions are returned in —
    ``float32`` whenever ``compute`` is a 16-bit type (never accumulate a
    substitution recurrence in bf16).  Factorizations (Cholesky, diagonal
    tile inversion) also run here: a bf16 *stored* factor is produced by
    rounding an fp32 factorization, never by factorizing in bf16.
``fit``
    The dtype of the polynomial fit (Vandermonde normal equations) and of
    every λ value that parameterizes it — floored at ``float32`` so a bf16
    problem never quantizes its regularizer grid.
``refine_iters``
    Iterative-refinement sweeps run per λ chunk on top of the low-precision
    ``interp_solve``: the residual ``g − (H + λI)θ`` is formed in ``accum``
    precision and corrected through one more interpolant solve.  The
    approximate-CV literature (Wilson et al.; Pilanci & Wainwright) shows
    hold-out *selection* tolerates controlled solve error — refinement is
    the mechanism that makes the tolerance explicit: ``bf16_refined``
    reproduces the fp32 argmin while storing factors at half the bytes.

``None`` for any dtype role means *inherit the input's dtype* (``accum``
additionally promotes 16-bit compute to fp32, ``fit`` floors at fp32) — the
``native`` preset is therefore bit-compatible with the pre-policy pipeline.

Presets
-------

=============== ========= ========= ======== ======== ======
name            store     compute   accum    fit      refine
=============== ========= ========= ======== ======== ======
``native``      inherit   inherit   auto     auto     0
``fp32``        float32   float32   float32  float32  0
``bf16_store``  bfloat16  bfloat16  float32  float32  0
``bf16_refined``bfloat16  bfloat16  float32  float32  1
``fp64``        float64   float64   float64  float64  0
=============== ========= ========= ======== ======== ======

The environment variable ``REPRO_TEST_PRECISION`` overrides the *default*
policy (what ``resolve_precision(None)`` returns) — the CI dtype-matrix
hook that re-runs the packed-pipeline and factor-cache parity suites under
``fp32`` and ``bf16_refined`` without touching a single call site.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Optional, Union

import jax
import jax.numpy as jnp

__all__ = ["PrecisionPolicy", "PRESETS", "resolve_precision", "tree_astype",
           "default_accum_dtype", "PrecisionLike"]


def _dt(name) -> jnp.dtype:
    return jnp.dtype(name)


def default_accum_dtype(compute_dtype) -> jnp.dtype:
    """THE never-accumulate-in-16-bit rule: fp32 when the compute dtype is
    16-bit, the compute dtype itself otherwise.  One definition shared by
    :meth:`PrecisionPolicy.accum_dtype`, the Pallas kernels' dtype
    resolution, and the jnp reference solvers — so the reference oracle
    and the kernels cannot drift onto different accumulation defaults."""
    cd = _dt(compute_dtype)
    return _dt(jnp.float32) if cd.itemsize < 4 else cd


@dataclasses.dataclass(frozen=True)
class PrecisionPolicy:
    """Dtype roles of the factor pipeline (see module doc).

    Fields hold dtype *names* (or ``None`` = inherit/derive) so the policy
    is hashable, JSON-trivial, and usable as a static jit argument.
    """

    name: str = "native"
    store: Optional[str] = None     # None: inherit the input dtype
    compute: Optional[str] = None   # None: inherit the input dtype
    accum: Optional[str] = None     # None: fp32 if compute is 16-bit
    fit: Optional[str] = None       # None: input dtype, floored at fp32
    refine_iters: int = 0

    def __post_init__(self):
        for role in ("store", "compute", "accum", "fit"):
            v = getattr(self, role)
            if v is not None:
                jnp.dtype(v)        # fail at construction, not deep in a jit
        if self.refine_iters < 0:
            raise ValueError(
                f"refine_iters must be >= 0, got {self.refine_iters}")

    # -- dtype resolution (input dtype -> role dtype) ----------------------

    def store_dtype(self, input_dtype) -> jnp.dtype:
        """Dtype fitted/cached factor state is stored in."""
        return _dt(self.store) if self.store else _dt(input_dtype)

    def compute_dtype(self, input_dtype) -> jnp.dtype:
        """Dtype fed to the substitution/Horner GEMMs."""
        return _dt(self.compute) if self.compute else _dt(input_dtype)

    def accum_dtype(self, input_dtype) -> jnp.dtype:
        """Dtype GEMMs accumulate in, solutions return in, and
        factorizations run in.  Never 16-bit: an unset ``accum`` promotes a
        16-bit compute dtype to fp32."""
        if self.accum:
            return _dt(self.accum)
        return default_accum_dtype(self.compute_dtype(input_dtype))

    def fit_dtype(self, input_dtype) -> jnp.dtype:
        """Dtype of the polynomial fit and of λ values — floored at fp32 so
        reduced-precision data never quantizes the regularizer grid.  This
        is the one definition of the default fit dtype (the engine's old
        ``jax_enable_x64`` probe collapsed into the inherit rule: fp64
        inputs fit in fp64, fp32 inputs in fp32)."""
        if self.fit:
            return _dt(self.fit)
        return jnp.promote_types(_dt(input_dtype), jnp.float32)

    # -- derived ----------------------------------------------------------

    @property
    def is_native(self) -> bool:
        return (self.store is None and self.compute is None
                and self.accum is None and self.fit is None
                and self.refine_iters == 0)

    def bytes_ratio(self, input_dtype) -> float:
        """Storage shrink factor vs the input dtype (2.0 for bf16 ÷ fp32)."""
        return (_dt(input_dtype).itemsize
                / self.store_dtype(input_dtype).itemsize)

    def descriptor(self) -> str:
        """Canonical content string for cache fingerprints — derived from
        the dtype roles, never the preset name, so two policies that round
        identically fingerprint identically."""
        if self.is_native:
            return "native"
        return (f"store={self.store or 'inherit'},"
                f"compute={self.compute or 'inherit'},"
                f"accum={self.accum or 'auto'},"
                f"fit={self.fit or 'auto'},"
                f"refine={self.refine_iters}")


PRESETS = {
    "native": PrecisionPolicy(),
    "fp32": PrecisionPolicy(name="fp32", store="float32", compute="float32",
                            accum="float32", fit="float32"),
    "bf16_store": PrecisionPolicy(name="bf16_store", store="bfloat16",
                                  compute="bfloat16", accum="float32",
                                  fit="float32"),
    "bf16_refined": PrecisionPolicy(name="bf16_refined", store="bfloat16",
                                    compute="bfloat16", accum="float32",
                                    fit="float32", refine_iters=1),
    "fp64": PrecisionPolicy(name="fp64", store="float64", compute="float64",
                            accum="float64", fit="float64"),
}

PrecisionLike = Union[None, str, PrecisionPolicy]


def resolve_precision(policy: PrecisionLike = None) -> PrecisionPolicy:
    """Map a ``precision=`` argument to a concrete :class:`PrecisionPolicy`.

    ``None`` resolves to the default policy: the ``REPRO_TEST_PRECISION``
    preset when that variable is set (the CI dtype-matrix hook), otherwise
    ``native`` — bit-compatible with the pre-policy pipeline.
    """
    if policy is None:
        policy = os.environ.get("REPRO_TEST_PRECISION", "native")
    if isinstance(policy, PrecisionPolicy):
        return policy
    try:
        return PRESETS[policy]
    except KeyError:
        raise ValueError(f"unknown precision policy {policy!r}; "
                         f"have {sorted(PRESETS)}") from None


def tree_astype(tree, dtype):
    """Cast every floating array leaf of a pytree to ``dtype``.

    Round-trips registered dataclasses (``PackedFactor``, ``PiCholesky``)
    — static fields survive, only inexact array leaves are cast.
    """
    dt = _dt(dtype)

    def cast(leaf):
        if hasattr(leaf, "dtype") and jnp.issubdtype(leaf.dtype, jnp.inexact):
            return leaf.astype(dt)
        return leaf

    return jax.tree.map(cast, tree)
