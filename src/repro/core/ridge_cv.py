"""RidgeCV — the end-to-end, mesh-aware piCholesky entry point.

Distribution: the design matrix shards over the data axes (rows); the
Hessian/gradient reductions become psums under GSPMD; the k-fold × λ sweep
is then a dense batched compute.  Without a mesh this runs single-device
with identical semantics (used by the CPU tests/examples).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.distributed.context import MeshCtx

from . import cv as cvlib
from . import picholesky
from .precision import resolve_precision

__all__ = ["RidgeCV"]


@dataclasses.dataclass
class RidgeCV:
    """k-fold cross-validated ridge with piCholesky λ-sweep acceleration."""

    k_folds: int = 5
    n_lambdas: int = 31
    lam_lo: float = 1e-3
    lam_hi: float = 1e2
    g_samples: int = 4
    degree: int = 2
    block: int = 128
    method: str = "pichol"          # pichol | exact
    ctx: Optional[MeshCtx] = None
    backend: object = "reference"   # engine linalg backend ('auto'|'pallas'|…)
    cv_mesh: object = None          # None | 'auto' | Mesh for the λ sweep
    precision: object = None        # PrecisionPolicy | preset name | None

    def lambdas(self) -> jax.Array:
        return jnp.logspace(jnp.log10(self.lam_lo), jnp.log10(self.lam_hi),
                            self.n_lambdas)

    def fit(self, x: jax.Array, y: jax.Array) -> cvlib.CVResult:
        ctx = self.ctx or MeshCtx(None)
        if ctx.mesh is not None:
            # rows sharded over the data axes; fold statistics psum under jit
            x = ctx.constrain(x, ctx.dp_axes, None)
            y = ctx.constrain(y, ctx.dp_axes)
        folds = cvlib.make_folds(x, y, self.k_folds)
        lams = self.lambdas()
        if self.method == "exact":
            return cvlib.cv_exact_cholesky(folds, lams, backend=self.backend,
                                           mesh=self.cv_mesh,
                                           precision=self.precision)
        return cvlib.cv_picholesky(folds, lams, g=self.g_samples,
                                   degree=self.degree, block=self.block,
                                   backend=self.backend, mesh=self.cv_mesh,
                                   precision=self.precision)

    def fit_theta(self, x: jax.Array, y: jax.Array):
        """CV-select λ*, then solve on the full data at λ*."""
        from . import solvers

        result = self.fit(x, y)
        hess = x.T @ x
        grad = x.T @ y
        # λ* lives at the policy's fit dtype (fp32 floor), NEVER the data's:
        # casting to x.dtype would quantize the selected regularizer on
        # bf16/fp16 designs — a different model than CV selected
        lam_dtype = resolve_precision(self.precision).fit_dtype(x.dtype)
        theta = solvers.solve_cholesky(hess, grad,
                                       jnp.asarray(result.best_lam, lam_dtype))
        return theta, result
