"""Row-sketch plans for sketched anchor factorization.

A :class:`SketchPlan` describes how to compress an ``(n, h)`` design block
``X`` into ``m << n`` sketched rows ``S @ X`` whose Gram matrix
``(SX)^T (SX)`` approximates the fold Hessian ``X^T X``.  Anchor Cholesky
factors built from the sketched Gram feed the piCholesky interpolation
pipeline unchanged; the Iterative Hessian Sketch refinement loop
(Pilanci & Wainwright, arXiv:1411.0347) then contracts the solve error
geometrically using *exact* residuals against the dense Hessian.

Everything is seeded through ``jax.random`` keys derived from
``(plan.seed, fold_index)`` so sketches are reproducible, vmap-safe over
folds, and cache-addressable: ``plan.descriptor()`` is the string that
lands in :class:`repro.core.factor_cache.CacheKey`.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional, Union

import jax
import jax.numpy as jnp

__all__ = [
    "SKETCH_METHODS",
    "SketchPlan",
    "as_plan",
    "fwht",
    "next_pow2",
    "sketch_rows",
    "sketched_gram",
]

SKETCH_METHODS = ("gaussian", "srht", "countsketch")


@dataclasses.dataclass(frozen=True)
class SketchPlan:
    """Describes one reproducible row-sketch of a design block.

    Attributes
    ----------
    method:
        One of ``"gaussian"`` (dense sub-Gaussian projection), ``"srht"``
        (subsampled randomized Hadamard transform) or ``"countsketch"``
        (sparse count-sketch via bucketed signed sums).
    m:
        Number of sketched rows.  Accuracy tightens as ``m`` grows; the
        embedding is only useful when ``m >= h``.
    seed:
        Base seed; the per-fold key is ``fold_in(PRNGKey(seed), f_idx)``.
    ihs_iters:
        Extra iterative-Hessian-sketch refinement iterations run against
        the exact Hessian after the interpolated solve.
    """

    method: str = "countsketch"
    m: int = 256
    seed: int = 0
    ihs_iters: int = 2

    def __post_init__(self):
        if self.method not in SKETCH_METHODS:
            raise ValueError(
                f"unknown sketch method {self.method!r}; expected one of {SKETCH_METHODS}"
            )
        if int(self.m) <= 0:
            raise ValueError(f"sketch size m must be positive, got {self.m}")
        if int(self.ihs_iters) < 0:
            raise ValueError(f"ihs_iters must be >= 0, got {self.ihs_iters}")
        object.__setattr__(self, "m", int(self.m))
        object.__setattr__(self, "seed", int(self.seed))
        object.__setattr__(self, "ihs_iters", int(self.ihs_iters))

    def descriptor(self) -> str:
        """Cache-key string; any field change must change this."""
        return f"{self.method}/m{self.m}/seed{self.seed}/ihs{self.ihs_iters}"

    def key_for(self, f_idx) -> jax.Array:
        """Per-fold PRNG key (works with traced ``f_idx`` under vmap)."""
        return jax.random.fold_in(jax.random.PRNGKey(self.seed), f_idx)

    def to_json(self) -> dict:
        return dict(
            method=self.method, m=self.m, seed=self.seed, ihs_iters=self.ihs_iters
        )

    @classmethod
    def from_json(cls, rec: dict) -> "SketchPlan":
        return cls(
            method=str(rec["method"]),
            m=int(rec["m"]),
            seed=int(rec.get("seed", 0)),
            ihs_iters=int(rec.get("ihs_iters", 0)),
        )


def as_plan(obj: Union["SketchPlan", dict, None]) -> Optional[SketchPlan]:
    """Coerce user input (``SketchPlan`` | dict | None) to a plan."""
    if obj is None or isinstance(obj, SketchPlan):
        return obj
    if isinstance(obj, dict):
        return SketchPlan(**obj)
    raise TypeError(f"cannot interpret {type(obj).__name__} as a SketchPlan")


def next_pow2(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


def fwht(x: jax.Array) -> jax.Array:
    """Orthonormal fast Walsh–Hadamard transform along axis 0.

    ``x`` must have a power-of-two leading dimension.  Self-inverse:
    ``fwht(fwht(x)) == x`` up to rounding.
    """
    n = x.shape[0]
    if n & (n - 1):
        raise ValueError(f"fwht requires a power-of-two length, got {n}")
    tail = x.shape[1:]
    h = 1
    while h < n:
        x = x.reshape((n // (2 * h), 2, h) + tail)
        a, b = x[:, 0], x[:, 1]
        x = jnp.stack([a + b, a - b], axis=1)
        h *= 2
    x = x.reshape((n,) + tail)
    return x / jnp.sqrt(jnp.asarray(n, x.dtype))


def _gaussian_sketch(x: jax.Array, m: int, key: jax.Array) -> jax.Array:
    n = x.shape[0]
    g = jax.random.normal(key, (m, n), dtype=x.dtype)
    return (g @ x) / jnp.sqrt(jnp.asarray(m, x.dtype))


def _srht_sketch(x: jax.Array, m: int, key: jax.Array) -> jax.Array:
    n = x.shape[0]
    n2 = next_pow2(n)
    m = min(m, n2)
    k_sign, k_rows = jax.random.split(key)
    signs = jax.random.rademacher(k_sign, (n,), dtype=x.dtype)
    xp = jnp.zeros((n2,) + x.shape[1:], x.dtype).at[:n].set(signs[:, None] * x)
    hx = fwht(xp)
    rows = jax.random.choice(k_rows, n2, (m,), replace=False)
    # Orthonormal H: E[(SX)^T SX] = X^T X needs the n2/m subsampling scale.
    return hx[rows] * jnp.sqrt(jnp.asarray(n2 / m, x.dtype))


def _countsketch(x: jax.Array, m: int, key: jax.Array) -> jax.Array:
    n = x.shape[0]
    k_bucket, k_sign = jax.random.split(key)
    buckets = jax.random.randint(k_bucket, (n,), 0, m)
    signs = jax.random.rademacher(k_sign, (n,), dtype=x.dtype)
    return jax.ops.segment_sum(signs[:, None] * x, buckets, num_segments=m)


def sketch_rows(plan: SketchPlan, x: jax.Array, key: jax.Array) -> jax.Array:
    """Apply ``S @ x`` for the plan's sketch operator; returns ``(m', h)``."""
    if plan.method == "gaussian":
        return _gaussian_sketch(x, plan.m, key)
    if plan.method == "srht":
        return _srht_sketch(x, plan.m, key)
    return _countsketch(x, plan.m, key)


def sketched_gram(
    plan: SketchPlan,
    x: jax.Array,
    f_idx,
    *,
    accum_dtype: Any = None,
) -> jax.Array:
    """Sketched fold Hessian ``(S X)^T (S X)`` at the accumulation dtype."""
    sx = sketch_rows(plan, x, plan.key_for(f_idx))
    if accum_dtype is not None:
        sx = sx.astype(accum_dtype)
    h = sx.T @ sx
    return 0.5 * (h + h.T)
