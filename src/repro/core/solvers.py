"""Ridge / regularized least-squares solvers (§3.2, §6.2 baselines).

All solvers consume the normal-equation data ``H = XᵀX`` (h×h) and
``g = Xᵀy`` (h,) — or the design matrix ``X`` itself for the SVD family —
and return θ(λ) for one or many λ.

The Cholesky-family solvers accept ``backend=`` (``'auto'`` | ``'pallas'`` |
``'reference'`` | a :class:`~repro.core.backends.LinalgBackend`) selecting
the factorize/substitute implementation; a ``chol_fn`` override takes
precedence over the backend's factorization (legacy hook, kept for the
kernel-injection tests).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from .backends import BackendLike, resolve_backend

__all__ = [
    "solve_from_factor",
    "solve_packed",
    "solve_interpolant_sweep",
    "solve_cholesky",
    "solve_cholesky_sweep",
    "svd_ridge_factors",
    "svd_ridge_sweep",
    "LowRankFactors",
    "lowrank_ridge_factors",
    "lowrank_ridge_sweep",
    "solve_svd",
    "solve_truncated_svd",
    "randomized_range_finder",
    "solve_randomized_svd",
]


def solve_from_factor(l, g: jax.Array,
                      backend: BackendLike = "reference") -> jax.Array:
    """Forward + back substitution: solve L Lᵀ θ = g (§3.2).

    ``l``: dense (h, h) factor or a
    :class:`~repro.core.packing.PackedFactor` (solved in the packed domain,
    no unpack).
    """
    return resolve_backend(backend).solve_from_factor(l, g)


def solve_packed(pf, g: jax.Array,
                 backend: BackendLike = "reference") -> jax.Array:
    """Packed-domain solve: L Lᵀ θ = g on tile-packed factor(s) (…, P)."""
    return resolve_backend(backend).solve_packed(pf, g)


def solve_interpolant_sweep(model, lams: jax.Array, g: jax.Array,
                            backend: BackendLike = "reference") -> jax.Array:
    """θ(λ) for a λ chunk straight from a fitted
    :class:`~repro.core.picholesky.PiCholesky`: fused Horner evaluation +
    packed substitution, no (q, h, h) intermediate.  (q, h)."""
    return model.solve(lams, g, backend=backend)


def solve_cholesky(hessian: jax.Array, g: jax.Array, lam: jax.Array,
                   chol_fn=None, backend: BackendLike = "reference") -> jax.Array:
    """Exact Chol baseline for one λ."""
    bk = resolve_backend(backend)
    chol_fn = chol_fn or bk.cholesky
    h = hessian.shape[-1]
    l = chol_fn(hessian + lam * jnp.eye(h, dtype=hessian.dtype))
    return bk.solve_from_factor(l, g)


def solve_cholesky_sweep(hessian: jax.Array, g: jax.Array, lams: jax.Array,
                         chol_fn=None,
                         backend: BackendLike = "reference") -> jax.Array:
    """Exact Chol for every λ in the grid — the O(q d³) cost piCholesky
    amortizes. (q, h)."""
    bk = resolve_backend(backend)
    return jax.vmap(
        lambda lam: solve_cholesky(hessian, g, lam, chol_fn, bk))(lams)


def svd_ridge_factors(x: jax.Array, y: jax.Array, mode: str = "full",
                      k: int = 0, key: Optional[jax.Array] = None):
    """λ-independent factor stage shared by the SVD family: returns
    ``(s, vt, uty)`` such that θ(λ) = vtᵀ diag(s/(s²+λ)) uty.

    ``mode``: ``'full'`` | ``'truncated'`` (top-k) | ``'randomized'``
    (Halko–Martinsson–Tropp range finder, then top-k).
    """
    if mode == "full":
        u, s, vt = jnp.linalg.svd(x, full_matrices=False)
    elif mode == "truncated":
        u, s, vt = jnp.linalg.svd(x, full_matrices=False)
        u, s, vt = u[:, :k], s[:k], vt[:k]
    elif mode == "randomized":
        key = key if key is not None else jax.random.PRNGKey(0)
        q = randomized_range_finder(x, k, key)
        b = q.T @ x  # (p, h)
        ub, s, vt = jnp.linalg.svd(b, full_matrices=False)
        u = q @ ub
        u, s, vt = u[:, :k], s[:k], vt[:k]
    else:
        raise ValueError(f"unknown SVD mode {mode!r}")
    return s, vt, u.T @ y


def svd_ridge_sweep(factors, lams: jax.Array) -> jax.Array:
    """θ(λ) for every λ from a :func:`svd_ridge_factors` result. (q, h)."""
    s, vt, uty = factors

    def per_lam(lam):
        d = s / (s * s + lam)
        return vt.T @ (d * uty)

    return jax.vmap(per_lam)(jnp.atleast_1d(lams))


def solve_svd(x: jax.Array, y: jax.Array, lams: jax.Array) -> jax.Array:
    """Full-SVD baseline (Eq. 11): factorize X once, reuse across all λ."""
    return svd_ridge_sweep(svd_ridge_factors(x, y, "full"), lams)


def solve_truncated_svd(x: jax.Array, y: jax.Array, lams: jax.Array,
                        k: int) -> jax.Array:
    """t-SVD baseline: keep only the top-k singular triplets."""
    return svd_ridge_sweep(svd_ridge_factors(x, y, "truncated", k), lams)


def randomized_range_finder(x: jax.Array, k: int, key: jax.Array,
                            oversample: int = 10, n_iter: int = 2) -> jax.Array:
    """Halko–Martinsson–Tropp randomized range finder with power iteration."""
    n, h = x.shape
    p = min(h, k + oversample)
    omega = jax.random.normal(key, (h, p), x.dtype)
    y = x @ omega
    q, _ = jnp.linalg.qr(y)
    for _ in range(n_iter):
        q, _ = jnp.linalg.qr(x.T @ q)
        q, _ = jnp.linalg.qr(x @ q)
    return q  # (n, p)


def solve_randomized_svd(x: jax.Array, y: jax.Array, lams: jax.Array, k: int,
                         key: Optional[jax.Array] = None) -> jax.Array:
    """r-SVD baseline [13]: approximate top-k SVD via random projection."""
    return svd_ridge_sweep(svd_ridge_factors(x, y, "randomized", k, key),
                           lams)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class LowRankFactors:
    """Spectral factors of a (rank-truncated) fold Hessian:
    H̃ = vtᵀ diag(evals) vt.

    ``vt`` holds *every* computed right singular vector of the training
    design (rows orthonormal, shape (r₀, h), r₀ = min(n, h)); ``evals``
    the squared singular values with entries **zeroed** beyond the
    requested rank.  Zeroing instead of dropping rows is what keeps the
    λ sweep cancellation-free: the truncated directions solve at 1/λ
    through the same ``1/(e+λ)`` expression (e=0), and no
    ``g − V Vᵀ g`` subtraction — catastrophic in fp32 when |g| ≫ |θ| —
    ever appears.  λ-independent: one factorization serves every grid.
    """

    vt: jax.Array
    evals: jax.Array


def lowrank_ridge_factors(x: jax.Array, rank: Optional[int] = None,
                          precision=None) -> LowRankFactors:
    """Low-rank ACV factor stage (Stephenson et al., arXiv:2008.10547).

    SVD of the (n, h) training design — O(n²h) when n ≪ h, vs g·O(h³)
    anchor Cholesky factorizations.  ``rank`` keeps the top-r curvature
    directions (evals beyond r are zeroed, see :class:`LowRankFactors`);
    ``None`` keeps all min(n, h).
    """
    _, s, vt = jnp.linalg.svd(x, full_matrices=False)
    evals = s * s
    if rank is not None:
        r = min(int(rank), s.shape[0])
        evals = jnp.where(jnp.arange(evals.shape[0]) < r, evals, 0.0)
    if precision is not None:
        vt = vt.astype(precision.store_dtype(vt.dtype))
        evals = evals.astype(precision.store_dtype(evals.dtype))
    return LowRankFactors(vt=vt, evals=evals)


def lowrank_ridge_sweep(factors: LowRankFactors, g: jax.Array,
                        lams: jax.Array, compute_dtype=None) -> jax.Array:
    """θ(λ) = V diag(1/(e+λ)) Vᵀg for every λ. (q, h).

    Woodbury form of (H̃ + λI)⁻¹g for H̃ = Vᵀ diag(e) V.  The gradient
    g = Xᵀy lies in range(Vᵀ) by construction, so the true null-space
    component is identically zero and needs no 1/λ term; truncated
    directions (e zeroed) solve at exactly 1/λ through the same
    expression.  Exact (up to rounding) whenever no eval was truncated.
    """
    dt = compute_dtype or jnp.promote_types(g.dtype, jnp.float32)
    vt = factors.vt.astype(dt)
    evals = factors.evals.astype(dt)
    vg = vt @ g.astype(dt)  # (r0,)

    def per_lam(lam):
        return vt.T @ (vg / (evals + lam.astype(dt)))

    return jax.vmap(per_lam)(jnp.atleast_1d(lams))
