"""Ridge / regularized least-squares solvers (§3.2, §6.2 baselines).

All solvers consume the normal-equation data ``H = XᵀX`` (h×h) and
``g = Xᵀy`` (h,) — or the design matrix ``X`` itself for the SVD family —
and return θ(λ) for one or many λ.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

__all__ = [
    "solve_from_factor",
    "solve_cholesky",
    "solve_cholesky_sweep",
    "solve_svd",
    "solve_truncated_svd",
    "randomized_range_finder",
    "solve_randomized_svd",
]


def _tri_solve(l: jax.Array, b: jax.Array, *, lower: bool, trans: bool) -> jax.Array:
    b2 = b[:, None] if b.ndim == 1 else b
    out = jax.lax.linalg.triangular_solve(
        l, b2, left_side=True, lower=lower, transpose_a=trans
    )
    return out[:, 0] if b.ndim == 1 else out


def solve_from_factor(l: jax.Array, g: jax.Array) -> jax.Array:
    """Forward + back substitution: solve L Lᵀ θ = g (§3.2)."""
    w = _tri_solve(l, g, lower=True, trans=False)
    return _tri_solve(l, w, lower=True, trans=True)


def solve_cholesky(hessian: jax.Array, g: jax.Array, lam: jax.Array,
                   chol_fn=None) -> jax.Array:
    """Exact Chol baseline for one λ."""
    chol_fn = chol_fn or jnp.linalg.cholesky
    h = hessian.shape[-1]
    l = chol_fn(hessian + lam * jnp.eye(h, dtype=hessian.dtype))
    return solve_from_factor(l, g)


def solve_cholesky_sweep(hessian: jax.Array, g: jax.Array, lams: jax.Array,
                         chol_fn=None) -> jax.Array:
    """Exact Chol for every λ in the grid — the O(q d³) cost piCholesky
    amortizes. (q, h)."""
    return jax.vmap(lambda lam: solve_cholesky(hessian, g, lam, chol_fn))(lams)


def solve_svd(x: jax.Array, y: jax.Array, lams: jax.Array) -> jax.Array:
    """Full-SVD baseline (Eq. 11): factorize X once, reuse across all λ."""
    u, s, vt = jnp.linalg.svd(x, full_matrices=False)
    uty = u.T @ y  # (k,)

    def per_lam(lam):
        d = s / (s * s + lam)
        return vt.T @ (d * uty)

    return jax.vmap(per_lam)(jnp.atleast_1d(lams))


def solve_truncated_svd(x: jax.Array, y: jax.Array, lams: jax.Array,
                        k: int) -> jax.Array:
    """t-SVD baseline: keep only the top-k singular triplets."""
    u, s, vt = jnp.linalg.svd(x, full_matrices=False)
    u, s, vt = u[:, :k], s[:k], vt[:k]
    uty = u.T @ y

    def per_lam(lam):
        d = s / (s * s + lam)
        return vt.T @ (d * uty)

    return jax.vmap(per_lam)(jnp.atleast_1d(lams))


def randomized_range_finder(x: jax.Array, k: int, key: jax.Array,
                            oversample: int = 10, n_iter: int = 2) -> jax.Array:
    """Halko–Martinsson–Tropp randomized range finder with power iteration."""
    n, h = x.shape
    p = min(h, k + oversample)
    omega = jax.random.normal(key, (h, p), x.dtype)
    y = x @ omega
    q, _ = jnp.linalg.qr(y)
    for _ in range(n_iter):
        q, _ = jnp.linalg.qr(x.T @ q)
        q, _ = jnp.linalg.qr(x @ q)
    return q  # (n, p)


def solve_randomized_svd(x: jax.Array, y: jax.Array, lams: jax.Array, k: int,
                         key: Optional[jax.Array] = None) -> jax.Array:
    """r-SVD baseline [13]: approximate top-k SVD via random projection."""
    key = key if key is not None else jax.random.PRNGKey(0)
    q = randomized_range_finder(x, k, key)
    b = q.T @ x  # (p, h)
    ub, s, vt = jnp.linalg.svd(b, full_matrices=False)
    u = q @ ub
    u, s, vt = u[:, :k], s[:k], vt[:k]
    uty = u.T @ y

    def per_lam(lam):
        d = s / (s * s + lam)
        return vt.T @ (d * uty)

    return jax.vmap(per_lam)(jnp.atleast_1d(lams))
