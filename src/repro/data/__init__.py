from .synthetic import (  # noqa: F401
    make_classification,
    random_polynomial_features,
    make_regression_dataset,
    make_low_rank_dataset,
    token_stream,
)
