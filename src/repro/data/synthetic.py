"""Synthetic data substrate.

The paper's experiments use MNIST/COIL/Caltech projected through the
Kar–Karnick randomized polynomial-kernel feature map [17].  Those datasets
are not available offline, so we generate two-class Gaussian-mixture data of
matching raw dimensionality and push it through the *same* feature map —
the piCholesky-relevant structure (an SPD Hessian whose Cholesky factor
varies smoothly with λ) is identical.

Also provides the token stream used by the LM training examples.
"""
from __future__ import annotations

from typing import Iterator, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "make_classification",
    "random_polynomial_features",
    "make_regression_dataset",
    "make_low_rank_dataset",
    "token_stream",
]


def make_classification(
    key: jax.Array,
    n: int,
    raw_dim: int,
    *,
    class_sep: float = 1.0,
    dtype=jnp.float32,
) -> Tuple[jax.Array, jax.Array]:
    """Balanced two-class Gaussian mixture; labels in {−1, +1} (the paper
    converts all datasets to 2-class problems with equal membership)."""
    k_mu, k_x, k_perm = jax.random.split(key, 3)
    mu = jax.random.normal(k_mu, (raw_dim,), dtype) * class_sep / np.sqrt(raw_dim)
    half = n // 2
    x = jax.random.normal(k_x, (2 * half, raw_dim), dtype)
    x = x.at[:half].add(mu).at[half:].add(-mu)
    y = jnp.concatenate([jnp.ones(half, dtype), -jnp.ones(half, dtype)])
    perm = jax.random.permutation(k_perm, 2 * half)
    return x[perm], y[perm]


def random_polynomial_features(
    key: jax.Array,
    x: jax.Array,
    out_dim: int,
    degree: int = 2,
    *,
    add_intercept: bool = True,
) -> jax.Array:
    """Kar–Karnick random feature map for the polynomial kernel (x·z + 1)^p:
    each feature is ∏_{t≤p} (ω_tᵀ[1; x]) with Rademacher ω.  Returns
    (n, out_dim[+1]) with an appended intercept column (the paper's h=d+1)."""
    n, d = x.shape
    x1 = jnp.concatenate([jnp.ones((n, 1), x.dtype), x], axis=1)
    feats = jnp.ones((n, out_dim), x.dtype)
    for t in range(degree):
        k_t = jax.random.fold_in(key, t)
        omega = jax.random.rademacher(k_t, (d + 1, out_dim), x.dtype)
        feats = feats * (x1 @ omega)
    feats = feats / jnp.sqrt(jnp.asarray(out_dim, x.dtype))
    if add_intercept:
        feats = jnp.concatenate([feats, jnp.ones((n, 1), x.dtype)], axis=1)
    return feats


def make_regression_dataset(
    key: jax.Array,
    n: int,
    h: int,
    *,
    raw_dim: int = 64,
    noise: float = 1.0,
    signal_scale: float = 3.0,
    dtype=jnp.float32,
) -> Tuple[jax.Array, jax.Array]:
    """End-to-end synthetic ridge dataset in an h-dim feature space
    (h includes the intercept column).

    Labels come from a planted linear model over the random-polynomial
    features plus Gaussian noise; with the default signal/noise ratio the
    hold-out error curve has an interior optimum in λ (the regime the
    paper's Figures 7/8 exercise).
    """
    k_c, k_f, k_t, k_n = jax.random.split(key, 4)
    x_raw, _ = make_classification(k_c, n, raw_dim, dtype=dtype)
    feats = random_polynomial_features(k_f, x_raw, h - 1, add_intercept=True)
    theta_true = signal_scale * jax.random.normal(k_t, (h,), dtype) / np.sqrt(h)
    y = feats @ theta_true + noise * jax.random.normal(k_n, (n,), dtype)
    return feats.astype(dtype), y.astype(dtype)


def make_low_rank_dataset(
    key: jax.Array,
    n: int,
    h: int,
    rank: int,
    *,
    noise: float = 1.0,
    tail_scale: float = 1e-3,
    signal_scale: float = 3.0,
    dtype=jnp.float32,
) -> Tuple[jax.Array, jax.Array]:
    """Planted (numerically) rank-r design in the n ≪ h regime the
    low-rank ACV strategy targets.

    ``X = A @ B + tail_scale · E`` with A (n, r), B (r, h): the top r
    singular values carry the signal, the tail sits ``tail_scale`` below
    them (exactly zero tails make SVD sign/order ties platform-dependent;
    a small tail keeps the factorization deterministic while leaving the
    rank-r truncation error negligible).  Labels come from a planted
    model in the row space plus noise, so the hold-out curve keeps an
    interior λ optimum.
    """
    if not 0 < rank <= min(n, h):
        raise ValueError(f"rank must be in (0, min(n={n}, h={h})], got {rank}")
    k_a, k_b, k_e, k_t, k_n = jax.random.split(key, 5)
    a = jax.random.normal(k_a, (n, rank), dtype)
    b = jax.random.normal(k_b, (rank, h), dtype) / np.sqrt(rank)
    e = jax.random.normal(k_e, (n, h), dtype)
    x = a @ b + tail_scale * e
    theta_true = signal_scale * (b.T @ jax.random.normal(k_t, (rank,), dtype)
                                 ) / np.sqrt(h)
    y = x @ theta_true + noise * jax.random.normal(k_n, (n,), dtype)
    return x.astype(dtype), y.astype(dtype)


def token_stream(
    key: jax.Array,
    vocab_size: int,
    batch: int,
    seq_len: int,
) -> Iterator[dict]:
    """Deterministic synthetic LM token stream (Zipf-ish unigram draw) —
    stands in for the tokenized corpus in the training examples/tests."""
    logits = -jnp.log1p(jnp.arange(vocab_size, dtype=jnp.float32))
    step = 0
    while True:
        k = jax.random.fold_in(key, step)
        tokens = jax.random.categorical(k, logits, shape=(batch, seq_len + 1))
        yield {"tokens": tokens[:, :-1], "labels": tokens[:, 1:]}
        step += 1
