from .context import MeshCtx  # noqa: F401
from . import sharding  # noqa: F401
from . import autotune  # noqa: F401
