"""Roofline-guided compile-time autotuner for the CV sweep.

Every hot-path knob in the pipeline used to be a static guess: the Pallas
backend hardcoded 256-wide kernel tiles, ``sharding.auto_lam_chunk`` sized
the λ-chunk from a fixed VMEM budget, and the folds × lams mesh shape was
caller-chosen (or the gcd heuristic).  This module *searches* that space
at compile time, with zero candidate executions:

1. **Enumerate** the legal configuration lattice for a problem geometry
   (h, k, q, dtype/precision, device count): kernel/packing block ×
   λ-chunk (the VMEM-auto value plus a pow2 ladder around it) × mesh
   shapes factoring the device count whose fold axis divides k
   (:func:`~repro.distributed.sharding.mesh_shape_candidates`).
2. **AOT-lower** the engine's jitted sweep — the ``fold_state`` +
   ``fold_errors`` stages jitted together, λ axis streamed under
   ``lax.map`` — for each candidate via ``jit(...).lower(shapes).compile()``
   on abstract :class:`jax.ShapeDtypeStruct` inputs.  Nothing runs; the
   compiled artifact is only *read*.
3. **Score** each artifact with the loop-aware HLO walker
   (:func:`~repro.distributed.hlo_cost.analyze_hlo` — λ-chunk ``while``
   loops are expanded by their trip count, so a small chunk's extra trips
   are priced) and the three roofline terms
   (:func:`~repro.distributed.roofline.roofline` against the detected
   :class:`~repro.distributed.roofline.HW` preset).  The predicted step
   time is ``max(compute, memory, collective)`` per device.
4. **Choose** the predicted-fastest :class:`TunedConfig`.  The engine's
   default configuration is always a candidate, and wins ties — tuning
   can refine the default, never silently regress its *prediction*.

Repeat tuning is free through the content-addressed :class:`TuningCache`
(keyed like the factor cache's ``CacheKey``: geometry + dtype + strategy
params + backend + precision + device fingerprint + lattice + HW),
persisted across processes via the checkpoint manager.

Entry points: :meth:`CVEngine(tune='auto') <repro.core.engine.CVEngine>`
threads the chosen config through the whole stack (strategy packing
block, Pallas kernel tiles, λ-chunk, mesh); :func:`tune` /
:func:`score_candidates` are the callable surface the bench and the
serving layer use directly.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import math
import os
import shutil
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.checkpoint.manager import CheckpointManager

from . import roofline as rl
from . import sharding as shardlib

__all__ = ["TunedConfig", "TuningCache", "fingerprint",
           "candidate_lattice", "score_candidates", "tune",
           "lower_sweep", "DEFAULT_BLOCKS"]

#: The kernel/packing block lattice on real problems (MXU-aligned tile
#: widths).  Candidates wider than the problem (block ≥ 2h) degenerate to
#: the same single padded tile and are pruned; benches and interpret-mode
#: tests pass proportionate lattices explicitly.
DEFAULT_BLOCKS = (128, 256, 512)

INDEX_FILENAME = "tuning_index.json"


# ------------------------------------------------------------------ config


@dataclasses.dataclass(frozen=True)
class TunedConfig:
    """One point of the configuration lattice (and the tuner's verdict).

    ``mesh_shape`` is ``(n_fold, n_lam)`` or ``None`` (no mesh — single
    device execution).  ``predicted_s`` is the roofline-predicted step
    time (state + λ stream, per device); ``source`` records how the
    config was obtained (``'tuned'`` — fresh search, ``'cache'`` —
    tuning-cache hit, ``'default'`` — the engine's untuned configuration,
    ``'candidate'`` — a scored lattice point).
    """

    block: int
    lam_chunk: int
    mesh_shape: Optional[Tuple[int, int]] = None
    predicted_s: float = float("nan")
    source: str = "candidate"

    def key(self) -> tuple:
        return (self.block, self.lam_chunk, self.mesh_shape)

    def to_json(self) -> dict:
        return {"block": self.block, "lam_chunk": self.lam_chunk,
                "mesh_shape": (None if self.mesh_shape is None
                               else list(self.mesh_shape)),
                "predicted_s": self.predicted_s, "source": self.source}

    @classmethod
    def from_json(cls, d: dict) -> "TunedConfig":
        ms = d.get("mesh_shape")
        return cls(block=int(d["block"]), lam_chunk=int(d["lam_chunk"]),
                   mesh_shape=None if ms is None else tuple(int(x) for x in ms),
                   predicted_s=float(d.get("predicted_s", float("nan"))),
                   source=str(d.get("source", "candidate")))


# ------------------------------------------------------------- fingerprint


def device_fingerprint() -> dict:
    """What makes a tuning verdict machine-specific: platform, device
    kind, and how many devices the mesh lattice can factor over."""
    import jax
    d = jax.devices()[0]
    return {"platform": d.platform, "device_kind": d.device_kind,
            "n_devices": len(jax.devices())}


def fingerprint(*, h: int, k: int, n_f: int, q: int, dtype: str,
                lam_dtype: str, params: dict, backend: str, precision: str,
                lattice: dict, hw_name: str,
                devices: Optional[dict] = None) -> str:
    """Content digest of everything a tuning verdict depends on — keyed
    like the factor cache's ``CacheKey``: problem geometry + dtype +
    strategy params + backend + precision + device fingerprint, plus the
    candidate lattice and HW preset the search ranked against (a wider
    lattice or recalibrated HW must re-tune, never serve a stale
    verdict)."""
    payload = {
        "schema": "tuning_key/v1",
        "h": int(h), "k": int(k), "n_f": int(n_f), "q": int(q),
        "dtype": str(dtype), "lam_dtype": str(lam_dtype),
        "params": {str(a): repr(b) for a, b in sorted(params.items())},
        "backend": str(backend), "precision": str(precision),
        "lattice": {str(a): repr(b) for a, b in sorted(lattice.items())},
        "hw": str(hw_name),
        "devices": devices or device_fingerprint(),
    }
    blob = json.dumps(payload, sort_keys=True).encode()
    return hashlib.sha256(blob).hexdigest()


# ------------------------------------------------------------------ cache


class TuningCache:
    """Content-addressed store of tuning verdicts (digest → config).

    Counters make the no-re-lowering contract testable: ``lowerings``
    increments once per candidate AOT compile, so a second :func:`tune`
    of the same geometry must be a ``hit`` that leaves it unchanged.

    Persistence rides the checkpoint manager exactly like the factor
    cache: :meth:`save` writes the verdict table as one checkpoint step
    (a uint8 JSON blob, sha256-manifested) plus an ``index.json`` sidecar
    recording the step and blob length (the like-tree
    :meth:`~repro.checkpoint.manager.CheckpointManager.restore` needs);
    the index flips last via ``os.replace`` so a torn save leaves the
    previous table valid, and stale steps are pruned only after the flip.
    """

    def __init__(self):
        self.configs: dict = {}    # digest -> TunedConfig
        self.hits = 0
        self.misses = 0
        self.lowerings = 0         # candidate AOT lower+compile count

    def __len__(self) -> int:
        return len(self.configs)

    def get(self, digest: str) -> Optional[TunedConfig]:
        cfg = self.configs.get(digest)
        if cfg is None:
            self.misses += 1
            return None
        self.hits += 1
        return cfg

    def put(self, digest: str, config: TunedConfig) -> TunedConfig:
        self.configs[digest] = config
        return config

    @property
    def stats(self) -> dict:
        return dict(entries=len(self.configs), hits=self.hits,
                    misses=self.misses, lowerings=self.lowerings)

    # -- persistence (checkpoint manager) ---------------------------------

    def save(self, directory: str) -> str:
        mgr = CheckpointManager(directory, keep=None)
        step = max(mgr.all_steps(), default=-1) + 1
        blob = json.dumps({d: c.to_json()
                           for d, c in sorted(self.configs.items())},
                          sort_keys=True).encode()
        arr = np.frombuffer(blob, dtype=np.uint8).copy()
        mgr.save(step, [arr])
        index = {"schema": "tuning_cache/v1", "step": step,
                 "nbytes": int(arr.size)}
        path = os.path.join(directory, INDEX_FILENAME)
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(index, f, indent=1)
        os.replace(tmp, path)                      # atomic flip
        for s in mgr.all_steps():                  # prune superseded steps
            if s != step:
                shutil.rmtree(mgr.step_dir(s), ignore_errors=True)
        return path

    @classmethod
    def load(cls, directory: str) -> "TuningCache":
        cache = cls()
        path = os.path.join(directory, INDEX_FILENAME)
        if not os.path.exists(path):
            return cache
        with open(path) as f:
            index = json.load(f)
        if index.get("schema") != "tuning_cache/v1":
            return cache
        mgr = CheckpointManager(directory, keep=None)
        like = [np.zeros(int(index["nbytes"]), dtype=np.uint8)]
        try:
            (arr,) = mgr.restore(int(index["step"]), like)
        except IOError:
            return cache          # torn step: serve an empty cache, re-tune
        table = json.loads(
            np.asarray(arr, dtype=np.uint8).tobytes().decode())
        for digest, d in table.items():
            cache.configs[digest] = TunedConfig.from_json(d)
        return cache


# ----------------------------------------------------------------- lattice


def _pow2_near(x: float, lo: int, hi: int) -> int:
    """The power of two nearest ``x`` (log scale), clipped to [lo, hi]."""
    x = max(float(x), 1.0)
    p = 2 ** int(round(math.log2(x)))
    return max(lo, min(hi, p))


def chunk_ladder(auto: int, q: int) -> Tuple[int, ...]:
    """λ-chunk candidates around the VMEM-auto value: the auto chunk plus
    a pow2 ladder at ×¼, ×½, ×2, ×4 (clipped to [1, q], deduped).  The
    walker prices a smaller chunk's extra ``lax.map`` trips and a larger
    chunk's bigger working set, so the ladder spans both failure modes of
    the static heuristic."""
    auto = max(1, min(int(auto), q))
    out = {auto}
    for mult in (0.25, 0.5, 2.0, 4.0):
        out.add(_pow2_near(auto * mult, 1, q))
    return tuple(sorted(out))


def candidate_lattice(*, h: int, k: int, q: int, n_devices: int,
                      default: TunedConfig,
                      blocks: Optional[Sequence[int]] = None,
                      chunks: Optional[Sequence[int]] = None,
                      mesh_shapes: Optional[Sequence] = None,
                      store_dtype=None,
                      budget: Optional[int] = None) -> List[TunedConfig]:
    """The legal configuration lattice for one problem geometry.

    ``default`` (the engine's untuned configuration) is always the first
    element — the search can only ever match or beat its prediction, and
    ties resolve to it.  Blocks whose padded single-tile layout coincides
    (block ≥ 2·2^ceil(log2(h)) beyond the first covering tile) are pruned
    by the ``block >= 2 * h`` guard; per-block chunk ladders follow the
    block's own packed bytes (a wider block pads more, so its VMEM-auto
    chunk is smaller).
    """
    blocks = tuple(blocks) if blocks is not None else DEFAULT_BLOCKS
    blocks = tuple(dict.fromkeys(
        b for b in blocks if b == default.block or b < 2 * h or b <= h))
    if default.block not in blocks:
        blocks = (default.block,) + blocks
    if mesh_shapes is None:
        mesh_shapes = ([None] if n_devices <= 1 else
                       [None] + [tuple(s) for s in
                                 shardlib.mesh_shape_candidates(k, n_devices)
                                 if s != (1, 1)])
    else:
        mesh_shapes = [None if s is None else tuple(s) for s in mesh_shapes]
    if default.mesh_shape not in mesh_shapes:
        mesh_shapes = [default.mesh_shape] + list(mesh_shapes)

    cands = [default]
    seen = {default.key()}
    for mesh_shape in mesh_shapes:
        n_lam = 1 if mesh_shape is None else mesh_shape[1]
        q_loc = max(1, math.ceil(q / n_lam))
        for block in blocks:
            if chunks is not None:
                ladder = tuple(max(1, min(int(c), q_loc)) for c in chunks)
            elif store_dtype is not None and budget is not None:
                auto = shardlib.auto_lam_chunk(h, block, store_dtype, budget)
                ladder = chunk_ladder(auto, q_loc)
            else:
                ladder = chunk_ladder(default.lam_chunk, q_loc)
            for chunk in dict.fromkeys(ladder):
                cand = TunedConfig(block=block, lam_chunk=chunk,
                                   mesh_shape=mesh_shape)
                if cand.key() not in seen:
                    seen.add(cand.key())
                    cands.append(cand)
    return cands


# ----------------------------------------------------------------- scoring


def _abstract_problem(folds, lams) -> tuple:
    """ShapeDtypeStructs of the sweep's traced inputs (h_tr, g_tr,
    x_folds, y_folds) — nothing device-resident is needed to lower."""
    import jax

    def sds(x):
        return jax.ShapeDtypeStruct(np.shape(x), np.asarray(x).dtype
                                    if not hasattr(x, "dtype") else x.dtype)

    k, n_f, h = folds.x_folds.shape
    dtype = folds.fold_hess.dtype
    h_tr = jax.ShapeDtypeStruct((k, h, h), dtype)
    g_tr = jax.ShapeDtypeStruct((k, h), dtype)
    x_s = sds(folds.x_folds)
    y_s = sds(folds.y_folds)
    return h_tr, g_tr, x_s, y_s


def lower_sweep(engine, folds, lams):
    """AOT lower + compile the engine's fused sweep (``fold_state`` +
    chunked ``fold_errors`` in one jit) on abstract shapes.  Returns
    ``(compiled, chips)``.  Nothing executes — this is the tuner's (and
    the roofline bench's) read-only view of a candidate."""
    import jax
    import jax.numpy as jnp

    k = folds.fold_hess.shape[0]
    mesh = engine._resolve_mesh(k)
    engine._check_fold_axis(mesh, k)
    h_tr, g_tr, x_s, y_s = _abstract_problem(folds, lams)
    lams = jnp.asarray(lams)
    q = int(lams.shape[0])
    if mesh is not None:
        q += (-q) % mesh.shape[shardlib.CV_LAM_AXIS]
    lam_s = jax.ShapeDtypeStruct((q,), lams.dtype)
    compiled = engine._sweep_fn(mesh).lower(
        h_tr, g_tr, x_s, y_s, lam_s).compile()
    chips = 1 if mesh is None else int(np.prod(list(mesh.shape.values())))
    return compiled, chips


def score_candidates(engine, folds, lams, candidates: Sequence[TunedConfig],
                     *, hw: Optional[rl.HW] = None,
                     cache: Optional[TuningCache] = None
                     ) -> List[TunedConfig]:
    """Predict each candidate's step time — AOT lowering only, zero
    executions.  Returns the candidates with ``predicted_s`` filled in
    (order preserved).  ``cache`` (when given) only counts lowerings."""
    hw = hw or rl.detect_hw()
    out = []
    for cand in candidates:
        derived = engine._apply_tuned(cand)
        compiled, chips = lower_sweep(derived, folds, lams)
        if cache is not None:
            cache.lowerings += 1
        roof = rl.roofline(compiled, chips, hw=hw)
        out.append(dataclasses.replace(cand, predicted_s=roof.step_s))
    return out


# -------------------------------------------------------------------- tune


def default_config(engine, k: int, h: int, q: int, dtype) -> TunedConfig:
    """The engine's untuned configuration as a lattice point: strategy /
    engine block, the resolved λ-chunk (VMEM-auto, explicit int, or the
    whole grid when streaming is off), and the mesh the engine would
    build (the gcd heuristic under ``mesh='auto'``)."""
    block = getattr(engine.strategy, "block", None) or engine.block or 128
    chunk = engine._resolve_chunk(q, h, dtype)
    chunk = q if chunk is None else min(chunk, q)
    mesh = engine._resolve_mesh(k)
    mesh_shape = (None if mesh is None else
                  (mesh.shape[shardlib.CV_FOLD_AXIS],
                   mesh.shape[shardlib.CV_LAM_AXIS]))
    return TunedConfig(block=block, lam_chunk=chunk, mesh_shape=mesh_shape,
                       source="default")


def tune(engine, folds, lams, *, cache: Optional[TuningCache] = None,
         blocks: Optional[Sequence[int]] = None,
         chunks: Optional[Sequence[int]] = None,
         mesh_shapes: Optional[Sequence] = None,
         hw: Optional[rl.HW] = None) -> TunedConfig:
    """Choose the predicted-fastest configuration for ``engine`` on this
    problem geometry.  See the module docstring for the pipeline; the
    returned config's ``source`` is ``'cache'`` on a tuning-cache hit
    (no lowering at all), else ``'tuned'``.
    """
    import jax
    import jax.numpy as jnp

    hw = hw or rl.detect_hw()
    k, n_f, h = folds.x_folds.shape
    lams = jnp.asarray(lams)
    q = int(lams.shape[0])
    dtype = folds.fold_hess.dtype
    n_devices = len(jax.devices())

    default = default_config(engine, k, h, q, dtype)
    lattice_desc = dict(
        blocks=tuple(blocks) if blocks else DEFAULT_BLOCKS,
        chunks=tuple(chunks) if chunks else "auto-ladder",
        mesh_shapes=(tuple("none" if s is None else tuple(s)
                           for s in mesh_shapes)
                     if mesh_shapes is not None else "factorizations"),
        default=default.key())
    meta = (engine.strategy.cache_meta(lams)
            if hasattr(engine.strategy, "cache_meta") else None)
    params = dict(meta["params"]) if meta else {}
    params.pop("block", None)                     # block is what we tune
    params.setdefault("strategy", engine.strategy.name)

    digest = fingerprint(
        h=h, k=k, n_f=n_f, q=q, dtype=str(dtype), lam_dtype=str(lams.dtype),
        params=params, backend=engine._bk.name,
        precision=engine._prec.descriptor(), lattice=lattice_desc,
        hw_name=hw.name)
    if cache is not None:
        hit = cache.get(digest)
        if hit is not None:
            return dataclasses.replace(hit, source="cache")

    store_dtype = engine._prec.store_dtype(dtype)
    from repro.core.engine import LAM_CHUNK_BUDGET_BYTES
    cands = candidate_lattice(
        h=h, k=k, q=q, n_devices=n_devices, default=default,
        blocks=blocks, chunks=chunks, mesh_shapes=mesh_shapes,
        store_dtype=store_dtype, budget=LAM_CHUNK_BUDGET_BYTES)
    scored = score_candidates(engine, folds, lams, cands, hw=hw, cache=cache)
    # strict < over a default-first list: ties (and equal-cost degenerate
    # candidates) resolve to the default configuration
    best = scored[0]
    for cand in scored[1:]:
        if cand.predicted_s < best.predicted_s:
            best = cand
    chosen = dataclasses.replace(best, source="tuned")
    if cache is not None:
        cache.put(digest, chosen)
    return chosen
