"""int8 error-feedback gradient compression for the DP all-reduce.

Classic EF-SGD scheme: the residual between the true gradient and its
quantized transport is carried to the next step, so compression error does
not bias the trajectory.  The compressed sync runs under ``shard_map`` with
per-device local gradients, so the wire format really is int8 (2-phase:
int8 reduce-scatter equivalent + scale psum) — this is the production path
for pure-DP replicas; FSDP configs keep fp32 reduce-scatter (their weight
all-gathers dominate the wire anyway, see §Roofline).
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

__all__ = ["quantize_int8", "dequantize_int8", "ef_compress_tree",
           "compressed_psum_tree"]


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-30
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def ef_compress_tree(grads: Any, residual: Any) -> Tuple[Any, Any]:
    """Error-feedback quantization: returns (dequantized grads, new residual).

    Local transform — combine with a psum (below) for the DP sync.
    """
    def one(g, r):
        gf = g.astype(jnp.float32) + r
        q, s = quantize_int8(gf)
        deq = dequantize_int8(q, s)
        return deq.astype(g.dtype), gf - deq

    out = jax.tree.map(one, grads, residual)
    is_t = lambda x: isinstance(x, tuple)
    deq = jax.tree.map(lambda o: o[0], out, is_leaf=is_t)
    res = jax.tree.map(lambda o: o[1], out, is_leaf=is_t)
    return deq, res


def compressed_psum_tree(grads: Any, residual: Any, axis_names) -> Tuple[Any, Any]:
    """int8 EF psum over ``axis_names`` (call inside shard_map)."""
    def one(g, r):
        gf = g.astype(jnp.float32) + r
        q, s = quantize_int8(gf)
        # int8 on the wire; accumulate in int32 to avoid overflow
        qsum = jax.lax.psum(q.astype(jnp.int32), axis_names)
        ssum = jax.lax.psum(s, axis_names)  # sum of scales bounds the error
        n = jax.lax.psum(jnp.ones((), jnp.float32), axis_names)
        deq = qsum.astype(jnp.float32) * (ssum / n) / n
        local_deq = dequantize_int8(q, s)
        return deq.astype(g.dtype), gf - local_deq

    out = jax.tree.map(one, grads, residual)
    is_t = lambda x: isinstance(x, tuple)
    deq = jax.tree.map(lambda o: o[0], out, is_leaf=is_t)
    res = jax.tree.map(lambda o: o[1], out, is_leaf=is_t)
    return deq, res
