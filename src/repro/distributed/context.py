"""Mesh context threaded through the model code.

``MeshCtx`` carries the mesh handle plus the axis-name conventions:
  dp_axes  — axes batch/tokens shard over (("pod","data") or ("data",))
  tp_axis  — tensor/expert-parallel axis ("model")
  fsdp     — whether weight matrices additionally shard over dp_axes[-1]

``MeshCtx(None)`` (no mesh) runs everything single-device — used by the CPU
smoke tests; model code must work identically in both modes.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["MeshCtx"]


@dataclasses.dataclass(frozen=True)
class MeshCtx:
    mesh: Optional[Mesh]
    dp_axes: Tuple[str, ...] = ("data",)
    tp_axis: str = "model"
    fsdp: bool = False

    @classmethod
    def from_mesh(cls, mesh: Optional[Mesh], fsdp: bool = False) -> "MeshCtx":
        if mesh is None:
            return cls(None, fsdp=fsdp)
        names = mesh.axis_names
        dp = tuple(n for n in names if n != "model")
        return cls(mesh, dp_axes=dp, tp_axis="model", fsdp=fsdp)

    @property
    def fsdp_axis(self) -> Optional[str]:
        return self.dp_axes[-1] if (self.fsdp and self.mesh is not None) else None

    def axis_size(self, name: str) -> int:
        if self.mesh is None:
            return 1
        return self.mesh.shape[name]

    @property
    def tp_size(self) -> int:
        return self.axis_size(self.tp_axis) if self.mesh is not None else 1

    @property
    def dp_size(self) -> int:
        if self.mesh is None:
            return 1
        s = 1
        for a in self.dp_axes:
            s *= self.axis_size(a)
        return s

    def sharding(self, *spec) -> Optional[NamedSharding]:
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, P(*spec))

    def constrain(self, x, *spec):
        """with_sharding_constraint that is a no-op without a mesh."""
        if self.mesh is None:
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, P(*spec)))
