"""One HLO-dtype → itemsize table shared by every HLO-text cost walker.

``hlo_cost.py`` (the loop-aware walker) and ``roofline.py`` (the
collective-bytes parser) both parse shapes like ``bf16[128,256]`` out of
compiled HLO text.  They used to carry private copies of this table, and
the copies drifted: the roofline parser was missing the fp8 / 4-bit /
token entries, so collective wire bytes silently dropped fp8 shapes.
One definition, imported by both, so a dtype added for one walker is
priced by the other too.

Sub-byte types (``s4``/``u4``) are priced at their *storage* granularity
(1 byte — XLA packs two nibbles per byte only in late layout passes, and
a conservative over-count keeps the memory term honest).  ``token`` is a
pure ordering artifact and moves no bytes.
"""
from __future__ import annotations

__all__ = ["DTYPE_BYTES"]

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "token": 0, "f8e4m3fn": 1, "f8e5m2": 1,
}
