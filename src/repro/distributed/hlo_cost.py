"""Loop-aware cost model over post-SPMD optimized HLO text.

``compiled.cost_analysis()`` counts a while-loop body ONCE, which silently
drops ~n_layers× of the FLOPs for scan-stacked models (and every collective
inside the loop).  This walker parses the HLO text, builds a per-computation
symbol table, expands ``while`` bodies by their ``known_trip_count`` (nested
loops multiply), and accumulates:

  flops       — dot (exact: 2·result·contracted), conv (approx), fusions ≈ 1/elem
  hbm bytes   — per instruction: result + operand bytes (XLA's own
                "bytes accessed" convention), fusion internals excluded
  wire bytes  — ring formulas per collective (see roofline.py), counted
                inside loops with multiplicity

Shapes in the compiled module are per-device, so all numbers are
per-device.  This is the basis of EXPERIMENTS.md §Roofline.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

from .dtype_bytes import DTYPE_BYTES as _DTYPE_BYTES

__all__ = ["analyze_hlo", "HloCost"]

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*"
    r"((?:\((?:[^()]|\([^()]*\))*\))|(?:[\w\[\],{}\d]+))\s+"
    r"([\w\-]+)\((.*)$")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->.*\{")
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_ARGS_RE = re.compile(r"%([\w.\-]+)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{([^}]*)\}")

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


def _parse_shapes(type_str: str) -> List[Tuple[str, List[int]]]:
    return [(dt, [int(d) for d in dims.split(",") if d])
            for dt, dims in _SHAPE_RE.findall(type_str)]


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _parse_shapes(type_str):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


def _type_elems(type_str: str) -> int:
    total = 0
    for _, dims in _parse_shapes(type_str):
        n = 1
        for d in dims:
            n *= d
        total += n
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_RE.search(line)
    if m:
        first = m.group(1).split("}")[0].lstrip("{")
        return max(len([x for x in first.split(",") if x.strip()]), 1)
    return 1


@dataclasses.dataclass
class HloCost:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    wire: Dict[str, float] = dataclasses.field(default_factory=dict)
    unknown_trip_loops: int = 0

    def add(self, other: "HloCost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.hbm_bytes += other.hbm_bytes * mult
        for k, v in other.wire.items():
            self.wire[k] = self.wire.get(k, 0.0) + v * mult
        self.unknown_trip_loops += other.unknown_trip_loops

    @property
    def wire_bytes(self) -> float:
        return sum(self.wire.values())


class _Instr:
    __slots__ = ("name", "type_str", "op", "rest", "line")

    def __init__(self, name, type_str, op, rest, line):
        self.name, self.type_str, self.op = name, type_str, op
        self.rest, self.line = rest, line


def _split_computations(text: str) -> Dict[str, List[_Instr]]:
    comps: Dict[str, List[_Instr]] = {}
    current: Optional[str] = None
    for line in text.splitlines():
        if current is None:
            m = _COMP_HDR_RE.match(line.strip())
            if m and line.rstrip().endswith("{"):
                current = m.group(1)
                comps[current] = []
            continue
        if line.startswith("}") or line.strip() == "}":
            current = None
            continue
        m = _INSTR_RE.match(line)
        if m:
            comps[current].append(
                _Instr(m.group(1), m.group(2), m.group(3), m.group(4), line))
    return comps


def _dot_flops(instr: _Instr, symbols: Dict[str, str]) -> float:
    result_elems = _type_elems(instr.type_str)
    m = _CONTRACT_RE.search(instr.line)
    args = _ARGS_RE.findall(instr.rest.split(")", 1)[0])
    contracted = 1
    if m and args:
        lhs_type = symbols.get(args[0])
        if lhs_type:
            shapes = _parse_shapes(lhs_type)
            if shapes:
                dims = shapes[0][1]
                for idx in (int(i) for i in m.group(1).split(",") if i):
                    if idx < len(dims):
                        contracted *= dims[idx]
    return 2.0 * result_elems * contracted


def analyze_hlo(text: str) -> HloCost:
    comps = _split_computations(text)
    symtabs: Dict[str, Dict[str, str]] = {
        cname: {i.name: i.type_str for i in instrs}
        for cname, instrs in comps.items()}
    memo: Dict[str, HloCost] = {}

    def _param_touch_bytes(cname: str) -> Dict[int, int]:
        """Per-parameter actually-touched bytes inside a fused computation:
        a parameter consumed ONLY through dynamic-slice/slice reads only the
        slice, not the stacked array (lax.scan xs access pattern, and the
        per-tile reads of a packed factor).  Zero-cost view ops (bitcast,
        reshape) between the parameter and the slice are looked through —
        XLA routinely emits ``bitcast(param) → slice`` for tiled layouts,
        and charging the full array per tile inflates the memory term by
        O(n_tiles)."""
        out: Dict[int, int] = {}
        if cname not in comps:
            return out
        instrs = comps[cname]
        pname_by_idx: Dict[str, int] = {}
        for ins in instrs:
            if ins.op == "parameter":
                m = re.match(r"(\d+)", ins.rest)
                if m:
                    pname_by_idx[ins.name] = int(m.group(1))
        for pname, idx in pname_by_idx.items():
            # alias set: the parameter plus every pure-view op chained off it
            aliases = {pname}
            grew = True
            while grew:
                grew = False
                for i in instrs:
                    if (i.op in ("bitcast", "reshape") and i.name not in aliases
                            and aliases & set(
                                _ARGS_RE.findall(i.rest.split("), ", 1)[0]))):
                        aliases.add(i.name)
                        grew = True
            uses = [i for i in instrs
                    if i.name not in aliases
                    and aliases & set(_ARGS_RE.findall(
                        i.rest.split("), ", 1)[0]))]
            if uses and all(u.op in ("dynamic-slice", "slice") for u in uses):
                out[idx] = sum(_type_bytes(u.type_str) for u in uses)
        return out

    def cost_of(cname: str, stack=()) -> HloCost:
        if cname in memo:
            return memo[cname]
        if cname in stack or cname not in comps:
            return HloCost()
        total = HloCost()
        sym = symtabs[cname]
        for ins in comps[cname]:
            op = ins.op
            if op in ("parameter", "constant", "get-tuple-element", "tuple",
                      "bitcast", "after-all", "iota"):
                continue
            # ---- bytes (bodies count their own) ----
            # Ops that touch only a slice-sized region must NOT be charged
            # their full operands: a lax.scan body dynamic-slices its xs
            # every trip, and charging the whole stacked array per trip
            # inflates the memory term by O(trip_count) (§Perf iteration 0).
            rb = _type_bytes(ins.type_str)
            if op in ("while", "call", "conditional"):
                pass
            elif op == "dynamic-slice":
                total.hbm_bytes += 2 * rb              # read + write the slice
            elif op == "dynamic-update-slice":
                args = _ARGS_RE.findall(ins.rest.split("), ", 1)[0])
                upd = _type_bytes(sym.get(args[1], "")) if len(args) > 1 else rb
                total.hbm_bytes += 2 * upd             # read + write the region
            elif op in ("slice", "broadcast", "reshape", "copy", "convert",
                        "transpose", "reverse", "pad"):
                total.hbm_bytes += 2 * rb              # stream result-sized data
            else:
                touch = {}
                if op == "fusion":
                    sub = _CALLS_RE.search(ins.line)
                    if sub:
                        touch = _param_touch_bytes(sub.group(1))
                ob = 0
                for i, a in enumerate(
                        _ARGS_RE.findall(ins.rest.split("), ", 1)[0])):
                    t = sym.get(a)
                    if t:
                        ob += touch.get(i, _type_bytes(t))
                total.hbm_bytes += rb + ob
            # ---- flops ----
            if op == "dot":
                total.flops += _dot_flops(ins, sym)
            elif op == "convolution":
                # depthwise/small convs only in this codebase: approximate
                total.flops += 2.0 * _type_elems(ins.type_str) * 8
            elif op in ("fusion", "add", "multiply", "subtract", "divide",
                        "exponential", "tanh", "rsqrt", "sqrt", "maximum",
                        "minimum", "compare", "select", "reduce", "log"):
                total.flops += _type_elems(ins.type_str)
            # ---- control flow ----
            if op == "while":
                body = _BODY_RE.search(ins.line)
                cond = _COND_RE.search(ins.line)
                trip_m = _TRIP_RE.search(ins.line)
                trips = int(trip_m.group(1)) if trip_m else 1
                if not trip_m:
                    total.unknown_trip_loops += 1
                if body:
                    total.add(cost_of(body.group(1), stack + (cname,)), trips)
                if cond:
                    total.add(cost_of(cond.group(1), stack + (cname,)), trips)
            elif op in ("call", "custom-call", "conditional"):
                for sub in _CALLS_RE.findall(ins.line):
                    total.add(cost_of(sub, stack + (cname,)))
            elif op == "fusion":
                pass  # internals stay in registers/VMEM: bytes already counted
            # ---- collectives (sync or -start; skip -done) ----
            base = op.replace("-start", "")
            if base in _COLLECTIVES and not op.endswith("-done"):
                size = _type_bytes(ins.type_str)
                if op.endswith("-start"):
                    # result of *-start is a tuple (operand, result[, …]):
                    # take the last array shape as the produced result
                    shapes = _parse_shapes(ins.type_str)
                    if len(shapes) >= 2:
                        dt, dims = shapes[-1]
                        n = 1
                        for d in dims:
                            n *= d
                        size = n * _DTYPE_BYTES.get(dt, 4)
                g = _group_size(ins.line)
                if g <= 1:
                    continue
                if base == "all-reduce":
                    wire = 2 * (g - 1) / g * size
                elif base == "all-gather":
                    wire = (g - 1) / g * size
                elif base == "reduce-scatter":
                    wire = (g - 1) * size
                elif base == "all-to-all":
                    wire = (g - 1) / g * size
                else:
                    wire = size
                total.wire[base] = total.wire.get(base, 0.0) + wire
        memo[cname] = total
        return total

    # entry computation: the one named like main / with ENTRY marker
    entry = None
    for line in text.splitlines():
        if line.startswith("ENTRY"):
            m = _COMP_HDR_RE.match(line.strip())
            if m:
                entry = m.group(1)
                break
    if entry is None:
        entry = next(iter(comps))
    # ENTRY header may not have been captured as a computation block opener
    if entry not in comps:
        entry = max(comps, key=lambda c: len(comps[c]))
    return cost_of(entry)
