"""Roofline-term extraction from a compiled dry-run artifact.

compute term    = HLO_FLOPs / (chips × peak_FLOP/s)
memory term     = HLO_bytes / (chips × HBM_bw)
collective term = wire_bytes_per_chip / link_bw

FLOPs/bytes come from ``compiled.cost_analysis()``; collective bytes are
parsed from the post-SPMD HLO text (shapes there are per-device), with ring
wire formulas per op:
  all-reduce      2(g−1)/g × result
  all-gather      (g−1)/g × result
  reduce-scatter  (g−1)   × result        (operand = g × result)
  all-to-all      (g−1)/g × result
  collective-permute       result

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s/link.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional

__all__ = ["HW", "collective_bytes", "roofline", "Roofline"]

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

HW = dict(peak_flops=PEAK_FLOPS, hbm_bw=HBM_BW, link_bw=LINK_BW)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"=\s*((?:\([^=]*?\))|(?:\S+))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        # iota format [n_groups,group_size]<=[total]
        return int(m.group(2))
    m = _GROUPS_RE.search(line)
    if m:
        first = m.group(1).split("}")[0].lstrip("{")
        ids = [x for x in first.split(",") if x.strip()]
        return max(len(ids), 1)
    return 1


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-device wire bytes by collective kind (ring formulas)."""
    out: Dict[str, float] = {}
    seen_done = set()
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        if "-done" in line:
            continue  # async pair: count the -start only
        result_type, op = m.group(1), m.group(2)
        size = _shape_bytes(result_type)
        g = _group_size(line)
        if g <= 1:
            continue
        if op == "all-reduce":
            wire = 2 * (g - 1) / g * size
        elif op == "all-gather":
            wire = (g - 1) / g * size
        elif op == "reduce-scatter":
            wire = (g - 1) * size
        elif op == "all-to-all":
            wire = (g - 1) / g * size
        else:  # collective-permute
            wire = size
        out[op] = out.get(op, 0.0) + wire
    return out


@dataclasses.dataclass
class Roofline:
    flops: float                 # per-device HLO flops
    hbm_bytes: float             # per-device bytes accessed
    wire_bytes: float            # per-device collective wire bytes
    by_collective: Dict[str, float]
    chips: int

    @property
    def compute_s(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.wire_bytes / LINK_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    def summary(self) -> Dict:
        return {
            "flops_per_device": self.flops,
            "hbm_bytes_per_device": self.hbm_bytes,
            "wire_bytes_per_device": self.wire_bytes,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "bottleneck": self.bottleneck,
            "by_collective": self.by_collective,
        }


def roofline(compiled, chips: int) -> Roofline:
    """Three roofline terms from the compiled artifact.

    Uses the loop-aware HLO walker (hlo_cost) rather than
    ``compiled.cost_analysis()`` because the latter counts while-loop
    (lax.scan layer stack) bodies exactly once — see EXPERIMENTS.md §Roofline
    for the calibration.  All values are per-device.
    """
    from . import hlo_cost

    text = compiled.as_text()
    cost = hlo_cost.analyze_hlo(text)
    return Roofline(flops=cost.flops, hbm_bytes=cost.hbm_bytes,
                    wire_bytes=cost.wire_bytes, by_collective=dict(cost.wire),
                    chips=chips)
