"""Roofline-term extraction from a compiled dry-run artifact.

compute term    = HLO_FLOPs / (chips × peak_FLOP/s)
memory term     = HLO_bytes / (chips × HBM_bw)
collective term = wire_bytes_per_chip / link_bw

FLOPs/bytes come from the loop-aware HLO walker (:mod:`.hlo_cost`);
collective bytes are parsed from the post-SPMD HLO text (shapes there are
per-device), with ring wire formulas per op:
  all-reduce      2(g−1)/g × result
  all-gather      (g−1)/g × result
  reduce-scatter  (g−1)   × result        (operand = g × result)
  all-to-all      (g−1)/g × result
  collective-permute       result

Hardware constants are an :class:`HW` dataclass, not module globals: the
autotuner ranks candidate configurations by these terms, so scoring a CPU
container against TPU v5e numbers would rank against the wrong machine.
:func:`detect_hw` picks a per-platform preset from
``jax.devices()[0].platform`` (``cpu`` / ``gpu`` / ``tpu``); the
``REPRO_HW`` env var forces a preset by name, and
``REPRO_HW_PEAK_FLOPS`` / ``REPRO_HW_HBM_BW`` / ``REPRO_HW_LINK_BW``
(plus ``REPRO_HW_CACHE_BW`` / ``REPRO_HW_CACHE_BYTES`` for the
cache-aware memory term) override individual terms (calibrating against
a measured machine).  The
module-level ``PEAK_FLOPS`` / ``HBM_BW`` / ``LINK_BW`` constants remain
the TPU v5e preset for backward compatibility.
"""
from __future__ import annotations

import dataclasses
import os
import re
from typing import Dict, List, Optional

from .dtype_bytes import DTYPE_BYTES as _DTYPE_BYTES

__all__ = ["HW", "HW_PRESETS", "detect_hw", "collective_bytes", "roofline",
           "Roofline"]

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9


@dataclasses.dataclass(frozen=True)
class HW:
    """Peak rates the three roofline terms divide by (per chip).

    ``cache_bw`` / ``cache_bytes`` turn on the cache-aware memory term
    (Ilic et al.'s cache-aware roofline): when the executable's static
    working set (``temp_size_in_bytes``) fits the last-level cache the
    memory term divides by ``cache_bw``; past it, the effective bandwidth
    blends toward ``hbm_bw`` in proportion to the spilled fraction.  Both
    ``None`` (the default) keeps the classic flat-``hbm_bw`` model.
    """

    name: str
    peak_flops: float   # FLOP/s
    hbm_bw: float       # bytes/s to HBM (or host RAM on CPU)
    link_bw: float      # bytes/s per inter-chip link
    cache_bw: Optional[float] = None     # bytes/s from last-level cache
    cache_bytes: Optional[float] = None  # last-level cache capacity


#: Per-platform presets keyed by ``jax.devices()[0].platform``.  tpu is
#: v5e (197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s ICI link); gpu is an
#: A100-80GB-class part (312 TFLOP/s bf16, 2.0 TB/s HBM, 300 GB/s NVLink);
#: cpu is a deliberately rough server-class estimate — on CPU the tuner
#: only needs the *relative* ordering of candidates, and all candidates
#: share the platform.  Only the cpu preset models the cache hierarchy
#: (~30 MB LLC at ~8× DRAM bandwidth): on CPU the candidates' total
#: flops/bytes are nearly flat and *locality* — whether the λ-chunk ×
#: packed-factor working set stays cache-resident — is what actually
#: separates their wall time; the accelerator presets keep the classic
#: HBM-only term (VMEM-sized tiles are the kernels' own contract).
HW_PRESETS = {
    "tpu": HW(name="tpu-v5e", peak_flops=PEAK_FLOPS, hbm_bw=HBM_BW,
              link_bw=LINK_BW),
    "gpu": HW(name="gpu-a100", peak_flops=312e12, hbm_bw=2.0e12,
              link_bw=300e9),
    "cpu": HW(name="cpu", peak_flops=1e11, hbm_bw=5e10, link_bw=2.5e10,
              cache_bw=4e11, cache_bytes=3e7),
}


def detect_hw() -> HW:
    """The :class:`HW` for this process: ``REPRO_HW`` preset override if
    set, else the preset for the default jax platform (cpu fallback for
    unknown platforms), with per-term ``REPRO_HW_*`` numeric overrides
    applied on top."""
    name = os.environ.get("REPRO_HW", "").strip().lower()
    if name:
        if name not in HW_PRESETS:
            raise ValueError(f"REPRO_HW={name!r}: no such preset; "
                             f"have {sorted(HW_PRESETS)}")
        hw = HW_PRESETS[name]
    else:
        import jax
        hw = HW_PRESETS.get(jax.devices()[0].platform, HW_PRESETS["cpu"])
    overrides = {}
    for field, env in (("peak_flops", "REPRO_HW_PEAK_FLOPS"),
                       ("hbm_bw", "REPRO_HW_HBM_BW"),
                       ("link_bw", "REPRO_HW_LINK_BW"),
                       ("cache_bw", "REPRO_HW_CACHE_BW"),
                       ("cache_bytes", "REPRO_HW_CACHE_BYTES")):
        val = os.environ.get(env)
        if val:
            overrides[field] = float(val)
    if overrides:
        hw = dataclasses.replace(hw, name=hw.name + "+env", **overrides)
    return hw


_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"=\s*((?:\([^=]*?\))|(?:\S+))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        # iota format [n_groups,group_size]<=[total]
        return int(m.group(2))
    m = _GROUPS_RE.search(line)
    if m:
        first = m.group(1).split("}")[0].lstrip("{")
        ids = [x for x in first.split(",") if x.strip()]
        return max(len(ids), 1)
    return 1


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-device wire bytes by collective kind (ring formulas)."""
    out: Dict[str, float] = {}
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        if "-done" in line:
            continue  # async pair: count the -start only
        result_type, op = m.group(1), m.group(2)
        size = _shape_bytes(result_type)
        g = _group_size(line)
        if g <= 1:
            continue
        if op == "all-reduce":
            wire = 2 * (g - 1) / g * size
        elif op == "all-gather":
            wire = (g - 1) / g * size
        elif op == "reduce-scatter":
            wire = (g - 1) * size
        elif op == "all-to-all":
            wire = (g - 1) / g * size
        else:  # collective-permute
            wire = size
        out[op] = out.get(op, 0.0) + wire
    return out


@dataclasses.dataclass
class Roofline:
    flops: float                 # per-device HLO flops
    hbm_bytes: float             # per-device bytes accessed
    wire_bytes: float            # per-device collective wire bytes
    by_collective: Dict[str, float]
    chips: int
    hw: Optional[HW] = None      # None = detect for this process
    temp_bytes: Optional[float] = None  # static working set (temp buffers)

    def __post_init__(self):
        if self.hw is None:
            self.hw = detect_hw()

    @property
    def compute_s(self) -> float:
        return self.flops / self.hw.peak_flops

    @property
    def effective_bw(self) -> float:
        """Bandwidth the memory term divides by: ``hbm_bw`` flat unless the
        HW models a cache AND the executable's working set is known — then
        cache-resident working sets stream at ``cache_bw`` and spilled ones
        blend toward ``hbm_bw`` by the spilled fraction."""
        hw = self.hw
        if (hw.cache_bw is None or hw.cache_bytes is None
                or not self.temp_bytes):
            return hw.hbm_bw
        if self.temp_bytes <= hw.cache_bytes:
            return hw.cache_bw
        resident = hw.cache_bytes / self.temp_bytes
        return resident * hw.cache_bw + (1.0 - resident) * hw.hbm_bw

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes / self.effective_bw

    @property
    def collective_s(self) -> float:
        return self.wire_bytes / self.hw.link_bw

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    def summary(self) -> Dict:
        return {
            "flops_per_device": self.flops,
            "hbm_bytes_per_device": self.hbm_bytes,
            "wire_bytes_per_device": self.wire_bytes,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "step_s": self.step_s,
            "bottleneck": self.bottleneck,
            "by_collective": self.by_collective,
            "hw": self.hw.name,
            "temp_bytes_per_device": self.temp_bytes,
            "effective_bw": self.effective_bw,
        }


def roofline(compiled, chips: int, hw: Optional[HW] = None) -> Roofline:
    """Three roofline terms from the compiled artifact.

    Uses the loop-aware HLO walker (hlo_cost) rather than
    ``compiled.cost_analysis()`` because the latter counts while-loop
    (lax.scan layer stack / lax.map λ-chunk stream) bodies exactly once —
    see EXPERIMENTS.md §Roofline for the calibration.  All values are
    per-device; ``hw=None`` detects the platform preset.
    """
    from . import hlo_cost

    text = compiled.as_text()
    cost = hlo_cost.analyze_hlo(text)
    temp = None
    try:
        temp = float(compiled.memory_analysis().temp_size_in_bytes)
    except Exception:  # noqa: BLE001 — backends without memory_analysis
        pass
    return Roofline(flops=cost.flops, hbm_bytes=cost.hbm_bytes,
                    wire_bytes=cost.wire_bytes, by_collective=dict(cost.wire),
                    chips=chips, hw=hw, temp_bytes=temp)
