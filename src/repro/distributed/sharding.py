"""Spec-axis → NamedSharding resolution.

Model param specs carry literal axis tags: "model", "fsdp" (resolved to the
innermost data axis when FSDP is on, else dropped) or None.  This module
turns a spec tree into NamedSharding / PartitionSpec trees and validates
divisibility so a bad mesh fails loudly at lowering time, not deep in XLA.
"""
from __future__ import annotations

import math
from typing import Any, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.params import Spec

__all__ = ["spec_pspec", "param_pspecs", "param_shardings", "data_pspec",
           "CV_FOLD_AXIS", "CV_LAM_AXIS", "make_cv_mesh", "cv_axis_sizes",
           "mesh_shape_candidates",
           "pad_to_multiple", "chunk_lams", "auto_lam_chunk",
           "cv_state_specs", "cv_chunk_in_specs", "StageRing"]


def spec_pspec(spec: Spec, ctx) -> P:
    """PartitionSpec for one param Spec under the given MeshCtx."""
    out = []
    for dim, ax in zip(spec.shape, spec.axes):
        if ax is None:
            out.append(None)
            continue
        mesh_ax = ctx.fsdp_axis if ax == "fsdp" else ax
        if mesh_ax is None:
            out.append(None)
            continue
        size = ctx.axis_size(mesh_ax)
        if size > 1 and dim % size != 0:
            raise ValueError(
                f"dim {dim} of {spec.shape} not divisible by mesh axis "
                f"{mesh_ax}={size}")
        out.append(mesh_ax)
    return P(*out)


def param_pspecs(tree: Any, ctx) -> Any:
    return jax.tree.map(lambda s: spec_pspec(s, ctx), tree,
                        is_leaf=lambda x: isinstance(x, Spec))


def param_shardings(tree: Any, ctx) -> Any:
    if ctx.mesh is None:
        raise ValueError("param_shardings requires a mesh")
    return jax.tree.map(lambda s: NamedSharding(ctx.mesh, spec_pspec(s, ctx)),
                        tree, is_leaf=lambda x: isinstance(x, Spec))


def data_pspec(ctx, ndim: int) -> P:
    """Batch-sharded PartitionSpec for an input of rank ``ndim``."""
    return P(ctx.dp_axes, *([None] * (ndim - 1)))


# --------------------------------------------------------------- CV engine
#
# The CV sweep is a dense (fold × λ) grid of independent solves, so its
# natural mesh is 2-D: fold Hessians shard over CV_FOLD_AXIS, the λ grid
# over CV_LAM_AXIS.  These helpers pick the mesh shape from the problem
# size and pad the λ grid so shard_map divisibility always holds.

CV_FOLD_AXIS = "folds"
CV_LAM_AXIS = "lams"


def cv_axis_sizes(k: int, n_devices: int) -> Tuple[int, int]:
    """(n_fold, n_lam) mesh shape for ``k`` folds on ``n_devices`` devices.

    The fold axis takes the largest device count that divides ``k`` (fold
    count is fixed by the problem; it cannot be padded), the λ axis absorbs
    the remaining devices (the λ grid *can* be padded, see
    :func:`pad_to_multiple`).
    """
    n_fold = math.gcd(k, n_devices)
    return n_fold, n_devices // n_fold


def mesh_shape_candidates(k: int, n_devices: int) -> list:
    """Every legal (n_fold, n_lam) mesh shape for ``k`` folds on
    ``n_devices`` devices: all factorizations ``n_fold · n_lam ==
    n_devices`` whose fold axis divides ``k`` (folds cannot be padded; the
    λ grid can).  This is the mesh dimension of the autotuner's candidate
    lattice — :func:`cv_axis_sizes` picks one member (the gcd heuristic),
    the tuner scores them all."""
    out = []
    for n_fold in range(1, n_devices + 1):
        if n_devices % n_fold == 0 and k % n_fold == 0:
            out.append((n_fold, n_devices // n_fold))
    return out


def make_cv_mesh(k: int, devices: Optional[Sequence[jax.Device]] = None) -> Mesh:
    """2-D (folds × lams) mesh over ``devices`` (default: all local)."""
    devices = list(devices if devices is not None else jax.devices())
    n_fold, n_lam = cv_axis_sizes(k, len(devices))
    dev = np.asarray(devices[: n_fold * n_lam]).reshape(n_fold, n_lam)
    return Mesh(dev, (CV_FOLD_AXIS, CV_LAM_AXIS))


def cv_state_specs(state: Any) -> Any:
    """Fold-sharded PartitionSpec tree for a per-fold state pytree.

    Cached/replayed fold states (e.g. the batched
    :class:`~repro.core.picholesky.PiCholesky` a warm sweep reuses) carry
    the fold axis as every leaf's leading dimension, so they shard over
    :data:`CV_FOLD_AXIS` exactly like the training Hessians they were
    fitted from — cache shards follow the folds × lams mesh.
    """
    return jax.tree.map(lambda _: P(CV_FOLD_AXIS), state)


def cv_chunk_in_specs(state: Any, aux: Any) -> tuple:
    """Per-stage ``in_specs`` for the pipelined sweep's λ-chunk stage.

    The staged (async) sweep evaluates one λ chunk per dispatch:
    ``chunk_errors(state, f_idx, h_tr, g_tr, x_folds, y_folds, lams_c, aux)``.
    Everything per-fold — the cached/stacked state pytree and the fold
    statistics — shards over :data:`CV_FOLD_AXIS` (leading axis), the λ
    chunk over :data:`CV_LAM_AXIS`, and the replicated ``aux`` from
    ``prepare`` rides along unsharded.  One definition shared by the
    warm-replay chunk stage and the cold pipelined stage, so the two paths
    cannot drift onto different meshes.
    """
    fold = P(CV_FOLD_AXIS)
    return (cv_state_specs(state), fold, fold, fold, fold, fold,
            P(CV_LAM_AXIS), jax.tree.map(lambda _: P(), aux))


class StageRing:
    """Bounded-lookahead dispatch ring (double buffering at ``depth=2``).

    The pipelined sweep dispatches per-fold ``fold_state`` stages without
    blocking; each dispatch consumes a donated per-fold Hessian slice, so
    unbounded lookahead would hold every fold's donated input in flight at
    once.  ``admit`` blocks on the *oldest* outstanding stage output before
    accepting a new dispatch, keeping at most ``depth`` stages (and their
    donated buffers) live — fold f+1's factorizations overlap fold f's
    chunk streaming, fold f+2's wait their turn.
    """

    def __init__(self, depth: int = 2):
        if depth < 1:
            raise ValueError(f"depth must be >= 1, got {depth}")
        self.depth = depth
        self._live: list = []

    def admit(self, staged: Any) -> Any:
        """Register a freshly dispatched stage output, blocking on the
        oldest outstanding one if the ring is full.  Returns ``staged``."""
        if len(self._live) >= self.depth:
            jax.block_until_ready(self._live.pop(0))
        self._live.append(staged)
        return staged

    def drain(self) -> None:
        """Block on everything still in flight (end of the stage stream)."""
        while self._live:
            jax.block_until_ready(self._live.pop(0))


def pad_to_multiple(x: jax.Array, multiple: int, axis: int = 0):
    """Pad ``x`` along ``axis`` (edge mode) to a length divisible by
    ``multiple``; returns (padded, original_length)."""
    n = x.shape[axis]
    pad = (-n) % multiple
    if pad == 0:
        return x, n
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, mode="edge"), n


def auto_lam_chunk(h: int, block: int, dtype, budget: int) -> int:
    """λ-chunk size whose per-chunk packed working set fits ``budget`` bytes.

    One definition shared by the engine's ``lam_chunk='auto'`` heuristic
    and the benches, so "the chunk that fits one VMEM" cannot drift.
    ``dtype`` is the *storage* dtype of the streamed interpolant rows
    (:meth:`~repro.core.precision.PrecisionPolicy.store_dtype`) — halving
    the itemsize (bf16) doubles the chunk at the same budget, which is the
    memory half of the mixed-precision contract.
    """
    from repro.core import packing   # local: distributed ↔ core layering
    per_lam = packing.packed_nbytes(h, block, dtype)
    return max(1, int(budget // per_lam))


def chunk_lams(lams: jax.Array, chunk: int):
    """Reshape a (local) λ grid into fixed-size chunks for the streamed
    sweep: (q,) → ((q_pad // chunk), chunk) plus the original length.

    Edge-padding keeps the padded tail numerically benign (repeats the last
    λ — an SPD shift that always factorizes); ``chunk > q`` degenerates to
    one padded chunk.  Composes with the λ-axis ``shard_map`` padding: that
    one runs on the global grid, this one on the per-device shard.
    """
    if chunk <= 0:
        raise ValueError(f"chunk must be positive, got {chunk}")
    padded, n = pad_to_multiple(lams, chunk)
    return padded.reshape(-1, chunk), n
