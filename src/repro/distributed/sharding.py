"""Spec-axis → NamedSharding resolution.

Model param specs carry literal axis tags: "model", "fsdp" (resolved to the
innermost data axis when FSDP is on, else dropped) or None.  This module
turns a spec tree into NamedSharding / PartitionSpec trees and validates
divisibility so a bad mesh fails loudly at lowering time, not deep in XLA.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models.params import Spec

__all__ = ["spec_pspec", "param_pspecs", "param_shardings", "data_pspec"]


def spec_pspec(spec: Spec, ctx) -> P:
    """PartitionSpec for one param Spec under the given MeshCtx."""
    out = []
    for dim, ax in zip(spec.shape, spec.axes):
        if ax is None:
            out.append(None)
            continue
        mesh_ax = ctx.fsdp_axis if ax == "fsdp" else ax
        if mesh_ax is None:
            out.append(None)
            continue
        size = ctx.axis_size(mesh_ax)
        if size > 1 and dim % size != 0:
            raise ValueError(
                f"dim {dim} of {spec.shape} not divisible by mesh axis "
                f"{mesh_ax}={size}")
        out.append(mesh_ax)
    return P(*out)


def param_pspecs(tree: Any, ctx) -> Any:
    return jax.tree.map(lambda s: spec_pspec(s, ctx), tree,
                        is_leaf=lambda x: isinstance(x, Spec))


def param_shardings(tree: Any, ctx) -> Any:
    if ctx.mesh is None:
        raise ValueError("param_shardings requires a mesh")
    return jax.tree.map(lambda s: NamedSharding(ctx.mesh, spec_pspec(s, ctx)),
                        tree, is_leaf=lambda x: isinstance(x, Spec))


def data_pspec(ctx, ndim: int) -> P:
    """Batch-sharded PartitionSpec for an input of rank ``ndim``."""
    return P(ctx.dp_axes, *([None] * (ndim - 1)))
