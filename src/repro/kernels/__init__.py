"""Pallas TPU kernels for the piCholesky hot spots.

  chol_blocked  blocked right-looking Cholesky (potf2 + trsm-as-GEMM + syrk)
  tri_pack      tile-major triangular pack/unpack (§5 TPU adaptation)
  poly_interp   fused Horner evaluation + unpack (beyond-paper fusion)
  trsm          blocked substitution with pre-inverted diagonal tiles
  ops           jit'd wrappers (REPRO_KERNELS=pallas|ref)
  ref           pure-jnp oracles
"""
from . import ops, ref  # noqa: F401
