"""Blocked right-looking Cholesky as Pallas TPU kernels.

The factorization ``A = LLᵀ`` is the paper's dominant O(d³) cost.  TPU-native
structure (MXU tiles instead of LAPACK panels):

* ``_panel_kernel`` — one pallas_call per tile-column: grid step 0 runs the
  unblocked ``potf2`` on the diagonal tile **and** forms ``L₁₁⁻¹`` in a VMEM
  scratch (persists across the sequential TPU grid); steps i>0 are pure MXU
  GEMMs ``L_{i1} = A_{i1}·L₁₁⁻ᵀ`` (the trsm, recast as a matmul against the
  cached inverse — triangular solves don't vectorize on the MXU, matmuls do).
* ``_syrk_kernel`` — trailing update ``A₂₂ −= L₂₁L₂₁ᵀ`` over the lower tiles
  only (grid masks the strictly-upper tiles to a copy-through).

The JAX-level driver walks tile columns; every FLOP executed between panel
potf2s is a dense ``B×B`` MXU matmul, which is what drives this kernel
toward the compute roofline on real hardware.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .compat import vmem_scratch

__all__ = ["cholesky_blocked"]


def _potf2(a: jax.Array) -> jax.Array:
    """Unblocked Cholesky of a B×B tile (functional, in-register)."""
    b = a.shape[0]
    iota = jax.lax.iota(jnp.int32, b)

    def body(k, a):
        pivot = jnp.sqrt(a[k, k])
        col = jnp.where(iota > k, a[:, k] / pivot, 0.0)
        col = jnp.where(iota == k, pivot, col)
        mask = (iota[:, None] > k) & (iota[None, :] > k)
        a = jnp.where(mask, a - col[:, None] * col[None, :], a)
        return a.at[:, k].set(col)

    a = jax.lax.fori_loop(0, b, body, a)
    return jnp.where(iota[:, None] >= iota[None, :], a, 0.0)


def _inv_lower(l: jax.Array) -> jax.Array:
    """X with L X = I via row-wise forward substitution (in-register)."""
    b = l.shape[0]
    iota = jax.lax.iota(jnp.int32, b)
    eye = jnp.eye(b, dtype=l.dtype)

    def body(k, x):
        row = l[k]
        s = jnp.sum(jnp.where((iota < k)[:, None], x, 0.0) * row[:, None], axis=0)
        return x.at[k].set((eye[k] - s) / l[k, k])

    return jax.lax.fori_loop(0, b, body, jnp.zeros_like(l))


def _make_panel_kernel(compute_dtype=None):
    def kernel(panel_ref, out_ref, inv_ref):
        i = pl.program_id(0)

        @pl.when(i == 0)
        def _diag():
            # potf2 + inversion always run at the panel (accumulation)
            # dtype — the sequential recurrences are the unstable half
            l11 = _potf2(panel_ref[...])
            inv_ref[...] = _inv_lower(l11)
            out_ref[...] = l11

        @pl.when(i > 0)
        def _sub():
            # trsm recast as GEMM against the cached inverse: A·(L⁻¹)ᵀ —
            # MXU operands at the compute dtype, fp32+ accumulation
            panel = panel_ref[...]
            inv_t = inv_ref[...].T
            if compute_dtype is not None:
                panel = panel.astype(compute_dtype)
                inv_t = inv_t.astype(compute_dtype)
            out_ref[...] = jnp.dot(panel, inv_t,
                                   preferred_element_type=out_ref.dtype)

    return kernel


def _make_syrk_kernel(compute_dtype=None):
    def kernel(panel_i_ref, panel_j_ref, c_ref, out_ref):
        i = pl.program_id(0)
        j = pl.program_id(1)

        @pl.when(i >= j)
        def _update():
            pi = panel_i_ref[...]
            pj_t = panel_j_ref[...].T
            if compute_dtype is not None:
                pi = pi.astype(compute_dtype)
                pj_t = pj_t.astype(compute_dtype)
            out_ref[...] = c_ref[...] - jnp.dot(
                pi, pj_t, preferred_element_type=out_ref.dtype)

        @pl.when(i < j)
        def _copy():
            out_ref[...] = c_ref[...]

    return kernel


def _factor_panel(panel: jax.Array, block: int, interpret: bool,
                  compute_dtype=None) -> jax.Array:
    m = panel.shape[0]
    nt = m // block
    return pl.pallas_call(
        _make_panel_kernel(compute_dtype),
        grid=(nt,),
        in_specs=[pl.BlockSpec((block, block), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block, block), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(panel.shape, panel.dtype),
        scratch_shapes=[vmem_scratch((block, block), panel.dtype)],
        interpret=interpret,
    )(panel)


def _syrk_update(trailing: jax.Array, panel: jax.Array, block: int,
                 interpret: bool, compute_dtype=None) -> jax.Array:
    m = trailing.shape[0]
    nt = m // block
    return pl.pallas_call(
        _make_syrk_kernel(compute_dtype),
        grid=(nt, nt),
        in_specs=[
            pl.BlockSpec((block, block), lambda i, j: (i, 0)),
            pl.BlockSpec((block, block), lambda i, j: (j, 0)),
            pl.BlockSpec((block, block), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((block, block), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct(trailing.shape, trailing.dtype),
        interpret=interpret,
    )(panel, panel, trailing)


@functools.partial(jax.jit, static_argnames=("block", "interpret",
                                             "compute_dtype", "accum_dtype"))
def cholesky_blocked(a: jax.Array, block: int = 256, *,
                     interpret: bool | None = None,
                     compute_dtype=None, accum_dtype=None) -> jax.Array:
    """Cholesky factor of SPD ``a`` (h×h) -> lower-triangular L (h×h).

    Mixed precision: the factorization state (panels, trailing matrix, the
    returned L) lives at ``accum_dtype`` — a 16-bit input is promoted, the
    potf2 recurrence never runs in bf16 — while ``compute_dtype`` (when
    given) feeds the syrk/trsm GEMM operands to the MXU at reduced
    precision with full-precision accumulation.  Defaults inherit
    ``a.dtype`` (bit-compatible with the pre-policy kernel).
    """
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    from .packed_trsm import _resolve_dtypes
    cd, ad = _resolve_dtypes(a.dtype, compute_dtype, accum_dtype)
    a = a.astype(ad)
    cd_gemm = None if cd == ad else cd
    h = a.shape[-1]
    nt = -(-h // block)
    hp = nt * block
    if hp != h:
        # pad with identity on the trailing diagonal — keeps potf2 finite
        a = jnp.pad(a, ((0, hp - h), (0, hp - h)))
        a = a.at[h:, h:].set(jnp.eye(hp - h, dtype=a.dtype))

    out = a
    for j in range(nt):
        lo = j * block
        panel = jax.lax.dynamic_slice(out, (lo, lo), (hp - lo, block))
        panel = _factor_panel(panel, block, interpret, cd_gemm)
        out = jax.lax.dynamic_update_slice(out, panel, (lo, lo))
        if j + 1 < nt:
            sub = jax.lax.dynamic_slice(panel, (block, 0), (hp - lo - block, block))
            trailing = jax.lax.dynamic_slice(
                out, (lo + block, lo + block), (hp - lo - block, hp - lo - block))
            trailing = _syrk_update(trailing, sub, block, interpret, cd_gemm)
            out = jax.lax.dynamic_update_slice(out, trailing, (lo + block, lo + block))
    return jnp.tril(out[:h, :h])
