"""Version compatibility for the Pallas TPU API surface.

jax renamed the TPU memory-space handles across 0.4.x → 0.5.x:

* old: ``pltpu.VMEM(shape, dtype)`` scratch, ``pltpu.SMEM`` block memory space
* new: ``pltpu.MemorySpace.VMEM(shape, dtype)`` / ``pltpu.MemorySpace.SMEM``

Kernels import these two names instead of touching ``pltpu`` directly so the
same kernel body lowers under either jax release.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

__all__ = ["vmem_scratch", "SMEM"]

if hasattr(pltpu, "VMEM"):
    vmem_scratch = pltpu.VMEM
    SMEM = pltpu.SMEM
else:  # pragma: no cover - newer jax
    vmem_scratch = pltpu.MemorySpace.VMEM
    SMEM = pltpu.MemorySpace.SMEM
