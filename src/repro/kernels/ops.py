"""Public jit'd entry points for the Pallas kernel layer.

``kernel_backend()`` decides per-call whether to run the real Pallas path
(interpret=True on CPU, compiled on TPU) or fall back to the jnp oracle —
callers toggle with the ``REPRO_KERNELS`` env var ("pallas" | "ref").
"""
from __future__ import annotations

import os

import jax

from . import (chol_blocked, packed_trsm, poly_interp, ref,
               ssm_scan as ssm_scan_mod, tri_pack, trsm)

__all__ = ["kernel_backend", "pack_tril", "unpack_tril", "cholesky",
           "interp_factors", "interp_solve", "solve_lower",
           "solve_lower_packed", "solve_packed", "solve_factor_sweep",
           "ssm_scan"]


def kernel_backend() -> str:
    return os.environ.get("REPRO_KERNELS", "pallas")


def pack_tril(mat, block: int = 128):
    if kernel_backend() == "ref":
        return ref.pack_tril(mat, block)
    return tri_pack.pack_tril(mat, block)


def unpack_tril(vec, h: int, block: int = 128):
    if kernel_backend() == "ref":
        return ref.unpack_tril(vec, h, block)
    return tri_pack.unpack_tril(vec, h, block)


def cholesky(a, block: int = 256):
    if kernel_backend() == "ref":
        return ref.cholesky(a)
    return chol_blocked.cholesky_blocked(a, block)


def interp_factors(theta, lams, h: int, block: int = 128, center=0.0):
    if kernel_backend() == "ref":
        return ref.interp_factors(theta, lams, h, block, center)
    return poly_interp.interp_factors(theta, lams, h, block, center=center)


def solve_lower(l, g, block: int = 256, *, transpose: bool = False):
    if kernel_backend() == "ref":
        return ref.solve_lower(l, g, transpose=transpose)
    return trsm.solve_lower_blocked(l, g, block, transpose=transpose)


def solve_lower_packed(vec, g, h: int, block: int = 128, *,
                       transpose: bool = False):
    if kernel_backend() == "ref":
        return ref.solve_lower_packed(vec, g, h, block, transpose=transpose)
    return packed_trsm.solve_lower_packed(vec, g, h, block,
                                          transpose=transpose)


def solve_packed(vec, g, h: int, block: int = 128):
    if kernel_backend() == "ref":
        return ref.solve_packed(vec, g, h, block)
    return packed_trsm.solve_packed(vec, g, h, block)


def interp_solve(theta, lams, g, h: int, block: int = 128, center=0.0):
    if kernel_backend() == "ref":
        return ref.interp_solve(theta, lams, g, h, block, center)
    return poly_interp.interp_solve(theta, lams, g, h, block, center=center)


def solve_factor_sweep(ls, g, block: int = 256):
    if kernel_backend() == "ref":
        return ref.solve_factor_sweep(ls, g)
    return trsm.solve_factor_sweep(ls, g, block)


def ssm_scan(xc, dt, b_mat, c_mat, a, d_skip, chunk: int = 128,
             di_block: int = 256):
    if kernel_backend() == "ref":
        return ref.ssm_scan(xc, dt, b_mat, c_mat, a, d_skip)
    return ssm_scan_mod.ssm_scan(xc, dt, b_mat, c_mat, a, d_skip,
                                 chunk=chunk, di_block=di_block)
