"""Triangular solves directly on tile-packed factors (packed-domain trsm).

The packed layout (:mod:`repro.core.packing`) stores the lower tiles of L in
tile-column-major order, so a column sweep of blocked forward substitution
walks the packed buffer panel by panel — and because column ``i`` of packed
``L`` is exactly row ``i`` of ``Lᵀ``, the *reverse* column sweep is back
substitution.  Nothing ever unpacks to the dense ``(h, h)`` matrix: peak
kernel footprint is one ``B×B`` tile + the RHS block, which is what lets the
λ sweep stream interpolated factors in constant memory.

Kernel layout: sequential grid ``(nt, nt)`` — outer step ``s`` is the tile
row being solved, inner step ``u`` streams that row's tiles (fetched via a
scalar-prefetched (s, u) → packed-index map; already-solved rows come from
the revisited output ref).  Diagonal tiles are pre-inverted once outside the
kernel (shared by both sweeps: ``inv(L_jj)ᵀ = inv(L_jjᵀ)``) so every inner
step is one ``B×B @ B×q`` MXU GEMM.

Mixed precision (:mod:`repro.core.precision`): ``compute_dtype`` is what the
MXU GEMM operands are cast to (bf16 halves the streamed tile traffic),
``accum_dtype`` is what the GEMMs accumulate in and the solution/output ref
live in — fp32 whenever compute is 16-bit, so the substitution recurrence
never accumulates rounding in bf16.  Diagonal tiles are inverted at the
accumulation dtype (inverting a bf16-rounded triangle is the unstable half
of the tradeoff), then cast down for the MXU.  Defaults (``None``) inherit
the factor's dtype — bit-compatible with the pre-policy kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import packing

__all__ = ["solve_lower_packed", "solve_packed"]


def _make_kernel(block: int, nt: int, reverse: bool):
    def kernel(idx_ref, inv_ref, g_ref, tiles_ref, out_ref, acc_ref):
        s = pl.program_id(0)
        u = pl.program_id(1)
        i = (nt - 1 - s) if reverse else s   # tile row being solved
        t = (nt - 1 - u) if reverse else u   # tile column being visited

        @pl.when((s == 0) & (u == 0))
        def _init():  # unsolved rows must read 0.0, not uninitialized VMEM
            out_ref[...] = jnp.zeros_like(out_ref)

        @pl.when(u == 0)
        def _zero_acc():
            acc_ref[...] = jnp.zeros_like(acc_ref)

        # In iteration order, off-diagonal contributions (solved rows) come
        # first, the diagonal solve last: forward visits t = 0..i, the
        # reverse sweep visits t = nt−1..i.
        contrib = (t > i) if reverse else (t < i)

        @pl.when(contrib)
        def _accumulate():
            # MXU operands at the compute dtype (the tile already is), the
            # accumulation at the scratch/accum dtype
            w_t = out_ref[pl.ds(t * block, block), :]
            tile = tiles_ref[0].T if reverse else tiles_ref[0]
            acc_ref[...] += jnp.dot(tile, w_t.astype(tile.dtype),
                                    preferred_element_type=acc_ref.dtype)

        @pl.when(t == i)
        def _solve():
            g_i = g_ref[pl.ds(i * block, block), :]
            inv = inv_ref[0].T if reverse else inv_ref[0]
            rhs = (g_i - acc_ref[...]).astype(inv.dtype)
            out_ref[pl.ds(i * block, block), :] = jnp.dot(
                inv, rhs, preferred_element_type=out_ref.dtype)

    return kernel


@functools.lru_cache(maxsize=None)
def _step_tile_indices(h: int, block: int, reverse: bool) -> np.ndarray:
    """(nt²,) packed-tile index for grid step (s, u); 0 for skipped steps."""
    nt = packing.num_tiles(h, block)
    pmap = packing.tile_pos_map(h, block)
    idx = np.zeros(nt * nt, np.int32)
    for s in range(nt):
        i = nt - 1 - s if reverse else s
        for u in range(nt):
            t = nt - 1 - u if reverse else u
            if reverse and t >= i:
                idx[s * nt + u] = pmap[t, i]   # row i of Lᵀ = column i of L
            elif not reverse and t <= i:
                idx[s * nt + u] = pmap[i, t]
    return idx


def _resolve_dtypes(ref_dtype, compute_dtype, accum_dtype):
    """(compute, accum) dtype pair: inherit by default, never accumulate in
    a 16-bit type — the one rule shared by every packed kernel (the rule
    itself lives in :func:`repro.core.precision.default_accum_dtype`)."""
    from repro.core.precision import default_accum_dtype

    cd = jnp.dtype(compute_dtype) if compute_dtype is not None \
        else jnp.dtype(ref_dtype)
    ad = (jnp.dtype(accum_dtype) if accum_dtype is not None
          else default_accum_dtype(cd))
    return cd, ad


def _inv_diag_tiles(vec: jax.Array, h: int, block: int,
                    accum_dtype=None) -> jax.Array:
    """(nt, B, B) pre-inverted diagonal tiles (identity-padded tail),
    inverted at ``accum_dtype`` for stability."""
    tiles = vec.reshape(-1, block, block)
    diag = packing._diag_tiles(tiles, h, block)
    if accum_dtype is not None:
        diag = diag.astype(accum_dtype)
    return packing.invert_diag_tiles(diag)


@functools.partial(jax.jit, static_argnames=("h", "block", "transpose",
                                             "interpret", "compute_dtype",
                                             "accum_dtype"))
def solve_lower_packed(vec: jax.Array, g: jax.Array, h: int, block: int = 128,
                       *, transpose: bool = False,
                       interpret: bool | None = None,
                       compute_dtype=None, accum_dtype=None) -> jax.Array:
    """Solve L w = g (or Lᵀ w = g) from the packed factor ``vec`` (P,).

    ``g``: (h,) or (h, q).  Matches :func:`repro.core.packing.solve_lower_packed`.
    ``compute_dtype`` / ``accum_dtype``: see module doc — defaults inherit
    ``vec.dtype``; the solution comes back in the accumulation dtype.
    """
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    cd, ad = _resolve_dtypes(vec.dtype, compute_dtype, accum_dtype)
    nt = packing.num_tiles(h, block)
    hp = nt * block
    squeeze = g.ndim == 1
    g2 = (g[:, None] if squeeze else g).astype(ad)
    q = g2.shape[1]
    if hp != h:
        g2 = jnp.pad(g2, ((0, hp - h), (0, 0)))

    tiles = vec.astype(cd).reshape(-1, block, block)
    inv_diag = _inv_diag_tiles(vec, h, block, accum_dtype=ad).astype(cd)
    idx = jnp.asarray(_step_tile_indices(h, block, transpose))

    def inv_index(s, u, idx):
        return ((nt - 1 - s) if transpose else s, 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nt, nt),
        in_specs=[
            pl.BlockSpec((1, block, block), inv_index),
            pl.BlockSpec((hp, q), lambda s, u, idx: (0, 0)),
            pl.BlockSpec((1, block, block),
                         lambda s, u, idx: (idx[s * nt + u], 0, 0)),
        ],
        out_specs=pl.BlockSpec((hp, q), lambda s, u, idx: (0, 0)),
        scratch_shapes=[pltpu.VMEM((block, q), g2.dtype)],
    )
    w = pl.pallas_call(
        _make_kernel(block, nt, transpose),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((hp, q), g2.dtype),
        interpret=interpret,
    )(idx, inv_diag, g2, tiles)
    w = w[:h]
    return w[:, 0] if squeeze else w


def solve_packed(vec: jax.Array, g: jax.Array, h: int, block: int = 128, *,
                 interpret: bool | None = None,
                 compute_dtype=None, accum_dtype=None) -> jax.Array:
    """L Lᵀ θ = g entirely in the packed domain (forward + back sweep)."""
    w = solve_lower_packed(vec, g, h, block, transpose=False,
                           interpret=interpret, compute_dtype=compute_dtype,
                           accum_dtype=accum_dtype)
    return solve_lower_packed(vec, w, h, block, transpose=True,
                              interpret=interpret, compute_dtype=compute_dtype,
                              accum_dtype=accum_dtype)
