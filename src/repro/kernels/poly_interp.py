"""Fused Horner evaluation + triangular unpack (beyond-paper fusion).

The paper evaluates the D interpolating polynomials into a packed vector and
then unpacks it into L(λ) — two passes over O(d²) data.  On TPU the packed
coefficient tiles Θ (r+1 per tile) can be streamed through VMEM **once**,
Horner-evaluated in registers, and written directly to the unpacked factor
position — halving HBM traffic for the interpolation step (the step §3.3
prices at O(rd²), i.e. memory-bound: arithmetic intensity ≈ r/4 FLOP/byte).

Grid is (q, nt, nt): λ-major so each interpolated factor streams out
contiguously; the λ value reaches the kernel through SMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .compat import SMEM

from repro.core import packing

__all__ = ["interp_factors"]


def _make_kernel(degree: int):
    def kernel(pidx_ref, lam_ref, theta_ref, out_ref):
        t = pl.program_id(0)
        i = pl.program_id(1)
        j = pl.program_id(2)

        @pl.when(i >= j)
        def _lower():
            x = lam_ref[t]
            acc = theta_ref[degree, 0]
            for k in range(degree - 1, -1, -1):  # Horner, in registers
                acc = acc * x + theta_ref[k, 0]
            out_ref[0] = acc

        @pl.when(i < j)
        def _upper():
            out_ref[...] = jnp.zeros_like(out_ref)

    return kernel


@functools.partial(jax.jit, static_argnames=("h", "block", "interpret"))
def interp_factors(theta: jax.Array, lams: jax.Array, h: int, block: int = 128,
                   *, center: jax.Array | float = 0.0,
                   interpret: bool | None = None) -> jax.Array:
    """Evaluate Θ ((r+1) × P) at λ grid (q,) -> interpolated factors (q, h, h).

    Fuses polynomial evaluation with the packed→triangular unpack.
    """
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    degree = theta.shape[0] - 1
    nt = packing.num_tiles(h, block)
    ii, jj = packing.tile_index_pairs(h, block)
    pmap = np.zeros((nt, nt), np.int32)
    for p, (i, j) in enumerate(zip(ii, jj)):
        pmap[i, j] = p
    pidx = jnp.asarray(pmap.reshape(-1), jnp.int32)

    q = lams.shape[0]
    x = (lams.astype(theta.dtype) - jnp.asarray(center, theta.dtype))
    theta_t = theta.reshape(degree + 1, -1, block, block)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(q, nt, nt),
        in_specs=[
            pl.BlockSpec(memory_space=SMEM),  # λ values
            pl.BlockSpec((degree + 1, 1, block, block),
                         lambda t, i, j, pidx: (0, pidx[i * nt + j], 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block, block), lambda t, i, j, pidx: (t, i, j)),
    )
    out = pl.pallas_call(
        _make_kernel(degree),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((q, nt * block, nt * block), theta.dtype),
        interpret=interpret,
    )(pidx, x, theta_t)
    return out[:, :h, :h]
