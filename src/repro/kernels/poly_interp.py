"""Fused Horner evaluation + triangular unpack / packed solve.

The paper evaluates the D interpolating polynomials into a packed vector and
then unpacks it into L(λ) — two passes over O(d²) data.  On TPU the packed
coefficient tiles Θ (r+1 per tile) can be streamed through VMEM **once**,
Horner-evaluated in registers, and written directly to the unpacked factor
position — halving HBM traffic for the interpolation step (the step §3.3
prices at O(rd²), i.e. memory-bound: arithmetic intensity ≈ r/4 FLOP/byte).

Two fusions live here:

* :func:`interp_factors` — Horner + unpack: grid (q, nt, nt), λ-major so
  each interpolated factor streams out contiguously; the λ value reaches
  the kernel through SMEM.  Still materializes (q, h, h) — the debug /
  dense-consumer path.
* :func:`interp_solve` — Horner + packed trsm: the production sweep path.
  Interpolated tiles are Horner-evaluated in registers *inside* the
  triangular-solve walk of :mod:`repro.kernels.packed_trsm`, so no
  interpolated factor — packed or dense — is ever written to HBM.  Peak
  footprint per λ is one coefficient tile stack ((r+1)·B²) + the (h,)
  solution, which is what makes the chunked λ sweep O(chunk · h) instead
  of O(q · h²).

Mixed precision (:mod:`repro.core.precision`): Θ may arrive stored in bf16;
``compute_dtype`` sets the Horner/GEMM operand dtype (default: Θ's own),
``accum_dtype`` the GEMM accumulation + solution dtype (fp32 on 16-bit
compute).  Diagonal tiles are Horner-evaluated and inverted at the
accumulation dtype before being cast down for the MXU.  ``rhs_per_lam=True``
accepts a per-λ right-hand side (q, h[, m]) — the refinement sweep's
residuals — reusing the kernel's batched-RHS back-substitution path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .compat import SMEM

from repro.core import packing

__all__ = ["interp_factors", "interp_solve"]


def _make_kernel(degree: int):
    def kernel(pidx_ref, lam_ref, theta_ref, out_ref):
        t = pl.program_id(0)
        i = pl.program_id(1)
        j = pl.program_id(2)

        @pl.when(i >= j)
        def _lower():
            x = lam_ref[t]
            acc = theta_ref[degree, 0]
            for k in range(degree - 1, -1, -1):  # Horner, in registers
                acc = acc * x + theta_ref[k, 0]
            out_ref[0] = acc

        @pl.when(i < j)
        def _upper():
            out_ref[...] = jnp.zeros_like(out_ref)

    return kernel


@functools.partial(jax.jit, static_argnames=("h", "block", "interpret"))
def interp_factors(theta: jax.Array, lams: jax.Array, h: int, block: int = 128,
                   *, center: jax.Array | float = 0.0,
                   interpret: bool | None = None) -> jax.Array:
    """Evaluate Θ ((r+1) × P) at λ grid (q,) -> interpolated factors (q, h, h).

    Fuses polynomial evaluation with the packed→triangular unpack.
    """
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    degree = theta.shape[0] - 1
    nt = packing.num_tiles(h, block)
    pidx = jnp.asarray(packing.tile_pos_map(h, block).reshape(-1), jnp.int32)

    q = lams.shape[0]
    x = (lams.astype(theta.dtype) - jnp.asarray(center, theta.dtype))
    theta_t = theta.reshape(degree + 1, -1, block, block)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(q, nt, nt),
        in_specs=[
            pl.BlockSpec(memory_space=SMEM),  # λ values
            pl.BlockSpec((degree + 1, 1, block, block),
                         lambda t, i, j, pidx: (0, pidx[i * nt + j], 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block, block), lambda t, i, j, pidx: (t, i, j)),
    )
    out = pl.pallas_call(
        _make_kernel(degree),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((q, nt * block, nt * block), theta.dtype),
        interpret=interpret,
    )(pidx, x, theta_t)
    return out[:, :h, :h]


# ------------------------------------------------- fused Horner + packed trsm


def _make_solve_kernel(degree: int, block: int, nt: int, reverse: bool,
                       rhs_batched: bool):
    def kernel(idx_ref, lam_ref, inv_ref, g_ref, theta_ref, out_ref, acc_ref):
        c = pl.program_id(0)                 # λ index within the chunk
        s = pl.program_id(1)
        u = pl.program_id(2)
        i = (nt - 1 - s) if reverse else s   # tile row being solved
        t = (nt - 1 - u) if reverse else u   # tile column being visited

        @pl.when((s == 0) & (u == 0))
        def _init():
            out_ref[...] = jnp.zeros_like(out_ref)

        @pl.when(u == 0)
        def _zero_acc():
            acc_ref[...] = jnp.zeros_like(acc_ref)

        contrib = (t > i) if reverse else (t < i)

        @pl.when(contrib)
        def _accumulate():
            # Horner at the coefficient (compute) dtype: λ is quantized to
            # it per step, the GEMM accumulates at the scratch dtype
            x = lam_ref[c].astype(theta_ref.dtype)
            tile = theta_ref[degree, 0]
            for k in range(degree - 1, -1, -1):  # Horner, in registers
                tile = tile * x + theta_ref[k, 0]
            tile = tile.T if reverse else tile
            w_t = out_ref[0, pl.ds(t * block, block), :]
            acc_ref[...] += jnp.dot(tile, w_t.astype(tile.dtype),
                                    preferred_element_type=acc_ref.dtype)

        @pl.when(t == i)
        def _solve():
            if rhs_batched:
                g_i = g_ref[0, pl.ds(i * block, block), :]
            else:
                g_i = g_ref[pl.ds(i * block, block), :]
            inv = inv_ref[0, 0].T if reverse else inv_ref[0, 0]
            rhs = (g_i - acc_ref[...]).astype(inv.dtype)
            out_ref[0, pl.ds(i * block, block), :] = jnp.dot(
                inv, rhs, preferred_element_type=out_ref.dtype)

    return kernel


def _interp_sweep(theta_t: jax.Array, x: jax.Array, inv_diag: jax.Array,
                  g: jax.Array, h: int, block: int, reverse: bool,
                  interpret: bool) -> jax.Array:
    """One triangular sweep over all λ: (q, hp, nrhs) ← Horner-fused solve.

    ``g`` is either the shared (hp, nrhs) RHS (forward sweep — the same g
    for every λ, no per-λ broadcast in HBM) or the per-λ (q, hp, nrhs)
    intermediate (back sweep consuming the forward solutions).
    """
    from .packed_trsm import _step_tile_indices

    degree = theta_t.shape[0] - 1
    nt = packing.num_tiles(h, block)
    hp = nt * block
    q = x.shape[0]
    rhs_batched = g.ndim == 3
    nrhs = g.shape[-1]
    idx = jnp.asarray(_step_tile_indices(h, block, reverse))

    def inv_index(c, s, u, idx):
        return (c, (nt - 1 - s) if reverse else s, 0, 0)

    if rhs_batched:
        g_spec = pl.BlockSpec((1, hp, nrhs), lambda c, s, u, idx: (c, 0, 0))
    else:
        g_spec = pl.BlockSpec((hp, nrhs), lambda c, s, u, idx: (0, 0))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(q, nt, nt),
        in_specs=[
            pl.BlockSpec(memory_space=SMEM),                        # λ values
            pl.BlockSpec((1, 1, block, block), inv_index),
            g_spec,
            pl.BlockSpec((degree + 1, 1, block, block),
                         lambda c, s, u, idx: (0, idx[s * nt + u], 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, hp, nrhs), lambda c, s, u, idx: (c, 0, 0)),
        scratch_shapes=[pltpu.VMEM((block, nrhs), g.dtype)],
    )
    return pl.pallas_call(
        _make_solve_kernel(degree, block, nt, reverse, rhs_batched),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((q, hp, nrhs), g.dtype),
        interpret=interpret,
    )(idx, x, inv_diag, g, theta_t)


@functools.partial(jax.jit, static_argnames=("h", "block", "interpret",
                                             "rhs_per_lam", "compute_dtype",
                                             "accum_dtype"))
def interp_solve(theta: jax.Array, lams: jax.Array, g: jax.Array, h: int,
                 block: int = 128, *, center: jax.Array | float = 0.0,
                 interpret: bool | None = None, rhs_per_lam: bool = False,
                 compute_dtype=None, accum_dtype=None) -> jax.Array:
    """Solve L(λ) L(λ)ᵀ θ = g at every λ without materializing any L(λ).

    ``theta``: (r+1, P) packed interpolant coefficients; ``lams``: (q,);
    ``g``: (h,) or (h, m) shared RHS — or, with ``rhs_per_lam=True``, a
    per-λ RHS (q, h) / (q, h, m) (the refinement residuals).  Returns
    (q, h) (or (q, h, m)) in the accumulation dtype.  The interpolated
    factor exists only tile-by-tile in registers: the only O(h²) buffer in
    the whole sweep is Θ itself, which is q-independent — and stays at its
    storage dtype.
    """
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    from .packed_trsm import _resolve_dtypes
    cd, ad = _resolve_dtypes(theta.dtype, compute_dtype, accum_dtype)
    degree = theta.shape[0] - 1
    nt = packing.num_tiles(h, block)
    hp = nt * block
    if rhs_per_lam:
        squeeze = g.ndim == 2                      # (q, h) -> (q, h, 1)
        g2 = (g[..., None] if squeeze else g).astype(ad)
        if hp != h:
            g2 = jnp.pad(g2, ((0, 0), (0, hp - h), (0, 0)))
    else:
        squeeze = g.ndim == 1
        g2 = (g[:, None] if squeeze else g).astype(ad)
        if hp != h:
            g2 = jnp.pad(g2, ((0, hp - h), (0, 0)))

    x = (lams.astype(ad) - jnp.asarray(center, ad))
    theta_t = theta.astype(cd).reshape(degree + 1, -1, block, block)

    # Diagonal tiles are the only place substitution needs an inverse, so
    # they alone are interpolated ahead of the sweep: (q, nt, B, B) — O(q·h·B)
    # not O(q·h²) — then pre-inverted (identity-padded tail, shared by both
    # sweeps via transposition).  Horner + inversion run at the accumulation
    # dtype (inverting bf16-rounded triangles in bf16 is the unstable half),
    # the inverses feed the MXU at the compute dtype.
    diag_coeff = theta.reshape(degree + 1, -1, block, block
                               )[:, packing.column_starts(h, block)].astype(ad)
    diag = diag_coeff[degree]
    for k in range(degree - 1, -1, -1):
        diag = diag * x[:, None, None, None] + diag_coeff[k]
    tail = packing._identity_tail(h, block)
    if tail.any():
        diag = diag.at[:, nt - 1].add(jnp.asarray(tail, diag.dtype))
    inv_diag = packing.invert_diag_tiles(diag).astype(cd)

    w = _interp_sweep(theta_t, x, inv_diag, g2, h, block, False, interpret)
    out = _interp_sweep(theta_t, x, inv_diag, w, h, block, True, interpret)
    out = out[:, :h]
    return out[..., 0] if squeeze else out

