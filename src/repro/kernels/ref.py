"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the semantic specification its kernel is tested against
(tests/test_kernels.py sweeps shapes/dtypes and asserts allclose).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import packing, picholesky

__all__ = ["pack_tril", "unpack_tril", "cholesky", "interp_factors",
           "solve_lower", "solve_factor_sweep", "solve_lower_packed",
           "solve_packed", "interp_solve", "ssm_scan"]


def pack_tril(mat: jax.Array, block: int) -> jax.Array:
    return packing.pack_tril(mat, block)


def unpack_tril(vec: jax.Array, h: int, block: int) -> jax.Array:
    return packing.unpack_tril(vec, h, block)


def cholesky(a: jax.Array) -> jax.Array:
    return jnp.linalg.cholesky(a)


def interp_factors(theta: jax.Array, lams: jax.Array, h: int, block: int,
                   center=0.0) -> jax.Array:
    model = picholesky.PiCholesky(
        theta=theta, center=jnp.asarray(center, theta.dtype), h=h, block=block)
    return model.eval_factor(lams)


def solve_lower(l: jax.Array, g: jax.Array, *, transpose: bool = False) -> jax.Array:
    g2 = g[:, None] if g.ndim == 1 else g
    g2 = g2.astype(l.dtype)
    w = jax.lax.linalg.triangular_solve(
        l, g2, left_side=True, lower=True, transpose_a=transpose)
    return w[:, 0] if g.ndim == 1 else w


def solve_factor_sweep(ls: jax.Array, g: jax.Array) -> jax.Array:
    def one(l):
        w = solve_lower(l, g)
        return solve_lower(l, w, transpose=True)

    return jax.vmap(one)(ls)


def solve_lower_packed(vec: jax.Array, g: jax.Array, h: int, block: int, *,
                       transpose: bool = False) -> jax.Array:
    return packing.solve_lower_packed(vec, g, h, block, transpose=transpose)


def solve_packed(vec: jax.Array, g: jax.Array, h: int, block: int) -> jax.Array:
    return packing.solve_packed_ref(vec, g, h, block)


def interp_solve(theta: jax.Array, lams: jax.Array, g: jax.Array, h: int,
                 block: int, center=0.0) -> jax.Array:
    """Packed-domain oracle: Horner-eval the packed rows, then packed solve —
    never materializes a dense factor."""
    model = picholesky.PiCholesky(
        theta=theta, center=jnp.asarray(center, theta.dtype), h=h, block=block)
    vecs = model.eval_packed(lams)
    return jax.vmap(
        lambda v: packing.solve_packed_ref(v, g.astype(theta.dtype), h, block)
    )(vecs)


def ssm_scan(xc, dt, b_mat, c_mat, a, d_skip):
    """Selective-scan oracle (see kernels/ssm_scan.py)."""
    bsz, s, di = xc.shape
    n = a.shape[-1]
    xc, dt = xc.astype(jnp.float32), dt.astype(jnp.float32)
    a_bar = jnp.exp(dt[..., None] * a.astype(jnp.float32))
    bx = (dt * xc)[..., None] * b_mat[:, :, None, :].astype(jnp.float32)

    def step(h, ab):
        h = ab[0] * h + ab[1]
        return h, h

    h_last, hs = jax.lax.scan(step, jnp.zeros((bsz, di, n), jnp.float32),
                              (jnp.moveaxis(a_bar, 1, 0),
                               jnp.moveaxis(bx, 1, 0)))
    hs = jnp.moveaxis(hs, 0, 1)
    y = (jnp.einsum("bsdn,bsn->bsd", hs, c_mat.astype(jnp.float32))
         + d_skip.astype(jnp.float32) * xc)
    return y, h_last
