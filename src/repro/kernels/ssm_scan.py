"""Pallas TPU selective-scan (Mamba-1) kernel — the SSM-family hot spot.

EXPERIMENTS.md §Perf (falcon) shows pure-XLA selective scan is HBM-bound:
the (B,S,d_inner,N) decay/input tensors and the associative-scan levels are
all materialized.  The kernel fuses the whole recurrence:

    read  xc, dt (B,S,di) and B, C (B,S,N) once
    keep  h (di_blk, N) in VMEM across the sequential S grid dimension
    write y (B,S,di) once

True DMA ≈ 4·B·S·di + 2·B·S·N elements — ~N×16 less than the XLA path.
Grid (B, di/di_blk, S/chunk): S innermost (TPU grids iterate sequentially,
so the VMEM carry h is valid across chunks of the same (b, di_blk)).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu  # noqa: F401  (grid specs)

from .compat import vmem_scratch

__all__ = ["ssm_scan"]


def _kernel(xc_ref, dt_ref, b_ref, c_ref, a_ref, d_ref, y_ref, hlast_ref,
            h_scr, *, chunk: int, n_chunks: int):
    sc = pl.program_id(2)

    @pl.when(sc == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    a = a_ref[...]                      # (di_blk, N)
    d_skip = d_ref[...]                 # (di_blk,)

    def step(t, h):
        xt = xc_ref[0, t, :]            # (di_blk,)
        dtt = dt_ref[0, t, :]
        bt = b_ref[0, t, :]             # (N,)
        ct = c_ref[0, t, :]
        a_bar = jnp.exp(dtt[:, None] * a)                    # (di_blk, N)
        bx = (dtt * xt)[:, None] * bt[None, :]
        h = a_bar * h + bx
        y = jnp.sum(h * ct[None, :], axis=1) + d_skip * xt   # (di_blk,)
        y_ref[0, t, :] = y.astype(y_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, chunk, step, h_scr[...])
    h_scr[...] = h

    @pl.when(sc == n_chunks - 1)
    def _final():
        hlast_ref[0] = h


@functools.partial(jax.jit,
                   static_argnames=("chunk", "di_block", "interpret"))
def ssm_scan(xc: jax.Array, dt: jax.Array, b_mat: jax.Array, c_mat: jax.Array,
             a: jax.Array, d_skip: jax.Array, *, chunk: int = 128,
             di_block: int = 256, interpret: bool | None = None):
    """Fused selective scan.

    xc, dt: (B, S, di);  b_mat, c_mat: (B, S, N);  a: (di, N) [negative];
    d_skip: (di,).  Returns (y (B,S,di) f32, h_last (B,di,N) f32).
    """
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    bsz, s, di = xc.shape
    n = a.shape[-1]
    chunk = min(chunk, s)
    di_block = min(di_block, di)
    assert s % chunk == 0 and di % di_block == 0, (s, chunk, di, di_block)
    n_chunks = s // chunk
    n_dblk = di // di_block

    f32 = jnp.float32
    grid = (bsz, n_dblk, n_chunks)
    kernel = functools.partial(_kernel, chunk=chunk, n_chunks=n_chunks)
    y, h_last = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, di_block), lambda b, d, sc: (b, sc, d)),
            pl.BlockSpec((1, chunk, di_block), lambda b, d, sc: (b, sc, d)),
            pl.BlockSpec((1, chunk, n), lambda b, d, sc: (b, sc, 0)),
            pl.BlockSpec((1, chunk, n), lambda b, d, sc: (b, sc, 0)),
            pl.BlockSpec((di_block, n), lambda b, d, sc: (d, 0)),
            pl.BlockSpec((di_block,), lambda b, d, sc: (d,)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, di_block), lambda b, d, sc: (b, sc, d)),
            pl.BlockSpec((1, di_block, n), lambda b, d, sc: (b, d, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, s, di), f32),
            jax.ShapeDtypeStruct((bsz, di, n), f32),
        ],
        scratch_shapes=[vmem_scratch((di_block, n), f32)],
        interpret=interpret,
    )(xc.astype(f32), dt.astype(f32), b_mat.astype(f32), c_mat.astype(f32),
      a.astype(f32), d_skip.astype(f32))
    return y, h_last
