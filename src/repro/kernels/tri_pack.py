"""Pallas TPU kernels for tile-major triangular packing (paper §5, TPU form).

The pack/unpack are pure data-movement kernels: every grid step copies one
aligned ``B×B`` VMEM tile; the (i,j) ↔ packed-index maps are scalar-prefetched
so the index computation costs nothing on the compute units.  This is the
TPU analogue of the paper's recursive vectorization — alignment unit is the
128-lane tile instead of a cache line, and only the ``nt(nt+1)/2`` lower
tiles move (requirement (ii): no redundant interpolation work downstream).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import packing

__all__ = ["pack_tril", "unpack_tril"]


def _pack_kernel(idx_ref, mat_ref, out_ref):
    p = pl.program_id(0)
    i = idx_ref[0, p]
    j = idx_ref[1, p]
    tile = mat_ref[...]
    b = tile.shape[0]
    rows = jax.lax.broadcasted_iota(jnp.int32, (b, b), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (b, b), 1)
    # Diagonal tiles keep only their lower triangle (alignment padding = 0).
    masked = jnp.where(rows >= cols, tile, jnp.zeros_like(tile))
    out_ref[0] = jnp.where(i == j, masked, tile)


def _unpack_kernel(pidx_ref, packed_ref, out_ref):
    i = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(i >= j)
    def _lower():
        out_ref[...] = packed_ref[0]

    @pl.when(i < j)
    def _upper():
        out_ref[...] = jnp.zeros_like(out_ref)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def pack_tril(mat: jax.Array, block: int = 128, *, interpret: bool | None = None) -> jax.Array:
    """Pack tril(mat) (h×h) into the tile-major packed vector (P,)."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    h = mat.shape[-1]
    nt = packing.num_tiles(h, block)
    pad = nt * block - h
    if pad:
        mat = jnp.pad(mat, ((0, pad), (0, pad)))
    ii, jj = packing.tile_index_pairs(h, block)
    idx = jnp.asarray(np.stack([ii, jj]), jnp.int32)  # (2, P)
    n_blocks = len(ii)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((block, block), lambda p, idx: (idx[0, p], idx[1, p])),
        ],
        out_specs=pl.BlockSpec((1, block, block), lambda p, idx: (p, 0, 0)),
    )
    out = pl.pallas_call(
        _pack_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_blocks, block, block), mat.dtype),
        interpret=interpret,
    )(idx, mat)
    return out.reshape(-1)


@functools.partial(jax.jit, static_argnames=("h", "block", "interpret"))
def unpack_tril(vec: jax.Array, h: int, block: int = 128, *, interpret: bool | None = None) -> jax.Array:
    """Inverse of :func:`pack_tril`: (P,) -> (h, h) lower-triangular."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    nt = packing.num_tiles(h, block)
    ii, jj = packing.tile_index_pairs(h, block)
    # map (i, j) -> packed block index (0 for unused upper blocks)
    pmap = np.zeros((nt, nt), np.int32)
    for p, (i, j) in enumerate(zip(ii, jj)):
        pmap[i, j] = p
    pidx = jnp.asarray(pmap.reshape(-1), jnp.int32)
    packed = vec.reshape(-1, block, block)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nt, nt),
        in_specs=[
            pl.BlockSpec((1, block, block), lambda i, j, pidx: (pidx[i * nt + j], 0, 0)),
        ],
        out_specs=pl.BlockSpec((block, block), lambda i, j, pidx: (i, j)),
    )
    out = pl.pallas_call(
        _unpack_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((nt * block, nt * block), vec.dtype),
        interpret=interpret,
    )(pidx, packed)
    return out[:h, :h]
