"""Blocked triangular solves (the per-λ back-end of §3.2) as Pallas kernels.

Solving ``L w = g`` / ``Lᵀ θ = w`` for the whole λ sweep at once makes the
right-hand side a (h × q) block — so the substitution becomes a chain of
``B×B @ B×q`` MXU GEMMs instead of q separate vector solves.  Diagonal tiles
are pre-inverted once (q-independent) so the kernel contains no sequential
scalar solve at all.

Kernel layout: sequential grid over tile-rows; the full RHS block lives in
VMEM as the output ref (revisited every step), each step reads one (B × h)
row-panel of L, masks the not-yet-solved columns, and updates its B rows.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["solve_lower_blocked", "solve_factor_sweep"]


def _make_solve_kernel(block: int, nt: int, reverse: bool,
                       compute_dtype=None):
    def kernel(panel_ref, inv_ref, g_ref, w_ref):
        step = pl.program_id(0)

        @pl.when(step == 0)
        def _init():  # unsolved rows must be 0.0, not uninitialized VMEM
            w_ref[...] = jnp.zeros_like(w_ref)

        i = (nt - 1 - step) if reverse else step
        h = nt * block
        col = jax.lax.broadcasted_iota(jnp.int32, (block, h), 1)
        if reverse:
            mask = col >= (i + 1) * block   # columns already solved (above)
        else:
            mask = col < i * block          # columns already solved (below)
        panel = jnp.where(mask, panel_ref[...], 0.0)
        w = w_ref[...]
        if compute_dtype is not None:       # MXU at reduced precision,
            panel = panel.astype(compute_dtype)   # full-precision accum
            w = w.astype(compute_dtype)
        s = jnp.dot(panel, w, preferred_element_type=w_ref.dtype)
        g_i = g_ref[pl.ds(i * block, block), :]
        rhs = g_i - s
        inv = inv_ref[0]
        if compute_dtype is not None:
            rhs = rhs.astype(compute_dtype)
            inv = inv.astype(compute_dtype)
        w_i = jnp.dot(inv, rhs, preferred_element_type=w_ref.dtype)
        w_ref[pl.ds(i * block, block), :] = w_i

    return kernel


@functools.partial(jax.jit, static_argnames=("transpose", "interpret", "block",
                                             "compute_dtype", "accum_dtype"))
def solve_lower_blocked(l: jax.Array, g: jax.Array, block: int = 256, *,
                        transpose: bool = False,
                        interpret: bool | None = None,
                        compute_dtype=None, accum_dtype=None) -> jax.Array:
    """Solve L w = g (or Lᵀ w = g) for lower-triangular L.  g: (h,) or (h, q).

    ``compute_dtype``/``accum_dtype``: MXU operand vs accumulation dtype —
    the factor state, diagonal inversion, and solution live at the
    accumulation dtype (defaults inherit ``l.dtype``).
    """
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    from .packed_trsm import _resolve_dtypes
    cd, ad = _resolve_dtypes(l.dtype, compute_dtype, accum_dtype)
    cd_gemm = None if cd == ad else cd
    l = l.astype(ad)
    h = l.shape[-1]
    nt = -(-h // block)
    hp = nt * block
    squeeze = g.ndim == 1
    g2 = (g[:, None] if squeeze else g).astype(ad)
    q = g2.shape[1]
    if hp != h:
        l = jnp.pad(l, ((0, hp - h), (0, hp - h)))
        l = l.at[h:, h:].set(jnp.eye(hp - h, dtype=l.dtype))
        g2 = jnp.pad(g2, ((0, hp - h), (0, 0)))

    mat = l.T if transpose else l
    # row-panels of the (possibly transposed) operator, and inverted diag tiles
    diag = jnp.stack([jax.lax.dynamic_slice(mat, (k * block, k * block),
                                            (block, block)) for k in range(nt)])
    eye = jnp.eye(block, dtype=l.dtype)
    inv_diag = jax.lax.linalg.triangular_solve(
        diag, jnp.broadcast_to(eye, diag.shape), left_side=True,
        lower=not transpose, transpose_a=False)

    kernel = _make_solve_kernel(block, nt, reverse=transpose,
                                compute_dtype=cd_gemm)

    def row_index(step, *_):
        return ((nt - 1 - step) if transpose else step, 0)

    w = pl.pallas_call(
        kernel,
        grid=(nt,),
        in_specs=[
            pl.BlockSpec((block, hp), row_index),
            pl.BlockSpec((1, block, block),
                         lambda step: ((nt - 1 - step) if transpose else step, 0, 0)),
            pl.BlockSpec((hp, q), lambda step: (0, 0)),
        ],
        out_specs=pl.BlockSpec((hp, q), lambda step: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((hp, q), g2.dtype),
        interpret=interpret,
    )(mat, inv_diag, g2)
    w = w[:h]
    return w[:, 0] if squeeze else w


def solve_factor_sweep(ls: jax.Array, g: jax.Array, block: int = 256, *,
                       interpret: bool | None = None) -> jax.Array:
    """Solve L_t L_tᵀ θ_t = g for a sweep of factors (q, h, h) -> (q, h)."""
    def one(l):
        w = solve_lower_blocked(l, g, block, transpose=False, interpret=interpret)
        return solve_lower_blocked(l, w, block, transpose=True, interpret=interpret)

    return jax.vmap(one)(ls)
