import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

# Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.
#
# The two lines above MUST precede any jax import — jax locks the device
# count at first init.  (They also force this file to skip `from __future__`.)
# Usage:
#   PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-1.5b --shape train_4k
#   PYTHONPATH=src python -m repro.launch.dryrun --all [--both-meshes] [--out results/dryrun]
#
# Per cell this prints/records memory_analysis() (fits / doesn't),
# cost_analysis() FLOPs+bytes, and the parsed per-device collective wire
# bytes — the raw inputs for EXPERIMENTS.md §Dry-run and §Roofline.

import argparse
import json
import math
import sys
import time
import traceback
from typing import Optional

import jax
import jax.numpy as jnp

from repro import configs
from repro.distributed import roofline as rl
from repro.distributed.context import MeshCtx
from repro.launch import specs as specmod
from repro.launch.mesh import make_production_mesh
from repro.models.model import Model
from repro.optim import adafactor, adamw
from repro.train.steps import make_train_step

FSDP_THRESHOLD = 2e9  # params; above this weights shard over data too


def build_cell(arch: str, shape: str, multi_pod: bool):
    cfg = configs.get(arch)
    meta = configs.SHAPES[shape]
    mesh = make_production_mesh(multi_pod=multi_pod)
    fsdp = cfg.n_params() > FSDP_THRESHOLD
    ctx = MeshCtx.from_mesh(mesh, fsdp=fsdp)
    model = Model(cfg, ctx)
    return cfg, meta, mesh, ctx, model


def lower_cell(arch: str, shape: str, *, multi_pod: bool = False,
               microbatches: Optional[int] = None):
    """Returns (lowered, chips, note). Raises on sharding/lowering bugs."""
    cfg, meta, mesh, ctx, model = build_cell(arch, shape, multi_pod)
    chips = math.prod(mesh.devices.shape)
    seq, batch = meta["seq_len"], meta["global_batch"]
    kind = meta["kind"]

    params_abs = specmod.param_specs_sharded(model)
    p_shardings = jax.tree.map(lambda s: s.sharding, params_abs)

    if kind == "train":
        # the 1T MoE uses adafactor + grad accumulation (see DESIGN.md §6)
        big = cfg.n_params() > 3e11
        opt = adafactor() if big else adamw()
        mb = microbatches or (2 if big else 1)
        opt_abs = specmod.opt_state_specs(opt[0], model)
        o_shardings = jax.tree.map(lambda s: s.sharding, opt_abs)
        batch_abs = specmod.batch_specs(cfg, ctx, batch, seq, with_labels=True)
        extra_abs = specmod.extra_specs(cfg, ctx, batch, seq)
        step = make_train_step(model, opt, microbatches=mb)
        fn = jax.jit(step, donate_argnums=(0, 1),
                     out_shardings=(p_shardings, o_shardings, None))
        args = (params_abs, opt_abs, batch_abs, extra_abs)
        note = f"train mb={mb} opt={'adafactor' if big else 'adamw'} fsdp={ctx.fsdp}"
    elif kind == "prefill":
        batch_abs = specmod.batch_specs(cfg, ctx, batch, seq, with_labels=False)
        extra_abs = specmod.extra_specs(cfg, ctx, batch, seq)

        def prefill(params, tokens, extra):
            return model.prefill(params, tokens, extra)

        fn = jax.jit(prefill)
        args = (params_abs, batch_abs["tokens"], extra_abs)
        note = f"prefill fsdp={ctx.fsdp}"
    else:  # decode
        extra_len = 0
        if cfg.family == "audio":
            extra_len = seq // cfg.enc_seq_ratio
        elif cfg.family == "vlm":
            extra_len = cfg.n_image_tokens
        cache_abs = specmod.cache_specs(model, batch, seq, extra_len)
        tok = jax.ShapeDtypeStruct(
            (batch, 1), jnp.int32,
            sharding=ctx.sharding(ctx.dp_axes if batch % ctx.dp_size == 0
                                  else None, None))

        def decode(params, cache, tokens):
            return model.decode(params, cache, tokens)

        fn = jax.jit(decode)
        args = (params_abs, cache_abs, tok)
        note = f"decode cache={seq} fsdp={ctx.fsdp}"

    with mesh:
        lowered = fn.lower(*args)
    return lowered, chips, note


def run_cell(arch: str, shape: str, *, multi_pod: bool = False,
             verbose: bool = True) -> dict:
    cell = f"{arch}×{shape}×{'2x16x16' if multi_pod else '16x16'}"
    cfgmeta = configs.SHAPES[shape]
    cfg = configs.get(arch)
    if shape == "long_500k" and not cfg.subquadratic:
        return {"cell": cell, "status": "skip",
                "reason": "pure full-attention arch (DESIGN.md §5)"}
    t0 = time.time()
    try:
        lowered, chips, note = lower_cell(arch, shape, multi_pod=multi_pod)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        mem_d = {}
        for k in ("generated_code_size_in_bytes", "argument_size_in_bytes",
                  "output_size_in_bytes", "temp_size_in_bytes",
                  "alias_size_in_bytes"):
            mem_d[k] = getattr(mem, k, None)
        roof = rl.roofline(compiled, chips)
        n = cfg.n_params()
        n_act = cfg.n_active_params()
        tokens = cfgmeta["global_batch"] * (cfgmeta["seq_len"]
                                            if cfgmeta["kind"] != "decode" else 1)
        mult = 6 if cfgmeta["kind"] == "train" else 2
        model_flops = mult * n_act * tokens
        total_hlo_flops = roof.flops * chips
        result = {
            "cell": cell, "status": "ok", "note": note, "chips": chips,
            "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
            "memory": mem_d,
            "roofline": roof.summary(),
            "n_params": n, "n_active_params": n_act,
            "model_flops": model_flops,
            "useful_flops_frac": (model_flops / total_hlo_flops
                                  if total_hlo_flops else None),
        }
    except Exception as e:  # noqa: BLE001 — record the failure, keep sweeping
        result = {"cell": cell, "status": "error",
                  "error": f"{type(e).__name__}: {e}",
                  "trace": traceback.format_exc()[-2000:]}
    if verbose:
        st = result["status"]
        if st == "ok":
            r = result["roofline"]
            print(f"[{st}] {cell}  {result['note']}  "
                  f"compile={result['compile_s']}s  "
                  f"bottleneck={r['bottleneck']}  "
                  f"compute={r['compute_s']:.3e}s mem={r['memory_s']:.3e}s "
                  f"coll={r['collective_s']:.3e}s", flush=True)
        else:
            print(f"[{st}] {cell}  "
                  f"{result.get('reason', result.get('error'))}", flush=True)
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    cells = []
    if args.all:
        for name, shape, meta, skip in configs.cells():
            cells.append((name, shape))
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required unless --all")
        cells = [(args.arch, args.shape)]

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    results = []
    for arch, shape in cells:
        for mp in meshes:
            res = run_cell(arch, shape, multi_pod=mp)
            results.append(res)
            if args.out:
                os.makedirs(args.out, exist_ok=True)
                tag = f"{arch}__{shape}__{'mp' if mp else 'sp'}.json"
                with open(os.path.join(args.out, tag), "w") as f:
                    json.dump(res, f, indent=1)
    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skip" for r in results)
    n_err = len(results) - n_ok - n_skip
    print(f"\n== dry-run: {n_ok} ok, {n_skip} skip, {n_err} error ==")
    if n_err:
        sys.exit(1)


if __name__ == "__main__":
    main()
