"""Production mesh construction.

A function (not module-level constant) so importing never touches jax
device state.  Single pod: (16, 16) = 256 chips, axes (data, model).
Multi-pod: (2, 16, 16) = 512 chips, axes (pod, data, model) — the "pod"
axis carries only data parallelism (gradient all-reduce crosses the
inter-pod DCN/optical links; everything bandwidth-hungry stays on-pod).
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_debug_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many real devices exist (tests)."""
    return jax.make_mesh((data, model), ("data", "model"))
