"""ShapeDtypeStruct stand-ins + sharding trees for every dry-run input.

No device allocation happens here: params, optimizer state and caches are
built with ``jax.eval_shape`` / abstract trees, each leaf annotated with its
NamedSharding so ``jit(...).lower()`` sees the production layout.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.distributed import sharding as shlib
from repro.distributed.context import MeshCtx
from repro.models.config import ModelConfig
from repro.models.model import Model

__all__ = ["batch_specs", "extra_specs", "cache_specs", "opt_state_specs",
           "param_specs_sharded", "attach"]


def attach(tree: Any, pspecs: Any, ctx: MeshCtx) -> Any:
    """ShapeDtypeStruct tree + pspec tree -> sharded ShapeDtypeStruct tree."""
    def one(sds, ps):
        return jax.ShapeDtypeStruct(sds.shape, sds.dtype,
                                    sharding=NamedSharding(ctx.mesh, ps))

    return jax.tree.map(one, tree, pspecs)


def param_specs_sharded(model: Model) -> Any:
    ctx = model.ctx
    abstract = model.abstract()
    pspecs = shlib.param_pspecs(model.param_specs(), ctx)
    return attach(abstract, pspecs, ctx)


def batch_specs(cfg: ModelConfig, ctx: MeshCtx, batch: int, seq: int,
                *, with_labels: bool) -> Dict:
    dp = ctx.dp_axes
    bspec = P(dp, None) if batch % ctx.dp_size == 0 else P(None, None)
    tok = jax.ShapeDtypeStruct((batch, seq), jnp.int32,
                               sharding=ctx.sharding(*bspec))
    out = {"tokens": tok}
    if with_labels:
        out["labels"] = tok
    return out


def extra_specs(cfg: ModelConfig, ctx: MeshCtx, batch: int, seq: int) -> Optional[Dict]:
    dp = ctx.dp_axes
    brow = dp if batch % ctx.dp_size == 0 else None
    if cfg.family == "audio":
        shape = (batch, seq // cfg.enc_seq_ratio, cfg.d_model)
        return {"enc_frames": jax.ShapeDtypeStruct(
            shape, cfg.activation_dtype,
            sharding=ctx.sharding(brow, None, None))}
    if cfg.family == "vlm":
        shape = (batch, cfg.n_image_tokens, cfg.d_model)
        return {"image_embeds": jax.ShapeDtypeStruct(
            shape, cfg.activation_dtype,
            sharding=ctx.sharding(brow, None, None))}
    return None


def _cache_leaf_pspec(path: str, shape: Tuple[int, ...], cfg: ModelConfig,
                      ctx: MeshCtx, batch: int) -> P:
    """Sharding for one cache leaf, by leaf name + rank.

    Batch dim shards over dp when divisible; otherwise (long_500k, B=1) the
    cache *sequence* dim takes the dp axes — flash-decode style sequence
    parallelism.  Head_dim / d_inner follow the weight TP layout.
    """
    dp = ctx.dp_axes
    tp = ctx.tp_size
    b_ok = batch % ctx.dp_size == 0
    leaf = path.split("/")[-1]
    if leaf == "pos":
        return P()
    none = (None,) * len(shape)
    if leaf in ("k", "v"):                    # (G?, B, S, KV, hd)
        off = len(shape) - 4
        lead = (None,) * off
        kvh, kvd = None, None
        if cfg.n_kv_heads % tp == 0 and cfg.n_heads % tp == 0:
            kvh = "model"
        elif cfg.n_heads % tp != 0 and cfg.head_dim_ % tp == 0:
            kvd = "model"
        if b_ok:
            return P(*lead, dp, None, kvh, kvd)
        seq = shape[off + 1]
        sp = dp if seq % ctx.dp_size == 0 else None
        return P(*lead, None, sp, kvh, kvd)
    if leaf == "conv":                        # (G?, B, K-1, C)
        off = len(shape) - 3
        lead = (None,) * off
        c = shape[-1]
        cax = "model" if c % tp == 0 else None
        return P(*lead, dp if b_ok else None, None, cax)
    if leaf == "h":                           # mamba (G?,B,di,N) / rglru (G?,B,W)
        if shape[-1] == cfg.ssm_state and cfg.family == "ssm":
            off = len(shape) - 3
            di = shape[-2]
            return P(*((None,) * off), dp if b_ok else None,
                     "model" if di % tp == 0 else None, None)
        off = len(shape) - 2
        w = shape[-1]
        return P(*((None,) * off), dp if b_ok else None,
                 "model" if w % tp == 0 else None)
    return none and P(*none)


def cache_specs(model: Model, batch: int, cache_len: int,
                extra_len: int = 0) -> Any:
    cfg, ctx = model.cfg, model.ctx
    abstract = jax.eval_shape(
        lambda: model.init_cache(batch, cache_len, extra_len))

    def one(path, leaf):
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        ps = _cache_leaf_pspec(name, leaf.shape, cfg, ctx, batch)
        return jax.ShapeDtypeStruct(leaf.shape, leaf.dtype,
                                    sharding=ctx.sharding(*ps))

    return jax.tree_util.tree_map_with_path(one, abstract)


def opt_state_specs(opt_init, model: Model) -> Any:
    """Abstract optimizer state with shardings derived from the params.

    Elementwise moments inherit the param pspec; factored (adafactor)
    moments inherit the pspec minus the reduced dim.
    """
    ctx = model.ctx
    params_abs = model.abstract()
    pspecs = shlib.param_pspecs(model.param_specs(), ctx)
    state_abs = jax.eval_shape(opt_init, params_abs)

    flat_p, _ = jax.tree.flatten(params_abs)
    flat_ps, _ = jax.tree.flatten(pspecs)
    by_shape = {}
    for p, ps in zip(flat_p, flat_ps):
        by_shape.setdefault(p.shape, ps)

    def one(leaf):
        ps = by_shape.get(leaf.shape)
        if ps is None:
            # factored moment: match a param whose prefix/suffix agrees
            for shape, cand in by_shape.items():
                if len(shape) == len(leaf.shape) + 1:
                    if shape[:-1] == leaf.shape:       # row factor
                        ps = P(*cand[:-1]) if cand else None
                        break
                    if shape[:-2] + shape[-1:] == leaf.shape:  # col factor
                        ps = P(*(cand[:-2] + cand[-1:])) if cand else None
                        break
        if ps is None:
            ps = P()
        return jax.ShapeDtypeStruct(leaf.shape, leaf.dtype,
                                    sharding=ctx.sharding(*ps))

    return jax.tree.map(one, state_abs)
