"""Production training launcher: mesh + sharded params/opt + fault-tolerant
loop.  On this CPU container it runs with a 1×1 debug mesh by default; on a
real pod slice pass --mesh 16x16 / 2x16x16 (the dry-run proves those lower).

    PYTHONPATH=src python -m repro.launch.train --arch smollm-360m \
        --steps 100 --ckpt-dir /tmp/ckpt [--mesh 1x1] [--reduced]
"""
from __future__ import annotations

import argparse
import itertools

import jax

from repro import configs
from repro.data import token_stream
from repro.distributed import sharding as shlib
from repro.distributed.context import MeshCtx
from repro.launch.mesh import make_debug_mesh, make_production_mesh
from repro.models.model import Model
from repro.optim import adafactor, adamw
from repro.train import TrainLoop, TrainLoopConfig, make_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m", choices=configs.names())
    ap.add_argument("--mesh", default="1x1",
                    help="1x1 | DxM (e.g. 16x16) | 2x16x16 (multi-pod)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--reduced", action="store_true",
                    help="use the CPU-sized reduced config")
    args = ap.parse_args()

    dims = [int(x) for x in args.mesh.split("x")]
    if dims == [1, 1]:
        mesh = make_debug_mesh()
    elif len(dims) == 2:
        mesh = make_production_mesh(multi_pod=False)
    else:
        mesh = make_production_mesh(multi_pod=True)

    cfg = configs.get(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    fsdp = cfg.n_params() > 2e9
    ctx = MeshCtx.from_mesh(mesh, fsdp=fsdp)
    model = Model(cfg, ctx)

    big = cfg.n_params() > 3e11
    opt = adafactor() if big else adamw()
    with mesh:
        shardings = shlib.param_shardings(model.param_specs(), ctx)
        params = jax.jit(model.init, out_shardings=shardings)(
            jax.random.PRNGKey(0))
        opt_state = jax.jit(opt[0])(params)
        step = jax.jit(make_train_step(model, opt,
                                       microbatches=args.microbatches),
                       donate_argnums=(0, 1))

        loop = TrainLoop(
            TrainLoopConfig(total_steps=args.steps, ckpt_every=args.ckpt_every,
                            ckpt_dir=args.ckpt_dir, log_every=10),
            step, params, opt_state)
        data = token_stream(jax.random.PRNGKey(1), cfg.vocab_size,
                            args.batch, args.seq)
        out = loop.run(itertools.islice(data, args.steps + 4))

    for e in out["log"]:
        print(f"step {e['step']:6d}  loss {e['loss']:.4f}  "
              f"{e['sec_per_step']:.3f}s/step")
    print(f"final step {out['final_step']}  stragglers {out['straggler_steps']}")


if __name__ == "__main__":
    main()
