"""Per-family blocks: param specs + forward + single-token decode.

Spec axes are literal mesh axes: "model" (TP/EP), "fsdp" (resolved to the
innermost data axis when the config enables FSDP), or None.  Builders are
divisibility-aware: e.g. attention picks heads-TP when n_heads % tp == 0
(Megatron GQA with replicated KV when kv doesn't divide), else head_dim-TP,
else replicated.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.context import MeshCtx

from . import layers
from .config import ModelConfig
from .params import Spec

# ---------------------------------------------------------------- helpers


def _padded_heads(cfg: ModelConfig, ctx: MeshCtx) -> int:
    tp = ctx.tp_size
    h = cfg.n_heads
    if cfg.pad_heads and tp > 1 and h % tp != 0:
        return -(-h // tp) * tp
    return h


def _attn_layout(cfg: ModelConfig, ctx: MeshCtx):
    tp = ctx.tp_size
    hp, kv, hd = _padded_heads(cfg, ctx), cfg.n_kv_heads, cfg.head_dim_
    if hp % tp == 0 and kv % tp == 0:
        return "model", "model", None, None
    if hp % tp == 0:
        return "model", None, None, None          # KV replicated (GQA-TP)
    if hd % tp == 0:
        return None, None, "model", "model"       # head_dim TP
    return None, None, None, None


def _kv_index(cfg: ModelConfig, ctx: MeshCtx):
    """Padded-q-head -> kv-head mapping (GQA groups preserved for the real
    heads; padded heads borrow group 0 — their wo rows learn from scratch)."""
    import numpy as np
    h, kv = cfg.n_heads, cfg.n_kv_heads
    hp = _padded_heads(cfg, ctx)
    group = max(h // kv, 1)
    return np.asarray([min(j, h - 1) // group for j in range(hp)], np.int32)


def _mlp_axis(d_ff: int, ctx: MeshCtx) -> Optional[str]:
    return "model" if d_ff % ctx.tp_size == 0 else None


# ---------------------------------------------------------------- attention


def attention_spec(cfg: ModelConfig, ctx: MeshCtx, *, cross: bool = False) -> Dict:
    d, kv, hd = cfg.d_model, cfg.n_kv_heads, cfg.head_dim_
    hp = _padded_heads(cfg, ctx)
    qh, kvh, qd, kvd = _attn_layout(cfg, ctx)
    spec = {
        "wq": Spec((d, hp, hd), ("fsdp", qh, qd)),
        "wk": Spec((d, kv, hd), ("fsdp", kvh, kvd)),
        "wv": Spec((d, kv, hd), ("fsdp", kvh, kvd)),
        "wo": Spec((hp, hd, d), (qh, qd, "fsdp")),
    }
    if cfg.qkv_bias and not cross:
        spec["bq"] = Spec((hp, hd), (qh, qd), init="zeros")
        spec["bk"] = Spec((kv, hd), (kvh, kvd), init="zeros")
        spec["bv"] = Spec((kv, hd), (kvh, kvd), init="zeros")
    if cross:
        spec["gate"] = Spec((), (), init="zeros")   # gated cross-attn (VLM)
    return spec


def _qkv(p: Dict, x: jax.Array, kv_src: jax.Array, cfg: ModelConfig):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", kv_src, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", kv_src, p["wv"].astype(x.dtype))
    if "bq" in p:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    return q, k, v


def attention_apply(
    p: Dict, x: jax.Array, cfg: ModelConfig, ctx: MeshCtx, *,
    causal: bool = True,
    window: Optional[int] = None,
    kv_src: Optional[jax.Array] = None,     # cross-attention source
    use_rope: bool = True,
    positions: Optional[jax.Array] = None,
) -> jax.Array:
    cross = kv_src is not None
    src = kv_src if cross else x
    q, k, v = _qkv(p, x, src, cfg)
    if use_rope and not cross:
        pos = positions if positions is not None else jnp.arange(x.shape[1])
        q = layers.rope(q, pos, cfg.rope_theta)
        k = layers.rope(k, pos, cfg.rope_theta)
    idx = jnp.asarray(_kv_index(cfg, ctx))
    ke, ve = jnp.take(k, idx, axis=2), jnp.take(v, idx, axis=2)
    out = layers.flash_attention(
        q, ke, ve, causal=causal and not cross, window=window,
        chunk=cfg.attn_chunk)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    if cross:
        y = jnp.tanh(p["gate"].astype(jnp.float32)).astype(x.dtype) * y
    return y


def attention_prefill(p, x, cfg, ctx, *, window=None, cache_len=None):
    """Forward + return the KV cache (window-clipped, with decode headroom)."""
    s = x.shape[1]
    pos = jnp.arange(s)
    q, k, v = _qkv(p, x, x, cfg)
    q = layers.rope(q, pos, cfg.rope_theta)
    k = layers.rope(k, pos, cfg.rope_theta)
    idx = jnp.asarray(_kv_index(cfg, ctx))
    out = layers.flash_attention(q, jnp.take(k, idx, axis=2),
                                 jnp.take(v, idx, axis=2),
                                 causal=True, window=window,
                                 chunk=cfg.attn_chunk)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    if window:
        # ring buffer of exactly `window` slots: token t lives at t % window
        keep = min(window, s)
        slots = jnp.arange(s - keep, s) % window
        shape = (k.shape[0], window) + k.shape[2:]
        ck = jnp.zeros(shape, k.dtype).at[:, slots].set(k[:, -keep:])
        cv = jnp.zeros(shape, v.dtype).at[:, slots].set(v[:, -keep:])
    else:
        cache_len = cache_len or s + 128
        pad = ((0, 0), (0, cache_len - s), (0, 0), (0, 0))
        ck, cv = jnp.pad(k, pad), jnp.pad(v, pad)
    return y, {"k": ck, "v": cv}


def attention_decode(p, x, cache: Dict, pos: jax.Array, cfg: ModelConfig,
                     ctx: MeshCtx, *, window: Optional[int] = None,
                     cross: bool = False):
    """x: (B, 1, D).  cache: {"k","v"} (B, S, KV, hd).  pos: tokens so far."""
    if cross:
        q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
        if "bq" in p:
            q = q + p["bq"].astype(x.dtype)
        idx = jnp.asarray(_kv_index(cfg, ctx))
        out = layers.decode_attention(q, jnp.take(cache["k"], idx, axis=2),
                                      jnp.take(cache["v"], idx, axis=2),
                                      cache["k"].shape[1])
        y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
        y = jnp.tanh(p["gate"].astype(jnp.float32)).astype(x.dtype) * y
        return y, cache
    q, k, v = _qkv(p, x, x, cfg)
    pos_b = jnp.broadcast_to(pos, (x.shape[0], 1))
    q = layers.rope(q, pos_b, cfg.rope_theta)
    k = layers.rope(k, pos_b, cfg.rope_theta)
    s = cache["k"].shape[1]
    slot = (pos % s if window else jnp.minimum(pos, s - 1)).astype(jnp.int32)
    zero = jnp.zeros((), jnp.int32)
    # write the new KV at the ring-buffer slot
    ck = jax.lax.dynamic_update_slice(cache["k"], k, (zero, slot, zero, zero))
    cv = jax.lax.dynamic_update_slice(cache["v"], v, (zero, slot, zero, zero))
    idx = jnp.asarray(_kv_index(cfg, ctx))
    out = layers.decode_attention(q, jnp.take(ck, idx, axis=2),
                                  jnp.take(cv, idx, axis=2),
                                  jnp.minimum(pos + 1, s),
                                  window=None)  # ring buffer already clips
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    return y, {"k": ck, "v": cv}


# ---------------------------------------------------------------- dense MLP


def mlp_spec(cfg: ModelConfig, ctx: MeshCtx, d_ff: Optional[int] = None) -> Dict:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    ax = _mlp_axis(f, ctx)
    spec = {"wi": Spec((d, f), ("fsdp", ax)), "wo": Spec((f, d), (ax, "fsdp"))}
    if cfg.act == "silu":
        spec["wg"] = Spec((d, f), ("fsdp", ax))
    return spec


def mlp_apply(p: Dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    pc = {k: v.astype(x.dtype) for k, v in p.items()}
    return layers.mlp(pc, x, cfg.act)


# ---------------------------------------------------------------- MoE


def moe_spec(cfg: ModelConfig, ctx: MeshCtx) -> Dict:
    d, e, f = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    ep = e % ctx.tp_size == 0
    if ep:
        ax = ("model", "fsdp", None)
    else:
        ax = (None, "fsdp", "model")
    spec = {
        "router": Spec((d, e), (None, None), scale=0.02 / math.sqrt(d)),
        "wi": Spec((e, d, f), ax),
        "wg": Spec((e, d, f), ax),
        "wo": Spec((e, f, d), (ax[0], ax[2], ax[1])),
    }
    if cfg.n_shared_experts:
        fs = cfg.moe_d_ff * cfg.n_shared_experts
        spec["shared"] = mlp_spec(cfg, ctx, d_ff=fs)
    return spec


def _moe_local(x: jax.Array, p: Dict, cfg: ModelConfig, n_local: int,
               exp_offset: jax.Array, capacity: int) -> Tuple[jax.Array, jax.Array]:
    """Token dispatch + expert compute on one shard.

    x: (T, D) local tokens; weights already local (n_local experts).
    Returns (out (T, D) — partial, caller psums over the expert/TP axis —
    and the load-balance aux loss).
    """
    t, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    logits = (x.astype(jnp.float32) @ p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                       # (T, E)
    topv, topi = jax.lax.top_k(probs, k)                          # (T, k)
    topv = topv / jnp.sum(topv, axis=-1, keepdims=True)

    # load-balance aux (Switch-style): E * Σ_e frac_tokens_e * frac_prob_e
    me = jnp.mean(probs, axis=0)
    ce = jnp.zeros((e,), jnp.float32).at[topi.reshape(-1)].add(1.0) / (t * k)
    aux = e * jnp.sum(me * ce)

    flat_e = topi.reshape(-1)                                     # (T*k,)
    order = jnp.argsort(flat_e)
    counts = jnp.zeros((e,), jnp.int32).at[flat_e].add(1)
    starts = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                              jnp.cumsum(counts)[:-1]])

    def slots_for(e_loc):
        eg = e_loc + exp_offset
        idx = jnp.take(order, starts[eg] + jnp.arange(capacity, dtype=jnp.int32),
                       mode="fill", fill_value=t * k)
        valid = jnp.arange(capacity) < counts[eg]
        return jnp.where(valid, idx, t * k), valid

    idxs, valids = jax.vmap(slots_for)(jnp.arange(n_local))       # (E_l, C)
    tok = jnp.where(valids, idxs // k, t)                         # sentinel t
    gate = jnp.take(topv.reshape(-1), idxs, mode="fill",
                    fill_value=0.0) * valids                      # (E_l, C)

    xg = jnp.take(x, tok, axis=0, mode="fill", fill_value=0.0)    # (E_l, C, D)
    wi, wg, wo = (p["wi"].astype(x.dtype), p["wg"].astype(x.dtype),
                  p["wo"].astype(x.dtype))
    hidden = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xg, wi))
    hidden = hidden * jnp.einsum("ecd,edf->ecf", xg, wg)
    ye = jnp.einsum("ecf,efd->ecd", hidden, wo)                   # (E_l, C, D)
    ye = ye * gate[..., None].astype(ye.dtype)

    out = jnp.zeros((t + 1, d), ye.dtype).at[tok.reshape(-1)].add(
        ye.reshape(-1, d), mode="drop")
    return out[:t], aux


def moe_apply(p: Dict, x: jax.Array, cfg: ModelConfig,
              ctx: MeshCtx) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, D) -> (out, aux_loss).  EP over tp axis via shard_map when a
    mesh is present; identical math single-device otherwise."""
    b, s, d = x.shape
    e = cfg.n_experts
    ep = e % ctx.tp_size == 0 and ctx.tp_size > 1
    xf = x.reshape(b * s, d)

    if ctx.mesh is None:
        cap = int(b * s * cfg.top_k / e * cfg.capacity_factor) + 1
        out, aux = _moe_local(xf, p, cfg, e, jnp.int32(0), cap)
    else:
        from jax.sharding import PartitionSpec as P
        from jax.experimental.shard_map import shard_map

        dp = ctx.dp_axes
        dp_ok = (b * s) % ctx.dp_size == 0
        t_loc = b * s // ctx.dp_size if dp_ok else b * s
        tok_spec = P(dp, None) if dp_ok else P(None, None)
        cap = int(t_loc * cfg.top_k / e * cfg.capacity_factor) + 1
        cap = -(-cap // 8) * 8
        n_local = e // ctx.tp_size if ep else e
        fa = ctx.fsdp_axis
        if ep:
            w_spec = P("model", fa, None)
            wo_spec = P("model", None, fa)
        else:
            w_spec = P(None, fa, "model")
            wo_spec = P(None, "model", fa)

        # NOTE (§Perf kimi iteration 2, refuted): emitting the expert combine
        # as psum_scatter into a (dp, model)-sharded token stream tripled the
        # all-reduce volume — GSPMD re-gathers the scattered output to feed
        # the replicated shared-expert branch and the residual add.  A full
        # psum with GSPMD left to fuse the downstream reshard is cheaper.
        use_rs = False

        def shard_fn(xl, router, wi, wg, wo):
            if fa is not None:  # FSDP: gather weight shards for this layer
                wi = jax.lax.all_gather(wi, fa, axis=1, tiled=True)
                wg = jax.lax.all_gather(wg, fa, axis=1, tiled=True)
                wo = jax.lax.all_gather(wo, fa, axis=2, tiled=True)
            off = (jax.lax.axis_index("model") * n_local) if ep else jnp.int32(0)
            pl = {"router": router, "wi": wi, "wg": wg, "wo": wo}
            out, aux = _moe_local(xl, pl, cfg, n_local, off, cap)
            if use_rs:
                out = jax.lax.psum_scatter(out, "model", scatter_dimension=0,
                                           tiled=True)
            else:
                out = jax.lax.psum(out, "model")
            if dp_ok:
                aux = jax.lax.pmean(aux, dp)
            return out, aux

        out_spec = (P((*dp, "model") if dp_ok else None, None) if use_rs
                    else tok_spec)
        out, aux = shard_map(
            shard_fn, mesh=ctx.mesh,
            in_specs=(tok_spec, P(None, None), w_spec, w_spec, wo_spec),
            out_specs=(out_spec, P()),
            check_rep=False,
        )(xf, p["router"], p["wi"], p["wg"], p["wo"])

    out = out.reshape(b, s, d).astype(x.dtype)
    if cfg.n_shared_experts:
        out = out + mlp_apply(p["shared"], x, cfg)
    return out, aux


# ---------------------------------------------------------------- Mamba-1


def mamba_spec(cfg: ModelConfig, ctx: MeshCtx) -> Dict:
    d, di, n, r = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.dt_rank_
    ax = "model" if di % ctx.tp_size == 0 else None
    return {
        "wx": Spec((d, di), ("fsdp", ax)),
        "wz": Spec((d, di), ("fsdp", ax)),
        "conv_w": Spec((di, cfg.d_conv), (ax, None)),
        "conv_b": Spec((di,), (ax,), init="zeros"),
        "x_proj": Spec((di, r + 2 * n), (ax, None)),
        "dt_proj": Spec((r, di), (None, ax)),
        "dt_bias": Spec((di,), (ax,), init="dt_bias"),
        "a_log": Spec((di, n), (ax, None), init="mamba_a"),
        "d_skip": Spec((di,), (ax,), init="ones"),
        "out_proj": Spec((di, d), (ax, "fsdp")),
    }


def _mamba_core(p, xc, cfg, h0):
    """xc: post-conv activations (B, S, di).  Returns (y, h_last)."""
    n, r = cfg.ssm_state, cfg.dt_rank_
    proj = xc @ p["x_proj"].astype(xc.dtype)                      # (B,S,r+2N)
    dt_r, b_mat, c_mat = jnp.split(proj, [r, r + n], axis=-1)
    dt = jax.nn.softplus(
        dt_r.astype(jnp.float32) @ p["dt_proj"].astype(jnp.float32)
        + p["dt_bias"].astype(jnp.float32))                       # (B,S,di)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))                  # (di,N)
    cd = xc.dtype                                                  # bf16 path
    a_bar = jnp.exp(dt[..., None] * a).astype(cd)                 # (B,S,di,N)
    bx = (dt[..., None].astype(cd) * b_mat[:, :, None, :].astype(cd)
          * xc[..., None])
    hs, h_last = layers.chunked_linear_recurrence(a_bar, bx, h0,
                                                  cfg.scan_chunk,
                                                  compute_dtype=cd)
    y = jnp.einsum("bsdn,bsn->bsd", hs, c_mat,
                   preferred_element_type=jnp.float32)
    y = y + p["d_skip"].astype(jnp.float32) * xc.astype(jnp.float32)
    return y.astype(xc.dtype), h_last


def mamba_apply(p: Dict, x: jax.Array, cfg: ModelConfig, ctx: MeshCtx) -> jax.Array:
    b = x.shape[0]
    di, n = cfg.d_inner, cfg.ssm_state
    xz = x @ p["wx"].astype(x.dtype)
    z = x @ p["wz"].astype(x.dtype)
    xc, _ = layers.causal_conv1d(xz, p["conv_w"].astype(x.dtype))
    xc = jax.nn.silu(xc + p["conv_b"].astype(x.dtype))
    h0 = jnp.zeros((b, di, n), jnp.float32)
    y, _ = _mamba_core(p, xc, cfg, h0)
    y = y * jax.nn.silu(z)
    return y @ p["out_proj"].astype(x.dtype)


def mamba_init_cache(cfg: ModelConfig, batch: int, dtype) -> Dict:
    return {
        "conv": jnp.zeros((batch, cfg.d_conv - 1, cfg.d_inner), dtype),
        "h": jnp.zeros((batch, cfg.d_inner, cfg.ssm_state), jnp.float32),
    }


def mamba_decode(p: Dict, x: jax.Array, cache: Dict, cfg: ModelConfig,
                 ctx: MeshCtx) -> Tuple[jax.Array, Dict]:
    """Single-token recurrent step.  x: (B, 1, D)."""
    n, r = cfg.ssm_state, cfg.dt_rank_
    xz = x @ p["wx"].astype(x.dtype)
    z = x @ p["wz"].astype(x.dtype)
    xc, conv_state = layers.causal_conv1d(xz, p["conv_w"].astype(x.dtype),
                                          cache["conv"])
    xc = jax.nn.silu(xc + p["conv_b"].astype(x.dtype))
    proj = xc @ p["x_proj"].astype(x.dtype)
    dt_r, b_mat, c_mat = jnp.split(proj, [r, r + n], axis=-1)
    dt = jax.nn.softplus(
        dt_r.astype(jnp.float32) @ p["dt_proj"].astype(jnp.float32)
        + p["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    a_bar = jnp.exp(dt[:, 0, :, None] * a)                        # (B,di,N)
    bx = (dt[:, 0, :, None] * b_mat[:, 0, None, :].astype(jnp.float32)
          * xc[:, 0, :, None].astype(jnp.float32))
    h = a_bar * cache["h"] + bx
    y = jnp.einsum("bdn,bn->bd", h, c_mat[:, 0].astype(jnp.float32))
    y = y + p["d_skip"].astype(jnp.float32) * xc[:, 0].astype(jnp.float32)
    y = (y.astype(x.dtype) * jax.nn.silu(z[:, 0]))[:, None, :]
    out = y @ p["out_proj"].astype(x.dtype)
    return out, {"conv": conv_state, "h": h}


# ---------------------------------------------------------------- RG-LRU


def rglru_spec(cfg: ModelConfig, ctx: MeshCtx) -> Dict:
    d, w = cfg.d_model, cfg.lru_width_
    ax = "model" if w % ctx.tp_size == 0 else None
    return {
        "wx": Spec((d, w), ("fsdp", ax)),
        "wy": Spec((d, w), ("fsdp", ax)),        # gate branch
        "conv_w": Spec((w, cfg.d_conv), (ax, None)),
        "conv_b": Spec((w,), (ax,), init="zeros"),
        "w_input": Spec((w, w), (None, ax)),
        "b_input": Spec((w,), (ax,), init="zeros"),
        "w_rec": Spec((w, w), (None, ax)),
        "b_rec": Spec((w,), (ax,), init="zeros"),
        "lam": Spec((w,), (ax,), init="rglru_a"),
        "out_proj": Spec((w, d), (ax, "fsdp")),
    }


_RGLRU_C = 8.0


def _rglru_gates(p, xc):
    xf = xc.astype(jnp.float32)
    i_gate = jax.nn.sigmoid(xf @ p["w_input"].astype(jnp.float32)
                            + p["b_input"].astype(jnp.float32))
    r_gate = jax.nn.sigmoid(xf @ p["w_rec"].astype(jnp.float32)
                            + p["b_rec"].astype(jnp.float32))
    log_a = -_RGLRU_C * jax.nn.softplus(p["lam"].astype(jnp.float32)) * r_gate
    a = jnp.exp(log_a)
    gated_x = xf * i_gate
    b = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * gated_x
    return a, b


def rglru_apply(p: Dict, x: jax.Array, cfg: ModelConfig, ctx: MeshCtx) -> jax.Array:
    b_sz, w = x.shape[0], cfg.lru_width_
    xz = x @ p["wx"].astype(x.dtype)
    gate = x @ p["wy"].astype(x.dtype)
    xc, _ = layers.causal_conv1d(xz, p["conv_w"].astype(x.dtype))
    xc = xc + p["conv_b"].astype(x.dtype)
    a, b = _rglru_gates(p, xc)
    hs, _ = layers.chunked_linear_recurrence(
        a, b, jnp.zeros((b_sz, w), jnp.float32), cfg.scan_chunk)
    y = hs.astype(x.dtype) * jax.nn.gelu(gate)
    return y @ p["out_proj"].astype(x.dtype)


def rglru_init_cache(cfg: ModelConfig, batch: int, dtype) -> Dict:
    return {
        "conv": jnp.zeros((batch, cfg.d_conv - 1, cfg.lru_width_), dtype),
        "h": jnp.zeros((batch, cfg.lru_width_), jnp.float32),
    }


def rglru_decode(p: Dict, x: jax.Array, cache: Dict, cfg: ModelConfig,
                 ctx: MeshCtx) -> Tuple[jax.Array, Dict]:
    xz = x @ p["wx"].astype(x.dtype)
    gate = x @ p["wy"].astype(x.dtype)
    xc, conv_state = layers.causal_conv1d(xz, p["conv_w"].astype(x.dtype),
                                          cache["conv"])
    xc = xc + p["conv_b"].astype(x.dtype)
    a, b = _rglru_gates(p, xc)                    # (B,1,W)
    h = a[:, 0] * cache["h"] + b[:, 0]
    y = h[:, None, :].astype(x.dtype) * jax.nn.gelu(gate)
    return y @ p["out_proj"].astype(x.dtype), {"conv": conv_state, "h": h}


# ---------------------------------------------------------------- norms


def norm_spec(cfg: ModelConfig) -> Dict:
    return {"scale": Spec((cfg.d_model,), (None,), init="zeros")}


def norm_apply(p: Dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    return layers.rms_norm(x, p["scale"], cfg.norm_eps)
