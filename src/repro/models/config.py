"""Model configuration for the architecture zoo.

One frozen dataclass covers all 10 assigned families; family-specific fields
are zero/None when unused.  ``reduced()`` derives the CPU smoke-test config.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp

__all__ = ["ModelConfig"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None  # default d_model // n_heads
    qkv_bias: bool = False
    act: str = "silu"               # silu (SwiGLU) | gelu
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = False

    # attention variants
    sliding_window: Optional[int] = None    # SWA width (tokens)

    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    n_shared_experts: int = 0
    capacity_factor: float = 1.25

    # SSM (Mamba-1)
    ssm_state: int = 0
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0                # 0 -> ceil(d_model / 16)

    # hybrid (RecurrentGemma): block pattern = `pattern_rnn` RG-LRU blocks
    # followed by 1 local-attention block, repeated.
    pattern_rnn: int = 0
    local_window: int = 2048
    lru_width: int = 0              # 0 -> d_model

    # encoder-decoder (Whisper)
    n_enc_layers: int = 0
    enc_seq_ratio: int = 2          # stub frontend: enc_len = seq_len // ratio

    # VLM (Llama-3.2-Vision): one cross-attn block every `cross_attn_every`
    cross_attn_every: int = 0
    n_image_tokens: int = 0

    # TP head padding: when n_heads doesn't divide the model axis, pad query
    # heads up to the next multiple so attention shards fully (Megatron GQA
    # with replicated KV).  Padded heads are extra capacity, not a stub —
    # set False to keep the exact reference head count (smoke tests use
    # tp=1 where padding is a no-op anyway).
    pad_heads: bool = True

    # numerics / memory
    dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"
    remat: bool = True
    scan_chunk: int = 256           # recurrence chunk (ssm / rg-lru)
    attn_chunk: int = 1024          # flash-attention KV chunk

    # ---- derived ----
    @property
    def head_dim_(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.n_heads if self.n_heads else 0

    @property
    def dt_rank_(self) -> int:
        return self.dt_rank or -(-self.d_model // 16)

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def lru_width_(self) -> int:
        return self.lru_width or self.d_model

    @property
    def activation_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def subquadratic(self) -> bool:
        """Can this arch run long_500k decode with O(1)/O(window) state?"""
        return (self.family in ("ssm", "hybrid")
                or self.sliding_window is not None)

    def n_params(self) -> int:
        """Approximate parameter count (used for MODEL_FLOPS = 6·N·D)."""
        d, hd = self.d_model, self.head_dim_
        attn = (d * hd * (self.n_heads + 2 * self.n_kv_heads)
                + self.n_heads * hd * d) if self.n_heads else 0
        dense_mlp = d * self.d_ff * (3 if self.act == "silu" else 2)
        per_layer = 0
        if self.family == "ssm":
            di, n, r = self.d_inner, self.ssm_state, self.dt_rank_
            per_layer = (d * 2 * di + di * self.d_conv + di * (2 * n + r)
                         + r * di + di * n + di * d)
        elif self.family == "moe":
            moe = self.n_experts * d * self.moe_d_ff * 3 + d * self.n_experts
            moe += self.n_shared_experts * d * self.moe_d_ff * 3
            per_layer = attn + moe
        elif self.family == "hybrid":
            w = self.lru_width_
            rnn = d * w * 2 + w * d + 2 * w + d * self.d_ff * 3
            att = attn + d * self.d_ff * 3
            per_layer = (self.pattern_rnn * rnn + att) / (self.pattern_rnn + 1)
        else:
            per_layer = attn + dense_mlp
        total = self.n_layers * per_layer + self.vocab_size * d
        if self.family == "audio":
            total += self.n_enc_layers * (attn + dense_mlp)
        if self.family == "vlm" and self.cross_attn_every:
            n_cross = self.n_layers // self.cross_attn_every
            total += n_cross * attn
        if not self.tie_embeddings:
            total += self.vocab_size * d
        return int(total)

    def n_active_params(self) -> int:
        """Active params per token (MoE: only top_k + shared experts)."""
        if self.family != "moe":
            return self.n_params()
        d = self.d_model
        active_moe = (self.top_k + self.n_shared_experts) * d * self.moe_d_ff * 3
        hd = self.head_dim_
        attn = d * hd * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * hd * d
        per_layer = attn + active_moe + d * self.n_experts
        return int(self.n_layers * per_layer + 2 * self.vocab_size * d)

    def reduced(self) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests."""
        def cap(v, m):
            return min(v, m)

        return dataclasses.replace(
            self,
            n_layers=cap(self.n_layers, 4) if self.family != "hybrid"
            else (self.pattern_rnn + 1),
            d_model=cap(self.d_model, 64),
            n_heads=cap(self.n_heads, 4),
            n_kv_heads=cap(self.n_kv_heads, 2) if self.n_kv_heads < self.n_heads
            else cap(self.n_heads, 4),
            head_dim=16 if self.head_dim or self.d_model > 64 else None,
            d_ff=cap(self.d_ff, 128) if self.d_ff else 0,
            vocab_size=cap(self.vocab_size, 512),
            n_experts=cap(self.n_experts, 8),
            top_k=cap(self.top_k, 2),
            moe_d_ff=cap(self.moe_d_ff, 64),
            # drop-free capacity so smoke tests are exactly batch-invariant
            capacity_factor=float(max(self.n_experts, 1)),
            ssm_state=cap(self.ssm_state, 8),
            dt_rank=8 if self.family == "ssm" else 0,
            lru_width=cap(self.lru_width_, 64) if self.family == "hybrid" else 0,
            local_window=cap(self.local_window, 32),
            sliding_window=cap(self.sliding_window, 32) if self.sliding_window else None,
            n_enc_layers=cap(self.n_enc_layers, 2),
            n_image_tokens=cap(self.n_image_tokens, 16),
            cross_attn_every=cap(self.cross_attn_every, 2) if self.cross_attn_every else 0,
            scan_chunk=min(self.scan_chunk, 16) if self.scan_chunk else 0,
            attn_chunk=32,
            dtype="float32",
            param_dtype="float32",
            remat=False,
        )
