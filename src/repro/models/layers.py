"""Shared neural-net primitives for the architecture zoo.

Everything is functional (params dict in, array out), fp32 for norms /
softmax / recurrences, activation dtype elsewhere.  Attention is
flash-style (q- and kv-chunked online softmax) so 32k-token prefill never
materializes an S×S score matrix.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

__all__ = [
    "rms_norm", "rope", "flash_attention", "decode_attention",
    "mlp", "chunked_linear_recurrence", "causal_conv1d",
]


import functools as _functools


@_functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    """RMSNorm with dtype-preserving backward.

    Forward: variance via an f32-accumulating dot on bf16 inputs (no f32
    copy of x exists, so XLA can't hoist an (L,B,S,D) f32 convert of the
    saved residual stack out of the backward loop).  Backward: custom vjp
    keeps dx in x.dtype — the naive AD path upcasts the entire residual
    cotangent to f32 through the variance branch, doubling every backward
    collective and activation store (EXPERIMENTS.md §Perf, kimi iter 3).
    """
    inv, _ = _rms_inv(x, eps)
    return x * inv * (1.0 + scale.astype(x.dtype))


def _rms_inv(x, eps):
    var = jnp.einsum("...d,...d->...", x, x,
                     preferred_element_type=jnp.float32)[..., None]
    var = var / x.shape[-1]
    inv_f32 = jax.lax.rsqrt(var + eps)
    return inv_f32.astype(x.dtype), inv_f32


def _rms_norm_fwd(x, scale, eps):
    inv, inv_f32 = _rms_inv(x, eps)
    return x * inv * (1.0 + scale.astype(x.dtype)), (x, inv, scale)


def _rms_norm_bwd(eps, res, dy):
    x, inv, scale = res
    n = x.shape[-1]
    g = dy * (1.0 + scale.astype(dy.dtype))
    # Σ g·x in f32 (accumulating dot), correction applied in x.dtype
    gx = jnp.einsum("...d,...d->...", g, x,
                    preferred_element_type=jnp.float32)[..., None]
    inv_f32 = inv.astype(jnp.float32)
    corr = (inv_f32 * inv_f32 * inv_f32 * gx / n).astype(x.dtype)
    dx = g * inv - x * corr
    dscale = jnp.einsum("...d,...d->d", dy.astype(jnp.float32),
                        (x * inv).astype(jnp.float32)).astype(scale.dtype)
    return dx, dscale


rms_norm.defvjp(_rms_norm_fwd, _rms_norm_bwd)


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding. x: (B, S, H, hd); positions: (S,) or (B, S)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = jnp.exp(-jnp.arange(0, half, dtype=jnp.float32)
                    * (math.log(theta) / half))
    if positions.ndim == 1:
        ang = positions[None, :, None].astype(jnp.float32) * freqs
    else:
        ang = positions[..., None].astype(jnp.float32) * freqs
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def _repeat_kv(k: jax.Array, n_rep: int) -> jax.Array:
    if n_rep == 1:
        return k
    b, s, kv, hd = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, kv, n_rep, hd)).reshape(
        b, s, kv * n_rep, hd)


def _mask_bias(qp, kp, sk0, causal, window, qc, kc):
    """Additive attention bias (qc, kc) f32: 0 where visible, −inf where not.

    An additive bias (instead of a broadcast boolean select) keeps the
    layer-loop-invariant value XLA hoists at (qc,kc) f32 instead of a
    (nq,nk,B,qc,H,kc) pred stack — see EXPERIMENTS.md §Perf iteration 1.
    """
    mask = jnp.broadcast_to(kp[None, :] < sk0, (qc, kc))   # kv padding
    if causal:
        mask &= qp[:, None] >= kp[None, :]
    if window is not None:
        mask &= qp[:, None] - kp[None, :] < window
    return jnp.where(mask, 0.0, -jnp.inf).astype(jnp.float32)


def _flash_core(causal, window, q_offset, qc, kc, sq0, sk0):
    """custom_vjp flash attention with recompute backward.

    lax.scan AD would otherwise stash per-step score-sized residuals
    ((nk, B, qc, H, kc) stacks — O(S²) memory again); the custom backward
    saves only (q, k, v, o, m, l) and recomputes score blocks chunkwise,
    exactly like the TPU kernel would.
    """

    def fwd_chunks(q, k, v):
        b, sq, h, hd = q.shape
        nq, nk = sq // qc, k.shape[1] // kc
        scale = 1.0 / math.sqrt(hd)
        ks = jnp.moveaxis(k.reshape(b, nk, kc, h, hd), 1, 0)
        vs = jnp.moveaxis(v.reshape(b, nk, kc, h, hd), 1, 0)

        def q_body(_, qi):
            q_blk, q_idx = qi
            # optimization_barrier stops XLA from constant-folding the
            # (nq × nk) mask grid into an S×S pred stack outside the loops
            q_idx = jax.lax.optimization_barrier(q_idx)
            qp = q_idx * qc + jnp.arange(qc) + q_offset

            def kv_body(carry, ki):
                m, l, acc = carry
                k_blk, v_blk, k_idx = ki
                k_idx = jax.lax.optimization_barrier(k_idx)
                kp = k_idx * kc + jnp.arange(kc)
                s = jnp.einsum("bqhd,bkhd->bqhk", q_blk.astype(jnp.float32),
                               k_blk.astype(jnp.float32)) * scale
                bias = _mask_bias(qp, kp, sk0, causal, window, qc, kc)
                s = s + bias[None, :, None, :]
                m_new = jnp.maximum(m, s.max(axis=-1))
                m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
                p = jnp.exp(s - m_safe[..., None])   # exp(-inf)=0: mask folded
                alpha = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
                l_new = l * alpha + p.sum(axis=-1)
                acc_new = acc * alpha[..., None] + jnp.einsum(
                    "bqhk,bkhd->bqhd", p, v_blk.astype(jnp.float32))
                return (m_new, l_new, acc_new), None

            init = (jnp.full((b, qc, h), -jnp.inf, jnp.float32),
                    jnp.zeros((b, qc, h), jnp.float32),
                    jnp.zeros((b, qc, h, hd), jnp.float32))
            (m, l, acc), _ = jax.lax.scan(
                kv_body, init, (ks, vs, jnp.arange(nk)))
            out = acc / jnp.maximum(l, 1e-30)[..., None]
            m_safe = jnp.where(jnp.isfinite(m), m, 0.0)
            return None, (out.astype(q.dtype), m_safe, l)

        qs = jnp.moveaxis(q.reshape(b, nq, qc, h, hd), 1, 0)
        _, (out, m, l) = jax.lax.scan(q_body, None, (qs, jnp.arange(nq)))
        reord = lambda x: jnp.moveaxis(x, 0, 1).reshape(b, sq, *x.shape[3:])
        return reord(out), reord(m), reord(l)

    @jax.custom_vjp
    def attn(q, k, v):
        out, _, _ = fwd_chunks(q, k, v)
        return out

    def attn_fwd(q, k, v):
        out, m, l = fwd_chunks(q, k, v)
        return out, (q, k, v, out, m, l)

    def attn_bwd(res, do):
        q, k, v, o, m, l = res
        b, sq, h, hd = q.shape
        nq, nk = sq // qc, k.shape[1] // kc
        scale = 1.0 / math.sqrt(hd)
        delta = jnp.sum(do.astype(jnp.float32) * o.astype(jnp.float32), -1)
        linv = 1.0 / jnp.maximum(l, 1e-30)

        qs = jnp.moveaxis(q.reshape(b, nq, qc, h, hd), 1, 0)
        dos = jnp.moveaxis(do.reshape(b, nq, qc, h, hd), 1, 0)
        ms = jnp.moveaxis(m.reshape(b, nq, qc, h), 1, 0)
        lis = jnp.moveaxis(linv.reshape(b, nq, qc, h), 1, 0)
        ds_ = jnp.moveaxis(delta.reshape(b, nq, qc, h), 1, 0)
        ks = jnp.moveaxis(k.reshape(b, nk, kc, h, hd), 1, 0)
        vs = jnp.moveaxis(v.reshape(b, nk, kc, h, hd), 1, 0)

        def kv_body(dq_acc, ki):
            k_blk, v_blk, k_idx = ki
            k_idx = jax.lax.optimization_barrier(k_idx)
            kp = k_idx * kc + jnp.arange(kc)

            def q_body(carry, qi):
                dkc, dvc = carry
                q_blk, do_blk, m_blk, li_blk, dl_blk, q_idx = qi
                q_idx = jax.lax.optimization_barrier(q_idx)
                qp = q_idx * qc + jnp.arange(qc) + q_offset
                s = jnp.einsum("bqhd,bkhd->bqhk", q_blk.astype(jnp.float32),
                               k_blk.astype(jnp.float32)) * scale
                bias = _mask_bias(qp, kp, sk0, causal, window, qc, kc)
                p = jnp.exp(s + bias[None, :, None, :] - m_blk[..., None])
                p = p * li_blk[..., None]
                dvc = dvc + jnp.einsum("bqhk,bqhd->bkhd", p,
                                       do_blk.astype(jnp.float32))
                dp = jnp.einsum("bqhd,bkhd->bqhk", do_blk.astype(jnp.float32),
                                v_blk.astype(jnp.float32))
                dsv = p * (dp - dl_blk[..., None]) * scale
                dq_blk = jnp.einsum("bqhk,bkhd->bqhd", dsv,
                                    k_blk.astype(jnp.float32))
                dkc = dkc + jnp.einsum("bqhk,bqhd->bkhd", dsv,
                                       q_blk.astype(jnp.float32))
                return (dkc, dvc), dq_blk

            init = (jnp.zeros((b, kc, h, hd), jnp.float32),
                    jnp.zeros((b, kc, h, hd), jnp.float32))
            (dkc, dvc), dq_blocks = jax.lax.scan(
                q_body, init, (qs, dos, ms, lis, ds_, jnp.arange(nq)))
            return dq_acc + dq_blocks, (dkc, dvc)

        dq0 = jnp.zeros((nq, b, qc, h, hd), jnp.float32)
        dq, (dk, dv) = jax.lax.scan(kv_body, dq0, (ks, vs, jnp.arange(nk)))
        reord = lambda x, s: jnp.moveaxis(x, 0, 1).reshape(b, s, h, hd)
        return (reord(dq, sq).astype(q.dtype),
                reord(dk, k.shape[1]).astype(k.dtype),
                reord(dv, v.shape[1]).astype(v.dtype))

    attn.defvjp(attn_fwd, attn_bwd)
    return attn


def flash_attention(
    q: jax.Array,               # (B, Sq, H, hd)
    k: jax.Array,               # (B, Sk, KV, hd)
    v: jax.Array,               # (B, Sk, KV, hd)
    *,
    causal: bool,
    window: Optional[int] = None,
    q_offset: int = 0,          # absolute position of q[0] (cross/cache use)
    chunk: int = 1024,
) -> jax.Array:
    """Chunked online-softmax attention, O(S) memory in fwd AND bwd.

    Causality/window handled by masking (block skipping is a §Perf
    iteration, see EXPERIMENTS.md).
    """
    b, sq0, h, hd = q.shape
    sk0, kv = k.shape[1], k.shape[2]
    k = _repeat_kv(k, h // kv)
    v = _repeat_kv(v, h // kv)

    qc = min(chunk, sq0)
    kc = min(chunk, sk0)
    sq = -(-sq0 // qc) * qc
    sk = -(-sk0 // kc) * kc
    if sq != sq0:
        q = jnp.pad(q, ((0, 0), (0, sq - sq0), (0, 0), (0, 0)))
    if sk != sk0:
        k = jnp.pad(k, ((0, 0), (0, sk - sk0), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, sk - sk0), (0, 0), (0, 0)))

    attn = _flash_core(causal, window, q_offset, qc, kc, sq0, sk0)
    return attn(q, k, v)[:, :sq0]


def decode_attention(
    q: jax.Array,               # (B, 1, H, hd)
    cache_k: jax.Array,         # (B, S, KV, hd)
    cache_v: jax.Array,
    pos: jax.Array,             # scalar: number of valid cache entries
    *,
    window: Optional[int] = None,
) -> jax.Array:
    b, _, h, hd = q.shape
    s, kv = cache_k.shape[1], cache_k.shape[2]
    k = _repeat_kv(cache_k, h // kv)
    v = _repeat_kv(cache_v, h // kv)
    scale = 1.0 / math.sqrt(hd)
    scores = jnp.einsum("bqhd,bkhd->bqhk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    k_pos = jnp.arange(s)
    valid = k_pos[None, :] < pos
    if window is not None:
        valid &= k_pos[None, :] >= pos - window
    scores = jnp.where(valid[:, None, None, :], scores, -jnp.inf)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bqhk,bkhd->bqhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def mlp(p: dict, x: jax.Array, act: str) -> jax.Array:
    if act == "silu":
        hidden = jax.nn.silu(x @ p["wi"]) * (x @ p["wg"])
    else:
        hidden = jax.nn.gelu(x @ p["wi"])
    return hidden @ p["wo"]


def causal_conv1d(x: jax.Array, w: jax.Array, state: Optional[jax.Array] = None):
    """Depthwise causal conv.  x: (B, S, C); w: (C, K).
    Returns (y, new_state) where state is the last K-1 inputs."""
    k = w.shape[-1]
    if state is None:
        state = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)          # (B, S+K-1, C)
    y = jax.lax.conv_general_dilated(
        xp, w.T[:, None, :],                          # (K, I=1, O=C)
        window_strides=(1,),
        padding="VALID",
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=x.shape[2],
    )
    new_state = xp[:, -(k - 1):, :]
    return y, new_state


def _chunked_recurrence_impl(a: jax.Array, b: jax.Array, h0: jax.Array,
                             chunk: int, compute_dtype):
    if chunk == 0:   # sequential-in-time mode (mamba-kernel structure):
        # one pass over S, h carried in registers — HBM traffic is exactly
        # read(a,b) + write(h), no O(log chunk) associative-scan levels.
        def step(h, ab):
            a_t, b_t = ab
            h = a_t.astype(jnp.float32) * h + b_t.astype(jnp.float32)
            return h, h.astype(compute_dtype)

        h_last, hs = jax.lax.scan(
            step, h0.astype(jnp.float32),
            (jnp.moveaxis(a, 1, 0), jnp.moveaxis(b, 1, 0)))
        return jnp.moveaxis(hs, 0, 1), h_last

    bsz, s0 = a.shape[0], a.shape[1]
    chunk = min(chunk, s0)
    s = -(-s0 // chunk) * chunk
    if s != s0:  # pad with identity steps (a=1, b=0) to preserve h_last
        pad = [(0, 0), (0, s - s0)] + [(0, 0)] * (a.ndim - 2)
        a = jnp.pad(a, pad, constant_values=1.0)
        b = jnp.pad(b, pad)
    nc = s // chunk
    rest = a.shape[2:]
    a_c = a.reshape(bsz, nc, chunk, *rest).astype(compute_dtype)
    b_c = b.reshape(bsz, nc, chunk, *rest).astype(compute_dtype)

    def block(carry, ab):
        a_blk, b_blk = ab                              # (B, chunk, …)

        def combine(x, y):
            ax, bx = x
            ay, by = y
            return ax * ay, ay * bx + by

        a_sc, b_sc = jax.lax.associative_scan(combine, (a_blk, b_blk), axis=1)
        h = a_sc * carry[:, None].astype(compute_dtype) + b_sc
        return h[:, -1].astype(jnp.float32), h         # fp32 carry

    h_last, hs = jax.lax.scan(
        block, h0.astype(jnp.float32),
        (jnp.moveaxis(a_c, 1, 0), jnp.moveaxis(b_c, 1, 0)))
    hs = jnp.moveaxis(hs, 0, 1).reshape(bsz, s, *rest)
    return hs[:, :s0], h_last


@_functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def chunked_linear_recurrence(a: jax.Array, b: jax.Array, h0: jax.Array,
                              chunk: int,
                              compute_dtype=jnp.float32) -> tuple[jax.Array, jax.Array]:
    """h_t = a_t ⊙ h_{t-1} + b_t, scanned over axis 1 of (B, S, …).

    Chunked: outer lax.scan over S/chunk blocks carrying h, inner
    associative_scan within the block.  Returns (all h_t, h_S).
    Shared by Mamba's selective scan (…= (C, N) state) and the RG-LRU.

    custom_vjp: the adjoint of a linear recurrence is the same recurrence
    run in reverse (λ_t = g_t + a_{t+1} λ_{t+1}; da_t = λ_t·h_{t-1};
    db_t = λ_t), so the backward is one more chunked scan instead of
    AD-through-associative-scan, which stores O(log chunk) full-size
    intermediates per chunk (§Perf falcon iteration 2).
    """
    return _chunked_recurrence_impl(a, b, h0, chunk, compute_dtype)


def _clr_fwd(a, b, h0, chunk, compute_dtype):
    hs, h_last = _chunked_recurrence_impl(a, b, h0, chunk, compute_dtype)
    return (hs, h_last), (a, hs, h0)


def _clr_bwd(chunk, compute_dtype, res, ct):
    a, hs, h0 = res
    dhs, dh_last = ct
    g = dhs.astype(compute_dtype)
    if dh_last is not None:
        g = g.at[:, -1].add(dh_last.astype(compute_dtype))
    # shifted decay: ar_t = a_{t+1}, 0 at the end; reverse scan runs in
    # compute_dtype — an f32 adjoint would double the dominant traffic
    ar = jnp.concatenate(
        [a[:, 1:], jnp.zeros_like(a[:, :1])], axis=1).astype(compute_dtype)
    lam_rev, _ = _chunked_recurrence_impl(
        ar[:, ::-1], g[:, ::-1], jnp.zeros_like(h0, jnp.float32),
        chunk, compute_dtype)
    lam = lam_rev[:, ::-1]
    h_prev = jnp.concatenate(
        [h0.astype(hs.dtype)[:, None], hs[:, :-1]], axis=1)
    da = (lam * h_prev.astype(jnp.float32)).astype(a.dtype)
    db = lam.astype(a.dtype)
    dh0 = (a[:, 0].astype(jnp.float32) * lam[:, 0]).astype(h0.dtype)
    return da, db, dh0


chunked_linear_recurrence.defvjp(_clr_fwd, _clr_bwd)
