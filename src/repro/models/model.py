"""Model assembly: embedding + scanned layer groups + head, for all families.

Layer stacks are ``lax.scan`` over parameter trees with a leading group axis
(keeps HLO size O(1) in depth — essential for 61-layer MoE compiles).
Heterogeneous families (hybrid 2×RG-LRU+1×attn, VLM 1×cross+4×self,
enc-dec) scan over the *repeating group*, so no layer carries unused params.

Three entry points per model: ``forward`` (training/logits), ``prefill``
(build KV/recurrent caches), ``decode`` (one token with caches) — the last
two implement ``serve_step`` for the decode_32k / long_500k dry-run cells.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.context import MeshCtx

from . import blocks
from .config import ModelConfig
from .params import Spec, abstract_params, init_params

__all__ = ["Model"]


def _stack(tree: Any, n: int) -> Any:
    """Add a leading group axis of size n to every Spec in the tree."""
    return jax.tree.map(
        lambda s: Spec((n,) + s.shape, (None,) + s.axes, s.init, s.scale),
        tree, is_leaf=lambda x: isinstance(x, Spec))


# ------------------------------------------------------------ group builders


def _dense_group_spec(cfg: ModelConfig, ctx: MeshCtx) -> Dict:
    g = {
        "ln1": blocks.norm_spec(cfg),
        "attn": blocks.attention_spec(cfg, ctx),
        "ln2": blocks.norm_spec(cfg),
    }
    if cfg.family == "moe":
        g["moe"] = blocks.moe_spec(cfg, ctx)
    else:
        g["mlp"] = blocks.mlp_spec(cfg, ctx)
    return g


def _gather_seq(ctx, x):
    """Megatron-SP boundary: materialize the full sequence at mixer entry
    (residual stream stays sequence-sharded; GSPMD turns the exit psum into
    a reduce-scatter)."""
    return ctx.constrain(x, ctx.dp_axes, None, None)


def _dense_group_apply(gp, x, cfg, ctx):
    h = _gather_seq(ctx, blocks.norm_apply(gp["ln1"], x, cfg))
    x = x + blocks.attention_apply(gp["attn"], h, cfg, ctx,
                                   window=cfg.sliding_window)
    h = _gather_seq(ctx, blocks.norm_apply(gp["ln2"], x, cfg))
    if cfg.family == "moe":
        y, aux = blocks.moe_apply(gp["moe"], h, cfg, ctx)
    else:
        y, aux = blocks.mlp_apply(gp["mlp"], h, cfg), jnp.zeros((), jnp.float32)
    return x + y, aux


def _dense_group_prefill(gp, x, cfg, ctx, cache_len=None):
    h = blocks.norm_apply(gp["ln1"], x, cfg)
    y, cache = blocks.attention_prefill(gp["attn"], h, cfg, ctx,
                                        window=cfg.sliding_window,
                                        cache_len=cache_len)
    x = x + y
    h = blocks.norm_apply(gp["ln2"], x, cfg)
    if cfg.family == "moe":
        y, _ = blocks.moe_apply(gp["moe"], h, cfg, ctx)
    else:
        y = blocks.mlp_apply(gp["mlp"], h, cfg)
    return x + y, cache


def _dense_group_decode(gp, x, cache, pos, cfg, ctx):
    h = blocks.norm_apply(gp["ln1"], x, cfg)
    y, cache = blocks.attention_decode(gp["attn"], h, cache, pos, cfg, ctx,
                                       window=cfg.sliding_window)
    x = x + y
    h = blocks.norm_apply(gp["ln2"], x, cfg)
    if cfg.family == "moe":
        y, _ = blocks.moe_apply(gp["moe"], h, cfg, ctx)
    else:
        y = blocks.mlp_apply(gp["mlp"], h, cfg)
    return x + y, cache


def _ssm_group_spec(cfg, ctx):
    return {"ln": blocks.norm_spec(cfg), "mamba": blocks.mamba_spec(cfg, ctx)}


def _rnn_sublayer_spec(cfg, ctx):
    return {
        "ln1": blocks.norm_spec(cfg),
        "mix": blocks.rglru_spec(cfg, ctx),
        "ln2": blocks.norm_spec(cfg),
        "mlp": blocks.mlp_spec(cfg, ctx),
    }


def _hybrid_group_spec(cfg, ctx):
    return {
        "rnn": [_rnn_sublayer_spec(cfg, ctx) for _ in range(cfg.pattern_rnn)],
        "aln1": blocks.norm_spec(cfg),
        "attn": blocks.attention_spec(cfg, ctx),
        "aln2": blocks.norm_spec(cfg),
        "amlp": blocks.mlp_spec(cfg, ctx),
    }


def _enc_group_spec(cfg, ctx):
    return {
        "ln1": blocks.norm_spec(cfg),
        "attn": blocks.attention_spec(cfg, ctx),
        "ln2": blocks.norm_spec(cfg),
        "mlp": blocks.mlp_spec(cfg, ctx),
    }


def _xdec_group_spec(cfg, ctx):
    """Decoder layer with cross-attention (whisper)."""
    return {
        "ln1": blocks.norm_spec(cfg),
        "attn": blocks.attention_spec(cfg, ctx),
        "lnx": blocks.norm_spec(cfg),
        "xattn": blocks.attention_spec(cfg, ctx, cross=True),
        "ln2": blocks.norm_spec(cfg),
        "mlp": blocks.mlp_spec(cfg, ctx),
    }


def _vlm_group_spec(cfg, ctx):
    return {
        "cross": _xdec_group_spec(cfg, ctx),   # 1 gated cross layer
        "self": [_dense_group_spec(cfg, ctx)
                 for _ in range(cfg.cross_attn_every - 1)],
    }


# ------------------------------------------------------------ model


class Model:
    def __init__(self, cfg: ModelConfig, ctx: Optional[MeshCtx] = None):
        self.cfg = cfg
        self.ctx = ctx or MeshCtx(None)

    # ---- parameter tree ----

    def param_specs(self) -> Dict:
        cfg, ctx = self.cfg, self.ctx
        v, d = cfg.vocab_size, cfg.d_model
        vocab_ax = "model" if v % ctx.tp_size == 0 else None
        if vocab_ax == "model":
            emb_ax, head_in_ax = "fsdp", "fsdp"
        elif d % ctx.tp_size == 0:
            emb_ax, head_in_ax = "model", "model"
        else:
            emb_ax, head_in_ax = None, None
        tree: Dict[str, Any] = {
            "embed": Spec((v, d), (vocab_ax, emb_ax)),
            "final_norm": blocks.norm_spec(cfg),
            "lm_head": Spec((d, v), (head_in_ax, vocab_ax)),
        }
        fam = cfg.family
        if fam in ("dense", "moe"):
            tree["groups"] = _stack(_dense_group_spec(cfg, ctx), cfg.n_layers)
        elif fam == "ssm":
            tree["groups"] = _stack(_ssm_group_spec(cfg, ctx), cfg.n_layers)
        elif fam == "hybrid":
            gsz = cfg.pattern_rnn + 1
            n_full, rem = divmod(cfg.n_layers, gsz)
            tree["groups"] = _stack(_hybrid_group_spec(cfg, ctx), n_full)
            if rem:
                tree["tail"] = _stack(_rnn_sublayer_spec(cfg, ctx), rem)
        elif fam == "audio":
            tree["enc_groups"] = _stack(_enc_group_spec(cfg, ctx), cfg.n_enc_layers)
            tree["enc_norm"] = blocks.norm_spec(cfg)
            tree["groups"] = _stack(_xdec_group_spec(cfg, ctx), cfg.n_layers)
        elif fam == "vlm":
            n_groups = cfg.n_layers // cfg.cross_attn_every
            tree["groups"] = _stack(_vlm_group_spec(cfg, ctx), n_groups)
        else:
            raise ValueError(fam)
        return tree

    def init(self, key: jax.Array) -> Dict:
        return init_params(key, self.param_specs(),
                           jnp.dtype(self.cfg.param_dtype))

    def abstract(self) -> Dict:
        return abstract_params(self.param_specs(), jnp.dtype(self.cfg.param_dtype))

    # ---- forward (training) ----

    def forward(self, params: Dict, tokens: jax.Array,
                extra: Optional[Dict] = None) -> Tuple[jax.Array, jax.Array]:
        """tokens: (B, S) -> (logits (B,S,V) fp32, aux loss scalar)."""
        cfg, ctx = self.cfg, self.ctx
        extra = extra or {}
        dt = cfg.activation_dtype
        x = jnp.take(params["embed"], tokens, axis=0).astype(dt)
        # sequence parallelism: the residual stream (and therefore the
        # per-layer activation stacks the scan saves for backward) shards
        # over the model axis between layers; GSPMD inserts the all-gather
        # at each layer entry / reduce-scatter at exit (Megatron SP).
        seq_ax = ("model" if tokens.shape[1] % max(ctx.tp_size, 1) == 0
                  and ctx.mesh is not None else None)
        x = ctx.constrain(x, ctx.dp_axes, seq_ax, None)

        enc_out = None
        if cfg.family == "audio":
            enc_out = self._encode(params, extra["enc_frames"].astype(dt))
        elif cfg.family == "vlm":
            enc_out = extra["image_embeds"].astype(dt)

        def group_fwd(gp, h):
            return self._group_apply(gp, h, enc_out)

        if cfg.remat:
            group_fwd = jax.checkpoint(
                group_fwd, policy=jax.checkpoint_policies.nothing_saveable)

        def body(carry, gp):
            h, aux = carry
            h, a = group_fwd(gp, h)
            h = ctx.constrain(h, ctx.dp_axes, seq_ax, None)
            return (h, aux + a), None

        (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                                   params["groups"])
        if "tail" in params:
            def tail_body(carry, gp):
                h, aux = carry
                h = _apply_rnn_sublayer(gp, h, cfg, ctx)
                return (h, aux), None

            (x, aux), _ = jax.lax.scan(tail_body, (x, aux), params["tail"])

        x = blocks.norm_apply(params["final_norm"], x, cfg)
        logits = jnp.einsum("bsd,dv->bsv", x,
                            params["lm_head"].astype(x.dtype))
        return logits.astype(jnp.float32), aux

    def _encode(self, params, frames):
        cfg, ctx = self.cfg, self.ctx

        def body(h, gp):
            n = blocks.norm_apply(gp["ln1"], h, cfg)
            h = h + blocks.attention_apply(gp["attn"], n, cfg, ctx, causal=False)
            n = blocks.norm_apply(gp["ln2"], h, cfg)
            h = h + blocks.mlp_apply(gp["mlp"], n, cfg)
            return h, None

        h, _ = jax.lax.scan(body, frames, params["enc_groups"])
        return blocks.norm_apply(params["enc_norm"], h, cfg)

    def _group_apply(self, gp, x, enc_out):
        cfg, ctx = self.cfg, self.ctx
        fam = cfg.family
        zero = jnp.zeros((), jnp.float32)
        if fam in ("dense", "moe"):
            return _dense_group_apply(gp, x, cfg, ctx)
        if fam == "ssm":
            h = _gather_seq(ctx, blocks.norm_apply(gp["ln"], x, cfg))
            return x + blocks.mamba_apply(gp["mamba"], h, cfg, ctx), zero
        if fam == "hybrid":
            for sub in gp["rnn"]:
                x = _apply_rnn_sublayer(sub, x, cfg, ctx)
            h = _gather_seq(ctx, blocks.norm_apply(gp["aln1"], x, cfg))
            x = x + blocks.attention_apply(gp["attn"], h, cfg, ctx,
                                           window=cfg.local_window)
            h = _gather_seq(ctx, blocks.norm_apply(gp["aln2"], x, cfg))
            return x + blocks.mlp_apply(gp["amlp"], h, cfg), zero
        if fam == "audio":
            return _apply_xdec_layer(gp, x, enc_out, cfg, ctx), zero
        if fam == "vlm":
            x = _apply_xdec_layer(gp["cross"], x, enc_out, cfg, ctx)
            for sub in gp["self"]:
                x, _ = _dense_group_apply(sub, x, cfg, ctx)
            return x, zero
        raise ValueError(fam)

    # ---- loss ----

    def loss(self, params, batch: Dict, extra: Optional[Dict] = None):
        logits, aux = self.forward(params, batch["tokens"], extra)
        labels = batch["labels"]
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
        nll = jnp.mean(logz - gold)
        return nll + 0.01 * aux, {"nll": nll, "aux": aux}

    # ---- serving ----

    def init_cache(self, batch: int, cache_len: int,
                   extra_len: int = 0) -> Dict:
        """extra_len: cross-attention source length (encoder frames / image
        tokens) for the audio/vlm families."""
        cfg, ctx = self.cfg, self.ctx
        dt = cfg.activation_dtype
        kv, hd = cfg.n_kv_heads, cfg.head_dim_

        def kv_cache(length):
            return {"k": jnp.zeros((batch, length, kv, hd), dt),
                    "v": jnp.zeros((batch, length, kv, hd), dt)}

        def stacked(tree, n):
            return jax.tree.map(lambda a: jnp.broadcast_to(a, (n,) + a.shape),
                                tree)

        fam = cfg.family
        attn_len = min(cache_len, cfg.sliding_window or cache_len)
        cache: Dict[str, Any] = {"pos": jnp.zeros((), jnp.int32)}
        if fam in ("dense", "moe"):
            cache["groups"] = stacked(kv_cache(attn_len), cfg.n_layers)
        elif fam == "ssm":
            cache["groups"] = stacked(blocks.mamba_init_cache(cfg, batch, dt),
                                      cfg.n_layers)
        elif fam == "hybrid":
            gsz = cfg.pattern_rnn + 1
            n_full, rem = divmod(cfg.n_layers, gsz)
            g = {"rnn": [blocks.rglru_init_cache(cfg, batch, dt)
                         for _ in range(cfg.pattern_rnn)],
                 "attn": kv_cache(min(cache_len, cfg.local_window))}
            cache["groups"] = stacked(g, n_full)
            if rem:
                cache["tail"] = stacked(blocks.rglru_init_cache(cfg, batch, dt),
                                        rem)
        elif fam == "audio":
            cache["groups"] = stacked(
                {"self": kv_cache(attn_len),
                 "cross": kv_cache(extra_len)},
                cfg.n_layers)
        elif fam == "vlm":
            n_groups = cfg.n_layers // cfg.cross_attn_every
            g = {"cross": kv_cache(extra_len),
                 "xself": kv_cache(attn_len),
                 "self": [kv_cache(attn_len)
                          for _ in range(cfg.cross_attn_every - 1)]}
            cache["groups"] = stacked(g, n_groups)
        return cache

    def prefill(self, params, tokens, extra=None,
                cache_len: Optional[int] = None) -> Tuple[jax.Array, Dict]:
        """Full-sequence forward that also returns the serving cache."""
        cfg, ctx = self.cfg, self.ctx
        extra = extra or {}
        dt = cfg.activation_dtype
        x = jnp.take(params["embed"], tokens, axis=0).astype(dt)
        x = ctx.constrain(x, ctx.dp_axes, None, None)

        enc_out = None
        if cfg.family == "audio":
            enc_out = self._encode(params, extra["enc_frames"].astype(dt))
        elif cfg.family == "vlm":
            enc_out = extra["image_embeds"].astype(dt)

        def body(h, gp):
            h, cache = self._group_prefill(gp, h, enc_out, cache_len)
            return h, cache

        x, caches = jax.lax.scan(body, x, params["groups"])
        cache: Dict[str, Any] = {"groups": caches,
                                 "pos": jnp.asarray(tokens.shape[1], jnp.int32)}
        if "tail" in params:
            def tail_body(h, gp):
                h, c = _prefill_rnn_sublayer(gp, h, cfg, ctx)
                return h, c

            x, tail_caches = jax.lax.scan(tail_body, x, params["tail"])
            cache["tail"] = tail_caches

        x = blocks.norm_apply(params["final_norm"], x, cfg)
        logits = jnp.einsum("bsd,dv->bsv", x[:, -1:],
                            params["lm_head"].astype(x.dtype))
        return logits.astype(jnp.float32), cache

    def _group_prefill(self, gp, x, enc_out, cache_len=None):
        cfg, ctx = self.cfg, self.ctx
        fam = cfg.family
        if fam in ("dense", "moe"):
            return _dense_group_prefill(gp, x, cfg, ctx, cache_len)
        if fam == "ssm":
            h = blocks.norm_apply(gp["ln"], x, cfg)
            y, cache = _mamba_prefill(gp["mamba"], h, cfg, ctx)
            return x + y, cache
        if fam == "hybrid":
            caches = {"rnn": []}
            for sub in gp["rnn"]:
                x, c = _prefill_rnn_sublayer(sub, x, cfg, ctx)
                caches["rnn"].append(c)
            h = blocks.norm_apply(gp["aln1"], x, cfg)
            y, c = blocks.attention_prefill(gp["attn"], h, cfg, ctx,
                                            window=cfg.local_window)
            caches["attn"] = c
            x = x + y
            h = blocks.norm_apply(gp["aln2"], x, cfg)
            return x + blocks.mlp_apply(gp["amlp"], h, cfg), caches
        if fam == "audio":
            return _prefill_xdec_layer(gp, x, enc_out, cfg, ctx, cache_len)
        if fam == "vlm":
            x, xc = _prefill_xdec_layer(gp["cross"], x, enc_out, cfg, ctx,
                                        cache_len)
            selfs = []
            for sub in gp["self"]:
                x, c = _dense_group_prefill(sub, x, cfg, ctx, cache_len)
                selfs.append(c)
            return x, {"cross": xc["cross"], "xself": xc["self"], "self": selfs}
        raise ValueError(fam)

    def decode(self, params, cache, tokens) -> Tuple[jax.Array, Dict]:
        """One-token step.  tokens: (B, 1)."""
        cfg, ctx = self.cfg, self.ctx
        dt = cfg.activation_dtype
        pos = cache["pos"]
        x = jnp.take(params["embed"], tokens, axis=0).astype(dt)

        def body(h, xs):
            gp, cache_g = xs
            h, new_c = self._group_decode(gp, h, cache_g, pos)
            return h, new_c

        x, new_caches = jax.lax.scan(body, x, (params["groups"],
                                               cache["groups"]))
        new_cache = {"groups": new_caches, "pos": pos + 1}
        if "tail" in params:
            def tail_body(h, xs):
                gp, c = xs
                h, nc = _decode_rnn_sublayer(gp, h, c, cfg, ctx)
                return h, nc

            x, tail_c = jax.lax.scan(tail_body, x,
                                     (params["tail"], cache["tail"]))
            new_cache["tail"] = tail_c

        x = blocks.norm_apply(params["final_norm"], x, cfg)
        logits = jnp.einsum("bsd,dv->bsv", x,
                            params["lm_head"].astype(x.dtype))
        return logits.astype(jnp.float32), new_cache

    def _group_decode(self, gp, x, cache_g, pos):
        cfg, ctx = self.cfg, self.ctx
        fam = cfg.family
        if fam in ("dense", "moe"):
            return _dense_group_decode(gp, x, cache_g, pos, cfg, ctx)
        if fam == "ssm":
            h = blocks.norm_apply(gp["ln"], x, cfg)
            y, c = blocks.mamba_decode(gp["mamba"], h, cache_g, cfg, ctx)
            return x + y, c
        if fam == "hybrid":
            new_c = {"rnn": []}
            for sub, c in zip(gp["rnn"], cache_g["rnn"]):
                x, nc = _decode_rnn_sublayer(sub, x, c, cfg, ctx)
                new_c["rnn"].append(nc)
            h = blocks.norm_apply(gp["aln1"], x, cfg)
            y, ac = blocks.attention_decode(gp["attn"], h, cache_g["attn"],
                                            pos, cfg, ctx,
                                            window=cfg.local_window)
            new_c["attn"] = ac
            x = x + y
            h = blocks.norm_apply(gp["aln2"], x, cfg)
            return x + blocks.mlp_apply(gp["amlp"], h, cfg), new_c
        if fam == "audio":
            return _decode_xdec_layer(gp, x, cache_g, pos, cfg, ctx)
        if fam == "vlm":
            x, nc_x = _decode_xdec_layer(
                gp["cross"], x,
                {"self": cache_g["xself"], "cross": cache_g["cross"]},
                pos, cfg, ctx)
            new_c = {"cross": nc_x["cross"], "xself": nc_x["self"], "self": []}
            for sub, c in zip(gp["self"], cache_g["self"]):
                x, nc = _dense_group_decode(sub, x, c, pos, cfg, ctx)
                new_c["self"].append(nc)
            return x, new_c
        raise ValueError(fam)


# ------------------------------------------------------------ sub-layer fns


def _apply_rnn_sublayer(gp, x, cfg, ctx):
    h = _gather_seq(ctx, blocks.norm_apply(gp["ln1"], x, cfg))
    x = x + blocks.rglru_apply(gp["mix"], h, cfg, ctx)
    h = _gather_seq(ctx, blocks.norm_apply(gp["ln2"], x, cfg))
    return x + blocks.mlp_apply(gp["mlp"], h, cfg)


def _prefill_rnn_sublayer(gp, x, cfg, ctx):
    from . import layers as L
    b, w = x.shape[0], cfg.lru_width_
    h = blocks.norm_apply(gp["ln1"], x, cfg)
    xz = h @ gp["mix"]["wx"].astype(x.dtype)
    gate = h @ gp["mix"]["wy"].astype(x.dtype)
    xc, conv_state = L.causal_conv1d(xz, gp["mix"]["conv_w"].astype(x.dtype))
    xc = xc + gp["mix"]["conv_b"].astype(x.dtype)
    a, bb = blocks._rglru_gates(gp["mix"], xc)
    hs, h_last = L.chunked_linear_recurrence(
        a, bb, jnp.zeros((b, w), jnp.float32), cfg.scan_chunk)
    y = hs.astype(x.dtype) * jax.nn.gelu(gate)
    x = x + y @ gp["mix"]["out_proj"].astype(x.dtype)
    h = blocks.norm_apply(gp["ln2"], x, cfg)
    x = x + blocks.mlp_apply(gp["mlp"], h, cfg)
    return x, {"conv": conv_state, "h": h_last}


def _decode_rnn_sublayer(gp, x, cache, cfg, ctx):
    h = blocks.norm_apply(gp["ln1"], x, cfg)
    y, nc = blocks.rglru_decode(gp["mix"], h, cache, cfg, ctx)
    x = x + y
    h = blocks.norm_apply(gp["ln2"], x, cfg)
    return x + blocks.mlp_apply(gp["mlp"], h, cfg), nc


def _mamba_prefill(p, x, cfg, ctx):
    from . import layers as L
    b = x.shape[0]
    xz = x @ p["wx"].astype(x.dtype)
    z = x @ p["wz"].astype(x.dtype)
    xc, conv_full = L.causal_conv1d(xz, p["conv_w"].astype(x.dtype))
    xc = jax.nn.silu(xc + p["conv_b"].astype(x.dtype))
    h0 = jnp.zeros((b, cfg.d_inner, cfg.ssm_state), jnp.float32)
    # recompute core but also capture final state
    n, r = cfg.ssm_state, cfg.dt_rank_
    proj = xc @ p["x_proj"].astype(xc.dtype)
    dt_r, b_mat, c_mat = jnp.split(proj, [r, r + n], axis=-1)
    dt = jax.nn.softplus(
        dt_r.astype(jnp.float32) @ p["dt_proj"].astype(jnp.float32)
        + p["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    a_bar = jnp.exp(dt[..., None] * a)
    bx = (dt[..., None] * b_mat[:, :, None, :].astype(jnp.float32)
          * xc[..., None].astype(jnp.float32))
    hs, h_last = L.chunked_linear_recurrence(a_bar, bx, h0, cfg.scan_chunk)
    y = jnp.einsum("bsdn,bsn->bsd", hs, c_mat.astype(jnp.float32))
    y = y + p["d_skip"].astype(jnp.float32) * xc.astype(jnp.float32)
    y = y.astype(x.dtype) * jax.nn.silu(z)
    out = y @ p["out_proj"].astype(x.dtype)
    cache = {"conv": conv_full[:, -(cfg.d_conv - 1):, :], "h": h_last}
    return out, cache


def _prefill_xdec_layer(gp, x, enc_out, cfg, ctx, cache_len=None):
    h = blocks.norm_apply(gp["ln1"], x, cfg)
    y, self_c = blocks.attention_prefill(gp["attn"], h, cfg, ctx,
                                         window=cfg.sliding_window,
                                         cache_len=cache_len)
    x = x + y
    h = blocks.norm_apply(gp["lnx"], x, cfg)
    x = x + blocks.attention_apply(gp["xattn"], h, cfg, ctx, kv_src=enc_out)
    # cross cache: K/V over encoder output, computed once
    xk = jnp.einsum("bsd,dhk->bshk", enc_out,
                    gp["xattn"]["wk"].astype(x.dtype))
    xv = jnp.einsum("bsd,dhk->bshk", enc_out,
                    gp["xattn"]["wv"].astype(x.dtype))
    h = blocks.norm_apply(gp["ln2"], x, cfg)
    x = x + blocks.mlp_apply(gp["mlp"], h, cfg)
    return x, {"self": self_c, "cross": {"k": xk, "v": xv}}


def _apply_xdec_layer(gp, x, enc_out, cfg, ctx):
    h = _gather_seq(ctx, blocks.norm_apply(gp["ln1"], x, cfg))
    x = x + blocks.attention_apply(gp["attn"], h, cfg, ctx,
                                   window=cfg.sliding_window)
    h = _gather_seq(ctx, blocks.norm_apply(gp["lnx"], x, cfg))
    x = x + blocks.attention_apply(gp["xattn"], h, cfg, ctx, kv_src=enc_out)
    h = _gather_seq(ctx, blocks.norm_apply(gp["ln2"], x, cfg))
    return x + blocks.mlp_apply(gp["mlp"], h, cfg)


def _decode_xdec_layer(gp, x, cache, pos, cfg, ctx):
    h = blocks.norm_apply(gp["ln1"], x, cfg)
    y, self_c = blocks.attention_decode(gp["attn"], h, cache["self"], pos,
                                        cfg, ctx, window=cfg.sliding_window)
    x = x + y
    h = blocks.norm_apply(gp["lnx"], x, cfg)
    y, _ = blocks.attention_decode(gp["xattn"], h, cache["cross"], pos,
                                   cfg, ctx, cross=True)
    x = x + y
    h = blocks.norm_apply(gp["ln2"], x, cfg)
    x = x + blocks.mlp_apply(gp["mlp"], h, cfg)
    return x, {"self": self_c, "cross": cache["cross"]}
