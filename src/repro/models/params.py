"""Parameter-spec trees: one description, three interpreters.

Every model describes its parameters as a nested dict of :class:`Spec`
(shape + logical axes + initializer).  Interpreters:

* ``init_params``      — materialize with a PRNG key (real training / tests)
* ``abstract_params``  — ShapeDtypeStruct tree (dry-run lowering, no alloc)
* ``repro.distributed.sharding.param_shardings`` — NamedSharding tree
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["Spec", "init_params", "abstract_params", "map_specs"]


@dataclasses.dataclass(frozen=True)
class Spec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]   # logical axis names, len == ndim
    init: str = "normal"              # normal | zeros | ones | mamba_a | dt_bias
    scale: float = 0.02

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _is_spec(x: Any) -> bool:
    return isinstance(x, Spec)


def _init_leaf(key: jax.Array, spec: Spec, dtype) -> jax.Array:
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, dtype)
    if spec.init == "mamba_a":
        # Mamba-1 A init: A = -(1..N) broadcast over channels; stored as log.
        n = spec.shape[-1]
        a = jnp.broadcast_to(jnp.arange(1, n + 1, dtype=jnp.float32), spec.shape)
        return jnp.log(a).astype(dtype)
    if spec.init == "dt_bias":
        # softplus^-1 of dt uniform in [1e-3, 1e-1]
        u = jax.random.uniform(key, spec.shape, jnp.float32,
                               np.log(1e-3), np.log(1e-1))
        dt = jnp.exp(u)
        return (dt + jnp.log(-jnp.expm1(-dt))).astype(dtype)
    if spec.init == "rglru_a":
        # RG-LRU a-param init so recurrence decay ~ U(0.9, 0.999)
        u = jax.random.uniform(key, spec.shape, jnp.float32, 0.9, 0.999)
        c = 8.0
        return (jnp.log(jnp.expm1(-jnp.log(u**2) / c))).astype(dtype)
    return (spec.scale * jax.random.normal(key, spec.shape, jnp.float32)).astype(dtype)


def init_params(key: jax.Array, tree: Any, dtype) -> Any:
    leaves, treedef = jax.tree.flatten(tree, is_leaf=_is_spec)
    keys = jax.random.split(key, len(leaves))
    out = [_init_leaf(k, s, dtype) for k, s in zip(keys, leaves)]
    return jax.tree.unflatten(treedef, out)


def abstract_params(tree: Any, dtype) -> Any:
    return jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, dtype), tree,
                        is_leaf=_is_spec)


def map_specs(fn: Callable[[Spec], Any], tree: Any) -> Any:
    return jax.tree.map(fn, tree, is_leaf=_is_spec)
