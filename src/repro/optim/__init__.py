from .adamw import adamw  # noqa: F401
from .adafactor import adafactor  # noqa: F401
from .gauss_newton import damped_gauss_newton_head  # noqa: F401
