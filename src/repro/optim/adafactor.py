"""Adafactor (factored second moment, no first moment) — the optimizer used
for the 1T-param kimi-k2 config, where full AdamW states would not fit the
512-chip HBM budget (see EXPERIMENTS.md §Dry-run memory table).
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

__all__ = ["adafactor"]


class AdafactorState(NamedTuple):
    step: jax.Array
    vr: Any    # row factors (or full v for rank<2 leaves)
    vc: Any    # col factors (or None sentinel zeros)


def adafactor(lr: float = 1e-3, decay: float = 0.8, eps: float = 1e-30,
              clip_threshold: float = 1.0, weight_decay: float = 0.0):
    def _factored(p):
        return p.ndim >= 2

    def init(params):
        def vr0(p):
            return (jnp.zeros(p.shape[:-1], jnp.float32) if _factored(p)
                    else jnp.zeros(p.shape, jnp.float32))

        def vc0(p):
            return (jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
                    if _factored(p) else jnp.zeros((1,), jnp.float32))

        return AdafactorState(jnp.zeros((), jnp.int32),
                              jax.tree.map(vr0, params),
                              jax.tree.map(vc0, params))

    def update(grads, state: AdafactorState, params) -> Tuple[Any, AdafactorState]:
        step = state.step + 1
        t = step.astype(jnp.float32)
        beta = 1.0 - t ** (-decay)

        def upd(g, vr, vc, p):
            gf = g.astype(jnp.float32)
            g2 = gf * gf + eps
            if _factored(p):
                vr = beta * vr + (1 - beta) * g2.mean(axis=-1)
                vc = beta * vc + (1 - beta) * g2.mean(axis=-2)
                denom = (vr[..., None] / vr.mean(axis=-1, keepdims=True)[..., None]
                         ) * vc[..., None, :]
                u = gf * jax.lax.rsqrt(denom + eps)
            else:
                vr = beta * vr + (1 - beta) * g2
                u = gf * jax.lax.rsqrt(vr + eps)
            rms_u = jnp.sqrt(jnp.mean(u * u) + eps)
            u = u / jnp.maximum(1.0, rms_u / clip_threshold)
            newp = p.astype(jnp.float32) - lr * (u + weight_decay * p.astype(jnp.float32))
            return newp.astype(p.dtype), vr, vc

        out = jax.tree.map(upd, grads, state.vr, state.vc, params)
        is_t = lambda x: isinstance(x, tuple)
        newp = jax.tree.map(lambda o: o[0], out, is_leaf=is_t)
        vr = jax.tree.map(lambda o: o[1], out, is_leaf=is_t)
        vc = jax.tree.map(lambda o: o[2], out, is_leaf=is_t)
        return newp, AdafactorState(step, vr, vc)

    return init, update
