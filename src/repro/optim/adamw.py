"""Minimal sharded AdamW (optax-style (init, update) pair, no dependency).

Optimizer state inherits the parameter sharding (moments are elementwise),
so FSDP/TP sharding of the model automatically shards the states — this is
what makes the 7–47B configs fit (see EXPERIMENTS.md §Dry-run).
``state_dtype`` bf16 halves optimizer HBM for the largest configs.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

__all__ = ["adamw"]


class AdamWState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


def adamw(lr: float = 3e-4, b1: float = 0.9, b2: float = 0.95,
          eps: float = 1e-8, weight_decay: float = 0.1,
          state_dtype=jnp.float32):
    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, state_dtype)
        return AdamWState(jnp.zeros((), jnp.int32),
                          jax.tree.map(zeros, params),
                          jax.tree.map(zeros, params))

    def update(grads, state: AdamWState, params) -> Tuple[Any, AdamWState]:
        step = state.step + 1
        t = step.astype(jnp.float32)
        c1 = 1.0 - b1 ** t
        c2 = 1.0 - b2 ** t

        def upd(g, m, v, p):
            gf = g.astype(jnp.float32)
            m = b1 * m.astype(jnp.float32) + (1 - b1) * gf
            v = b2 * v.astype(jnp.float32) + (1 - b2) * gf * gf
            u = (m / c1) / (jnp.sqrt(v / c2) + eps)
            u = u + weight_decay * p.astype(jnp.float32)
            newp = p.astype(jnp.float32) - lr * u
            return newp.astype(p.dtype), m.astype(state_dtype), v.astype(state_dtype)

        out = jax.tree.map(upd, grads, state.mu, state.nu, params)
        newp = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
        mu = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
        nu = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
        return newp, AdamWState(step, mu, nu)

    return init, update
