"""Damped Gauss–Newton updates for dense readout heads, accelerated by
piCholesky across the damping schedule (DESIGN.md §4.2).

A GN step on a least-squares head solves ``(H + λI) δ = g`` where the
damping λ is trust-region-adapted every few steps — exactly the
Cholesky-under-diagonal-shift sweep the paper accelerates.  We fit the
piCholesky interpolant once over the plausible damping range and reuse it
for every adaptation, refitting only when λ exits the sampled range
(the paper's MChol narrowing, applied online).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import picholesky, solvers

__all__ = ["damped_gauss_newton_head", "GNState"]


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class GNState:
    model: picholesky.PiCholesky
    lam: jax.Array
    lo: jax.Array
    hi: jax.Array


def damped_gauss_newton_head(
    hessian: jax.Array,
    lam_range: Tuple[float, float] = (1e-4, 1e1),
    g_samples: int = 6,
    degree: int = 2,
    block: int = 128,
) -> Tuple[GNState, Callable]:
    """Returns (state, step_fn); step_fn(state, grad, lam) -> (delta, state).

    ``delta = (H + λI)⁻¹ grad`` via the interpolated factor; exact refit
    happens lazily when λ leaves the fitted range.
    """
    lo, hi = lam_range
    sample = picholesky.choose_sample_lambdas(lo, hi, g_samples)
    model = picholesky.fit(hessian, sample, degree, block=block,
                           basis="centered")
    state = GNState(model=model, lam=jnp.asarray((lo * hi) ** 0.5),
                    lo=jnp.asarray(lo), hi=jnp.asarray(hi))

    def step(state: GNState, grad: jax.Array, lam: jax.Array):
        lam = jnp.clip(lam, state.lo, state.hi)   # stay in fitted range
        l_fac = state.model.eval_factor(lam)
        delta = solvers.solve_from_factor(l_fac, grad)
        return delta, dataclasses.replace(state, lam=lam)

    return state, step
