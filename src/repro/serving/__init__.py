"""CV-as-a-service: the multi-tenant ridge-CV sweep server.

The paper's economics are amortization — a handful of anchor
factorizations serve an entire λ sweep — and this package is the layer
that amortizes *across tenants*: a request queue
(:class:`~repro.serving.server.CVSweepServer`) admits compatible
problems into one stacked ``fold_state`` dispatch
(:meth:`~repro.core.engine.CVEngine.run_batch`) and serves overlapping
Hessians from one shared content-addressed
:class:`~repro.core.factor_cache.FactorCache`, with per-tenant stat
partitioning and result isolation.

:mod:`~repro.serving.traffic` generates the deterministic Zipf-mix
synthetic workload the committed ``BENCH_serving.json`` record measures.
"""
from .server import CVSweepServer, ServerConfig, SweepRequest, SweepResponse
from .traffic import TrafficConfig, make_traffic

__all__ = [
    "CVSweepServer", "ServerConfig", "SweepRequest", "SweepResponse",
    "TrafficConfig", "make_traffic",
]
