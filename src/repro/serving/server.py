"""Request-queue CV sweep server with admission batching.

Tenants submit ridge-CV problems (folds + λ grid + precision); an
admission layer groups *compatible geometries* — same fold shape, dtype,
anchor set and precision — into one stacked folds × λ dispatch through
:meth:`~repro.core.engine.CVEngine.run_batch`, and every engine in the
pool shares ONE content-addressed
:class:`~repro.core.factor_cache.FactorCache`, so a tenant's anchor
factorizations serve every later tenant with the same training Hessians
(the cache fingerprint guarantees byte-identical data, so cross-tenant
reuse can never serve stale or foreign factors).

Service discipline is FIFO **across admission groups** (the group whose
head request is oldest is served next) and FIFO within a group, bounded
by ``max_batch`` requests per dispatch.  Results are isolated per tenant:
:meth:`CVSweepServer.take_responses` hands a tenant only its own
responses.

The flow::

    submit() ──► admission queues (keyed by geometry) ──► step()
                     │                                      │
                     │ same (h, k, n_f, dtype,              │ one
                     │       anchors, precision)            │ run_batch
                     ▼                                      ▼
              [req, req, …]  ──────────────────►  shared FactorCache
                                                   hit | refit | miss

Driven synchronously from the host (``submit`` + ``step``/``drain``) —
the same single-process idiom as ``examples/serve_lm.py``; the queue
discipline, not threads, provides the batching.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Deque, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.core import factor_cache as cachelib
from repro.core.engine import CVEngine, CVStrategy, PiCholeskyStrategy
from repro.core.folds import CVResult, FoldData
from repro.core.precision import resolve_precision

__all__ = ["SweepRequest", "SweepResponse", "ServerConfig", "CVSweepServer"]


@dataclasses.dataclass
class SweepRequest:
    """One tenant's CV problem: folds, a λ grid, and a precision preset
    (``None`` = the server's default policy).

    ``mode`` selects how the λ axis is spent: ``'grid'`` (default)
    evaluates the dense grid through the stacked ``run_batch`` dispatch;
    ``'search'`` runs the adaptive λ-refinement
    (:meth:`~repro.core.engine.CVEngine.search`) over the grid's range —
    far fewer solves to the same λ*, still through the shared factor
    cache (search requests admit into their own groups: the two modes
    never fuse)."""

    tenant: str
    folds: FoldData
    lams: jax.Array
    precision: Optional[str] = None
    mode: str = "grid"            # 'grid' | 'search'
    request_id: int = -1          # assigned by the server at submit()
    submitted_at: float = 0.0     # perf_counter timestamp, set at submit()


@dataclasses.dataclass
class SweepResponse:
    """The served result plus its service metadata.

    ``latency_s`` is queue latency: submit() → the dispatch that served
    the request completing.  ``status`` is the cache disposition the
    engine reported ('hit' | 'refit' | 'miss').
    """

    tenant: str
    request_id: int
    result: CVResult
    latency_s: float
    batch_size: int
    status: str


@dataclasses.dataclass(frozen=True)
class ServerConfig:
    """Admission/batching knobs.

    max_batch:   requests fused into one ``run_batch`` dispatch.
    reuse:       cache policy for every pooled engine ('covering' lets a
                 superset-anchor entry serve a subset request).
    cache_bytes: byte budget of the ONE shared cache (None = unbounded).
    cache_anchors: also cache packed anchor factors, enabling the
                 zero-factorization refit path across tenants.
    lam_chunk:   λ-chunk policy forwarded to the engines.
    tune:        ``tune=`` forwarded to every pooled engine (``'auto'``
                 turns on roofline-guided autotuning).  All engines share
                 ONE :class:`~repro.distributed.autotune.TuningCache`, and
                 the tuning key is content-addressed over the problem
                 geometry — so each admission-group geometry is tuned
                 exactly once per server, however many tenants share it.
    tune_lattice: lattice overrides forwarded to the engines (benches and
                 tests shrink the candidate search with this).
    search_tol:  interval tolerance (log₁₀ decades) for ``mode='search'``
                 requests (forwarded as ``tol_decades``).
    search_wave: λ points per refinement wave for ``mode='search'``
                 requests (``None`` = the engine's chunk-derived default).
    """

    max_batch: int = 8
    reuse: str = "covering"
    cache_bytes: Optional[int] = None
    cache_anchors: bool = True
    lam_chunk: object = "auto"
    tune: object = False
    tune_lattice: Optional[dict] = None
    search_tol: float = 0.05
    search_wave: Optional[int] = None


class CVSweepServer:
    """Multi-tenant sweep server: one strategy + backend, an engine pool
    keyed by precision preset, one shared factor cache."""

    def __init__(self, strategy: Optional[CVStrategy] = None,
                 backend: object = "reference", *,
                 config: Optional[ServerConfig] = None,
                 precision: Optional[str] = None):
        self.config = config or ServerConfig()
        self.strategy = strategy or PiCholeskyStrategy()
        self._backend = backend
        self._default_precision = resolve_precision(precision).name
        self.cache = cachelib.FactorCache(max_bytes=self.config.cache_bytes)
        # one tuning cache per server: content-addressed over geometry, so
        # each admission-group geometry is tuned once and every pooled
        # engine (and every tenant) reuses the verdict
        from repro.distributed import autotune
        self.tune_cache = autotune.TuningCache()
        self._engines: Dict[str, CVEngine] = {}
        # admission key -> FIFO of pending requests
        self._queues: Dict[tuple, Deque[SweepRequest]] = \
            collections.OrderedDict()
        self._responses: Dict[str, List[SweepResponse]] = {}
        self._next_id = 0
        self.served = 0
        self.dispatches = 0

    # -- engine pool ------------------------------------------------------

    def engine(self, precision: Optional[str] = None) -> CVEngine:
        """The pooled engine for a precision preset (compilations and the
        shared cache amortize across requests)."""
        name = (resolve_precision(precision).name if precision is not None
                else self._default_precision)
        if name not in self._engines:
            self._engines[name] = CVEngine(
                strategy=self.strategy, backend=self._backend,
                precision=name, cache=self.cache,
                reuse=self.config.reuse,
                cache_anchors=self.config.cache_anchors,
                lam_chunk=self.config.lam_chunk,
                tune=self.config.tune, tune_cache=self.tune_cache,
                tune_lattice=self.config.tune_lattice)
        return self._engines[name]

    # -- admission --------------------------------------------------------

    def _admission_key(self, req: SweepRequest) -> tuple:
        """Geometry fingerprint two requests must share to ride one
        stacked dispatch: mode + fold shapes + dtypes + anchor set +
        precision.  An unkeyable strategy (no cache meta) gets a
        singleton group.

        Admission must not mutate server state: the precision preset is
        validated through ``resolve_precision`` directly — the old code
        instantiated a pooled engine just to read its policy name, so a
        *rejected* precision string still left an engine in the pool.
        The λ-grid dtype is part of the key (it shapes the chunk-stage
        jit signature, so fusing float32 and float64 grids would recompile
        per request)."""
        prec = (resolve_precision(req.precision).name
                if req.precision is not None else self._default_precision)
        if req.mode not in ("grid", "search"):
            raise ValueError(f"mode must be 'grid' or 'search', "
                             f"got {req.mode!r}")
        meta = (self.strategy.cache_meta(req.lams)
                if hasattr(self.strategy, "cache_meta") else None)
        if meta is None:
            return ("solo", req.request_id)
        f = req.folds
        return (req.mode, tuple(f.fold_hess.shape), tuple(f.x_folds.shape),
                str(f.fold_hess.dtype),
                str(np.asarray(req.lams).dtype),
                tuple(np.asarray(meta["anchors"]).tolist()),
                prec, meta.get("sketch", "exact"))

    def submit(self, req: SweepRequest) -> int:
        """Enqueue a request; returns its assigned request id.  Raises
        (and enqueues nothing, touching no pool state) on an invalid
        precision preset or mode."""
        key = self._admission_key(req)     # validates before any mutation
        req.request_id = self._next_id
        self._next_id += 1
        req.submitted_at = time.perf_counter()
        if key[0] == "solo":
            key = ("solo", req.request_id)
        self._queues.setdefault(key, collections.deque()).append(req)
        return req.request_id

    @property
    def pending(self) -> int:
        return sum(len(q) for q in self._queues.values())

    # -- service ----------------------------------------------------------

    def step(self) -> List[SweepResponse]:
        """Serve one batch: pick the admission group whose head request is
        oldest, dispatch up to ``max_batch`` of it through ``run_batch``,
        and record per-tenant responses.  Returns the responses served
        (empty when idle)."""
        if not self._queues:
            return []
        key = min(self._queues, key=lambda k: self._queues[k][0].request_id)
        queue = self._queues[key]
        batch = [queue.popleft()
                 for _ in range(min(self.config.max_batch, len(queue)))]
        if not queue:
            del self._queues[key]

        eng = self.engine(batch[0].precision)
        if batch[0].mode == "search":
            # adaptive λ-refinement: per-request waves (each request's
            # bracket trajectory is its own), still through the shared
            # cache — request 1's anchor factorizations serve request 2's
            # state stage as a hit/refit exactly like grid mode
            results = []
            for r in batch:
                with eng._cache_scope(r.tenant):
                    results.append(eng.search(
                        r.folds, r.lams, wave=self.config.search_wave,
                        tol_decades=self.config.search_tol))
        else:
            results = eng.run_batch([(r.folds, r.lams) for r in batch],
                                    tenants=[r.tenant for r in batch])
        done = time.perf_counter()
        out = []
        for req, res in zip(batch, results):
            info = res.extras.get("engine", {}).get("cache") or {}
            resp = SweepResponse(
                tenant=req.tenant, request_id=req.request_id, result=res,
                latency_s=done - req.submitted_at, batch_size=len(batch),
                status=info.get("status", "bypass"))
            self._responses.setdefault(req.tenant, []).append(resp)
            out.append(resp)
        self.served += len(batch)
        self.dispatches += 1
        return out

    def drain(self) -> List[SweepResponse]:
        """Serve until the queues are empty; returns everything served."""
        out: List[SweepResponse] = []
        while self._queues:
            out.extend(self.step())
        return out

    # -- per-tenant isolation ---------------------------------------------

    def take_responses(self, tenant: str) -> List[SweepResponse]:
        """Pop (and return) the responses belonging to ``tenant`` — and
        only those; one tenant can never observe another's results."""
        return self._responses.pop(tenant, [])

    @property
    def stats(self) -> dict:
        """Serving counters + the shared cache's cumulative stats (with
        its per-tenant partitioning)."""
        return dict(served=self.served, dispatches=self.dispatches,
                    pending=self.pending,
                    batch_mean=(self.served / self.dispatches
                                if self.dispatches else 0.0),
                    engines=sorted(self._engines),
                    cache=self.cache.stats,
                    tuning=self.tune_cache.stats,
                    tenants={t: dict(rec)
                             for t, rec in self.cache.tenant_stats.items()})
