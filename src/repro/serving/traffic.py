"""Deterministic synthetic multi-tenant traffic.

A seeded Zipf mix over a small population of distinct ridge problems:
request r draws problem p with probability ∝ 1/rank(p)^a — a few hot
Hessians dominate (they are the cache's amortization opportunity) with a
long cold tail — then draws a λ grid from a palette of sizes over the
*same* decades (identical anchors → cross-tenant sharing) plus an
optional shifted range (different anchors → admission into a separate
group).  Tenants round-robin over the request stream, so hot problems
are shared across tenants by construction.

Everything is a pure function of :class:`TrafficConfig` — the committed
``BENCH_serving.json`` record and the serving tests replay the exact
same stream.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np

from repro.testing import strategies as props

from .server import SweepRequest

__all__ = ["TrafficConfig", "make_traffic"]


@dataclasses.dataclass(frozen=True)
class TrafficConfig:
    """Knobs of the synthetic workload (all defaults CPU-sized).

    n_problems distinct fold datasets are ranked by popularity; Zipf
    exponent ``zipf_a`` sets how hot the head is (higher = hotter).
    ``grid_sizes`` λ grids span the canonical test decades so they share
    anchors; a ``shifted_grid_every``-th request instead sweeps a shifted
    range (distinct anchors — exercises multi-group admission).
    """

    n_requests: int = 48
    n_tenants: int = 6
    n_problems: int = 8
    h: int = 32
    n: int = 256
    k: int = 4
    zipf_a: float = 1.2
    seed: int = 0
    dtype: str = "float64"
    grid_sizes: Tuple[int, ...] = (17, 25, 33)
    shifted_grid_every: int = 0      # 0 disables the shifted-range grids
    precision: Optional[str] = None


def zipf_weights(n: int, a: float) -> np.ndarray:
    """Normalized rank-popularity weights w_r ∝ 1/r^a, r = 1..n."""
    w = 1.0 / np.arange(1, n + 1, dtype=np.float64) ** a
    return w / w.sum()


def make_traffic(cfg: TrafficConfig) -> List[SweepRequest]:
    """The request stream for ``cfg`` — deterministic in ``cfg.seed``."""
    import jax.numpy as jnp

    rng = np.random.default_rng(cfg.seed)
    dtype = jnp.dtype(cfg.dtype)
    problems = [props.regression_folds(h=cfg.h, n=cfg.n, k=cfg.k,
                                       seed=1000 * (cfg.seed + 1) + p,
                                       dtype=dtype)
                for p in range(cfg.n_problems)]
    grids = [props.log_grid(q) for q in cfg.grid_sizes]
    lo, hi = props.DEFAULT_GRID_RANGE
    shifted = props.log_grid(cfg.grid_sizes[0], lo + 1.0, hi + 1.0)

    picks = rng.choice(cfg.n_problems, size=cfg.n_requests,
                       p=zipf_weights(cfg.n_problems, cfg.zipf_a))
    grid_picks = rng.integers(0, len(grids), size=cfg.n_requests)
    reqs = []
    for r in range(cfg.n_requests):
        lams = (shifted if cfg.shifted_grid_every
                and (r + 1) % cfg.shifted_grid_every == 0
                else grids[int(grid_picks[r])])
        reqs.append(SweepRequest(
            tenant=f"tenant-{r % cfg.n_tenants}",
            folds=problems[int(picks[r])], lams=lams,
            precision=cfg.precision))
    return reqs
