"""Test-support utilities: the deterministic hypothesis fallback shim
(:mod:`repro.testing.hypothesis_fallback`) and the shared property-test
generators (:mod:`repro.testing.strategies`)."""
