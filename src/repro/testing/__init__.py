"""Test-support utilities (hypothesis fallback, shared helpers)."""
