"""Minimal drop-in for ``hypothesis`` when the real package is absent.

The tier-1 suite property-tests with hypothesis (declared in
``requirements-dev.txt``), but hermetic containers may not have it
installed and cannot ``pip install``.  ``tests/conftest.py`` calls
:func:`install` in that case, which registers this module under
``sys.modules['hypothesis']`` so the test files import unchanged.

Scope: deterministic example generation for the strategy subset the suite
uses (``integers``, ``sampled_from``, ``floats``, ``booleans``, ``just``).
Examples are seeded from the test name, boundary values run first, and a
failing example is reported in the assertion chain.  No shrinking, no
database, no health checks — when the real hypothesis is installed it
always wins (``install`` is only reached on ImportError).
"""
from __future__ import annotations

import enum
import random
import sys
import types
import zlib
from typing import Any, Callable, Sequence

__all__ = ["install", "given", "settings", "assume", "strategies",
           "HealthCheck", "Verbosity"]


class UnsatisfiedAssumption(Exception):
    pass


def assume(condition: Any) -> bool:
    if not condition:
        raise UnsatisfiedAssumption()
    return True


class SearchStrategy:
    """A strategy = boundary examples + a random sampler."""

    def __init__(self, sample: Callable[[random.Random], Any],
                 boundaries: Sequence[Any] = ()):
        self._sample = sample
        self._boundaries = list(boundaries)

    def boundaries(self):
        return list(self._boundaries)

    def example(self, rng: random.Random):
        return self._sample(rng)

    def map(self, fn):
        return SearchStrategy(lambda rng: fn(self._sample(rng)),
                              [fn(b) for b in self._boundaries])


def integers(min_value: int, max_value: int) -> SearchStrategy:
    return SearchStrategy(lambda rng: rng.randint(min_value, max_value),
                          [min_value, max_value])


def sampled_from(elements) -> SearchStrategy:
    elements = list(elements)
    return SearchStrategy(lambda rng: rng.choice(elements), elements[:2])


def floats(min_value: float, max_value: float) -> SearchStrategy:
    return SearchStrategy(lambda rng: rng.uniform(min_value, max_value),
                          [min_value, max_value])


def booleans() -> SearchStrategy:
    return SearchStrategy(lambda rng: rng.random() < 0.5, [False, True])


def just(value) -> SearchStrategy:
    return SearchStrategy(lambda rng: value, [value])


class HealthCheck(enum.Enum):
    data_too_large = 1
    filter_too_much = 2
    too_slow = 3
    function_scoped_fixture = 4

    @classmethod
    def all(cls):
        return list(cls)


class Verbosity(enum.IntEnum):
    quiet = 0
    normal = 1
    verbose = 2
    debug = 3


_DEFAULT_MAX_EXAMPLES = 20


def settings(max_examples: int = _DEFAULT_MAX_EXAMPLES, deadline=None,
             **_ignored):
    """Decorator recording run parameters on the test function.

    Works in either decorator order relative to ``@given``: it simply tags
    whatever callable it receives; the ``@given`` runner reads the tag at
    call time.
    """

    def tag(fn):
        fn._fallback_settings = {"max_examples": max_examples}
        return fn

    return tag


def given(**strats: SearchStrategy):
    """Deterministic example-driving decorator.

    Runs the cartesian boundary examples first, then random draws seeded
    from the test name, for ``max_examples`` total iterations.  Examples
    rejected via :func:`assume` don't count toward the total.
    """

    def decorate(fn):
        def runner():
            cfg = (getattr(runner, "_fallback_settings", None)
                   or getattr(fn, "_fallback_settings", None)
                   or {"max_examples": _DEFAULT_MAX_EXAMPLES})
            max_examples = cfg["max_examples"]
            rng = random.Random(zlib.crc32(fn.__qualname__.encode()))
            names = sorted(strats)
            queue = []
            width = max((len(strats[n].boundaries()) for n in names),
                        default=0)
            for i in range(width):
                queue.append({n: strats[n].boundaries()[
                    i % max(len(strats[n].boundaries()), 1)]
                    for n in names if strats[n].boundaries()})
            ran = 0
            while ran < max_examples:
                example = (queue.pop(0) if queue else
                           {n: strats[n].example(rng) for n in names})
                try:
                    fn(**example)
                except UnsatisfiedAssumption:
                    continue
                except Exception as err:
                    raise AssertionError(
                        f"falsifying example ({fn.__name__}): {example!r}"
                    ) from err
                ran += 1

        runner.__name__ = fn.__name__
        runner.__qualname__ = fn.__qualname__
        runner.__doc__ = fn.__doc__
        runner.__module__ = fn.__module__
        runner.hypothesis = types.SimpleNamespace(inner_test=fn)
        if hasattr(fn, "_fallback_settings"):
            runner._fallback_settings = fn._fallback_settings
        return runner

    return decorate


def install() -> None:
    """Register this module as ``hypothesis`` + ``hypothesis.strategies``."""
    if "hypothesis" in sys.modules:   # real package (or already installed)
        return
    hyp = types.ModuleType("hypothesis")
    hyp.given = given
    hyp.settings = settings
    hyp.assume = assume
    hyp.HealthCheck = HealthCheck
    hyp.Verbosity = Verbosity
    hyp.__is_repro_fallback__ = True

    st = types.ModuleType("hypothesis.strategies")
    st.integers = integers
    st.sampled_from = sampled_from
    st.floats = floats
    st.booleans = booleans
    st.just = just
    st.SearchStrategy = SearchStrategy

    hyp.strategies = st
    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = st


strategies = types.SimpleNamespace(
    integers=integers, sampled_from=sampled_from, floats=floats,
    booleans=booleans, just=just, SearchStrategy=SearchStrategy)
