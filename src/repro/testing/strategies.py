"""Shared property-test generators for the CV/factor-pipeline suites.

One definition of the SPD-Hessian / fold-problem / λ-grid / backend
generators that used to be copy-pasted across ``tests/test_factor_cache.py``,
``tests/test_packed_pipeline.py`` and ``tests/test_engine.py``.  Two layers:

* plain **builders** (:func:`spd_matrix`, :func:`regression_folds`,
  :func:`make_backend`, :func:`log_grid`) — deterministic constructors any
  test can call directly, hypothesis or not;
* **strategies** (:func:`backend_names`, :func:`grid_sizes`,
  :func:`lam_chunks`, :func:`packed_shapes`, …) — ``@given``-able wrappers
  that deliberately cover the awkward corners: grid sizes that are not a
  multiple of the λ chunk (``q % chunk != 0``), grids smaller than the
  anchor count (``q < g``), chunk sizes larger than the grid, and matrix
  sizes that are not a tile multiple (including ``h < block``).

Works with both real hypothesis and the deterministic in-repo fallback
(:mod:`repro.testing.hypothesis_fallback`): only the shared strategy
surface is used (``integers`` / ``sampled_from`` / ``floats`` /
``booleans`` / ``just`` / ``.map``).

Precision matrix: ``REPRO_TEST_PRECISION`` (a
:mod:`repro.core.precision` preset name) re-runs these suites under a
mixed-precision policy — the engines and :func:`make_backend` pick it up
through ``resolve_precision(None)``, and the cross-path numeric asserts
widen through :func:`parity_tol` / :func:`argmin_slack` (native runs keep
their original tight tolerances bit-for-bit).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

try:  # pragma: no cover - exercised implicitly by both environments
    from hypothesis import strategies as st
except ImportError:  # hermetic container: install the fallback shim
    from . import hypothesis_fallback

    hypothesis_fallback.install()
    from hypothesis import strategies as st

__all__ = [
    "spd_matrix", "unit_spd_matrix", "regression_folds",
    "tall_skinny_folds", "low_rank_folds", "make_backend", "log_grid",
    "backend_names", "grid_sizes", "lam_chunks", "heights", "blocks",
    "packed_shapes", "tall_skinny_design", "low_rank_design",
    "sketch_plans", "DEFAULT_GRID_RANGE", "PACKED_SHAPES",
    "TALL_SKINNY_DESIGNS", "LOW_RANK_DESIGNS", "SKETCH_PLAN_CONFIGS",
    "active_precision", "parity_tol", "argmin_slack",
]

#: (h, block) pairs where h is NOT a tile multiple, incl. h < block — the
#: escape-hatch oracle cases (also available as the :func:`packed_shapes`
#: strategy; the list form feeds ``pytest.mark.parametrize``).
PACKED_SHAPES = [(5, 8), (13, 8), (21, 8), (37, 8), (27, 16), (61, 16)]

#: (log10 lo, log10 hi) of the canonical test λ grid — the same decades the
#: suites' fixed ``LAMS = logspace(-3, 2, 31)`` grid spans, so grids drawn
#: from :func:`grid_sizes` derive the same anchors and can hit the cache.
DEFAULT_GRID_RANGE = (-3.0, 2.0)

#: n ≫ h fold-problem geometries for the sketched-anchor suites.  Per-fold
#: training rows n_tr = n·(k−1)/k bound the sketch size a CountSketch IHS
#: loop needs to contract (m ≳ 4·n_tr empirically — below that the sketched
#: preconditioner's iteration matrix can have spectral radius > 1).
TALL_SKINNY_DESIGNS = [
    dict(h=16, n=128, k=4, seed=0),
    dict(h=24, n=160, k=4, seed=2),
    dict(h=24, n=192, k=3, seed=5),
    dict(h=32, n=256, k=4, seed=1),
]

#: n ≪ h geometries (with a planted numerical rank) for the low-rank ACV
#: suites — the regime where one SVD of the (n, h) design beats g Cholesky
#: factorizations of the (h, h) Hessian.
LOW_RANK_DESIGNS = [
    dict(h=64, n=24, k=4, rank=6, seed=0),
    dict(h=96, n=32, k=4, rank=8, seed=3),
    dict(h=80, n=30, k=3, rank=10, seed=7),
]

#: SketchPlan configurations spanning every method, adequate sketch sizes
#: for the TALL_SKINNY_DESIGNS row counts (CountSketch needs the larger m),
#: and both zero and nonzero IHS refinement.
SKETCH_PLAN_CONFIGS = [
    dict(method="gaussian", m=256, seed=0, ihs_iters=2),
    dict(method="gaussian", m=384, seed=3, ihs_iters=1),
    dict(method="srht", m=256, seed=1, ihs_iters=2),
    dict(method="srht", m=384, seed=4, ihs_iters=0),
    dict(method="countsketch", m=512, seed=2, ihs_iters=2),
    dict(method="countsketch", m=1024, seed=5, ihs_iters=3),
]


# ---------------------------------------------------------------- builders


def spd_matrix(h: int, seed: int = 0, dtype=jnp.float64) -> jax.Array:
    """Well-conditioned (h, h) SPD test Hessian: XᵀX + h·I."""
    x = jax.random.normal(jax.random.PRNGKey(seed), (2 * h, h), dtype)
    return x.T @ x + h * jnp.eye(h, dtype=dtype)


def unit_spd_matrix(d: int, seed: int = 0) -> jax.Array:
    """Unit-scale (d, d) SPD test matrix: XᵀX/rows + I — eigenvalues O(1),
    the conditioning regime of the exact-Fréchet bound suites (which need
    ‖A‖ ≈ 1 so the Thm 4.4/4.7 interval arithmetic stays in range).
    NumPy RandomState draw, bit-identical to the historical in-test
    generator the bound suites used."""
    import numpy as np

    x = np.random.RandomState(seed).randn(3 * d, d)
    return jnp.asarray(x.T @ x / 3.0 + np.eye(d))


def regression_folds(h: int = 32, n: int = 256, k: int = 4, seed: int = 1,
                     dtype=jnp.float64, jitter: float = 0.0,
                     noise: float = 1.0):
    """k-fold :class:`~repro.core.folds.FoldData` over a synthetic ridge
    problem — the shared fold-problem builder (``jitter`` perturbs the
    design, for invalidation tests that need a *different* Hessian;
    ``noise`` scales the label noise — the sketched-anchor suites raise it
    so the hold-out curve sits in the noise-dominated regime where
    approximate anchors select equivalently)."""
    from repro.core.folds import make_folds
    from repro.data import make_regression_dataset

    x, y = make_regression_dataset(jax.random.PRNGKey(seed), n, h,
                                   noise=noise, dtype=jnp.float64)
    if jitter:
        x = x + jitter * jax.random.normal(jax.random.PRNGKey(99), x.shape,
                                           jnp.float64)
    return make_folds(x.astype(dtype), y.astype(dtype), k)


def tall_skinny_folds(h: int = 24, n: int = 160, k: int = 4, seed: int = 2,
                      dtype=jnp.float64, noise: float = 8.0):
    """n ≫ h fold problem — the sketched-anchor regime (sketching the
    (n_tr, h) design rows pays off only when n_tr dominates h).  Default
    noise puts the hold-out curve in the noise-dominated regime."""
    if n <= 2 * h:
        raise ValueError(f"tall-skinny wants n >> h, got n={n}, h={h}")
    return regression_folds(h=h, n=n, k=k, seed=seed, dtype=dtype,
                            noise=noise)


def low_rank_folds(h: int = 96, n: int = 32, k: int = 4, rank: int = 8,
                   seed: int = 3, dtype=jnp.float64):
    """n ≪ h fold problem over a planted (numerically) rank-r design —
    the low-rank ACV regime (one SVD of the (n_tr, h) design replaces g
    Cholesky factorizations of the (h, h) Hessian)."""
    from repro.core.folds import make_folds
    from repro.data import make_low_rank_dataset

    x, y = make_low_rank_dataset(jax.random.PRNGKey(seed), n, h, rank,
                                 dtype=jnp.float64)
    return make_folds(x.astype(dtype), y.astype(dtype), k)


def make_backend(name: str, block: int = 8):
    """Backend under test: ``'reference'`` or ``'pallas'`` (interpret mode
    off-TPU) with proportionate kernel tiles for small test problems.
    Carries the active precision policy (``REPRO_TEST_PRECISION``)."""
    from repro.core.backends import PallasBackend, ReferenceBackend

    pol = active_precision()
    return (ReferenceBackend(precision=pol) if name == "reference"
            else PallasBackend(chol_block=block, trsm_block=block,
                               precision=pol))


# ------------------------------------------------------- precision matrix


def active_precision():
    """The policy the suite is running under — ``native`` unless the
    ``REPRO_TEST_PRECISION`` dtype-matrix hook says otherwise."""
    from repro.core.precision import resolve_precision

    return resolve_precision(None)


def parity_tol(rtol: float = 1e-9, atol: float = 1e-12) -> dict:
    """Tolerances for asserts that compare *independently computed* paths
    (split vs fused jit, packed vs dense oracle, warm vs fresh cold).

    Native runs keep the call site's original tight tolerances; under the
    dtype matrix they widen to the active policy's rounding scale —
    refinement narrows solve error but not the last-ulp fusion freedom.
    """
    pol = active_precision()
    if pol.store == "bfloat16" or pol.compute == "bfloat16":
        return dict(rtol=5e-2, atol=1e-2)
    if pol.store == "float32" or pol.compute == "float32":
        return dict(rtol=3e-4, atol=1e-5)
    return dict(rtol=rtol, atol=atol)


def argmin_slack() -> int:
    """Grid steps two independently computed hold-out curves may disagree
    on the argmin: 0 under native (bit-level ties break identically), 1
    under a reduced-precision policy (near-ties can flip)."""
    return 0 if active_precision().is_native else 1


def assert_selection_close(errors_a, errors_b):
    """Two independently computed hold-out curves select equivalent λ.

    Native: the argmin index must match exactly (bit-level ties break
    identically).  Under a reduced-precision policy the curve can plateau
    at the rounding scale — the argmin index may wander arbitrarily far
    along the plateau — so the plateau-safe contract is *selection
    quality*: each curve's chosen index must be within policy rounding of
    the other curve's minimum.
    """
    import numpy as np

    a, b = np.asarray(errors_a), np.asarray(errors_b)
    ia, ib = int(np.argmin(a)), int(np.argmin(b))
    if active_precision().is_native:
        assert ia == ib, (ia, ib)
        return
    tol = parity_tol()
    for curve, pick in ((a, ib), (b, ia)):
        lo = float(curve.min())
        assert curve[pick] <= lo + tol["atol"] + tol["rtol"] * abs(lo), \
            (ia, ib, float(curve[pick]), lo)


def log_grid(q: int, lo: float = DEFAULT_GRID_RANGE[0],
             hi: float = DEFAULT_GRID_RANGE[1]) -> jax.Array:
    """q-point log-spaced λ grid over the canonical test decades."""
    return jnp.logspace(lo, hi, q)


# -------------------------------------------------------------- strategies


def backend_names():
    """Both linalg backends — every parity property runs on each."""
    return st.sampled_from(["reference", "pallas"])


def grid_sizes(lo: int = 2, hi: int = 64):
    """Dense-grid sizes q: the default floor of 2 keeps ``q < g`` (fewer
    grid points than anchors) in play, the ceiling crosses every chunk
    boundary in :func:`lam_chunks`."""
    return st.integers(lo, hi)


def lam_chunks():
    """λ-chunk settings: unchunked (None), degenerate (1), sizes that do
    not divide typical grids (5, 7), and chunk > q (64)."""
    return st.sampled_from([None, 1, 5, 7, 16, 64])


def heights(lo: int = 4, hi: int = 48):
    """Matrix sizes h, deliberately spanning non-tile-multiples."""
    return st.integers(lo, hi)


def blocks():
    """Packed-layout tile sizes."""
    return st.sampled_from([4, 8, 16])


def packed_shapes():
    """(h, block) pairs where h is NOT a tile multiple, incl. h < block —
    the escape-hatch oracle cases."""
    return st.sampled_from(PACKED_SHAPES)


def tall_skinny_design():
    """Geometry dicts (h, n, k, seed) for the n ≫ h sketched-anchor
    regime — feed to :func:`tall_skinny_folds` via ``**cfg``."""
    return st.sampled_from(TALL_SKINNY_DESIGNS)


def low_rank_design():
    """Geometry dicts (h, n, k, rank, seed) for the n ≪ h low-rank ACV
    regime — feed to :func:`low_rank_folds` via ``**cfg``."""
    return st.sampled_from(LOW_RANK_DESIGNS)


def sketch_plans(methods=None):
    """:class:`~repro.core.sketch.SketchPlan` draws covering every sketch
    method at sizes adequate for the :data:`TALL_SKINNY_DESIGNS` row
    counts (built from ``sampled_from`` + ``.map`` only, so the in-repo
    hypothesis fallback enumerates them too).  ``methods`` restricts to a
    subset of :data:`~repro.core.sketch.SKETCH_METHODS`."""
    cfgs = (SKETCH_PLAN_CONFIGS if methods is None else
            [c for c in SKETCH_PLAN_CONFIGS if c["method"] in methods])
    if not cfgs:
        raise ValueError(f"no sketch-plan configs for methods={methods!r}")

    def _build(cfg):
        from repro.core.sketch import SketchPlan

        return SketchPlan(**cfg)

    return st.sampled_from(cfgs).map(_build)
