from .steps import make_train_step, make_serve_steps  # noqa: F401
from .loop import TrainLoop, TrainLoopConfig  # noqa: F401
