"""Training loop with checkpoint/restart, straggler detection and
prefetching — the piece that makes the framework restartable at scale.

Fault-tolerance contract:
* every ``ckpt_every`` steps an **async atomic** checkpoint of
  (params, opt_state, step) is written;
* on construction the loop auto-resumes from the newest valid checkpoint
  (corrupt/torn checkpoints are skipped — see CheckpointManager);
* a crashed/preempted job rerun with the same arguments continues.

Straggler mitigation (host-side):
* per-step wall time EWMA + deviation tracking; steps slower than
  ``straggler_factor ×`` EWMA are counted and surfaced in metrics so the
  orchestration layer can drain/replace the slow host;
* the data iterator is wrapped in a background prefetch thread
  (depth ``prefetch``) so input stalls never serialize with compute.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Any, Callable, Dict, Iterator, Optional

import jax

from repro.checkpoint import CheckpointManager

__all__ = ["TrainLoop", "TrainLoopConfig"]


@dataclasses.dataclass
class TrainLoopConfig:
    total_steps: int
    ckpt_every: int = 100
    ckpt_dir: Optional[str] = None
    log_every: int = 10
    straggler_factor: float = 2.0
    ewma_alpha: float = 0.1
    prefetch: int = 2


class _Prefetcher:
    def __init__(self, it: Iterator, depth: int):
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = False

        def work():
            for item in it:
                if self._stop:
                    return
                self.q.put(item)
            self.q.put(None)

        self.t = threading.Thread(target=work, daemon=True)
        self.t.start()

    def __iter__(self):
        return self

    def __next__(self):
        item = self.q.get()
        if item is None:
            raise StopIteration
        return item

    def close(self):
        self._stop = True


class TrainLoop:
    def __init__(self, cfg: TrainLoopConfig, step_fn: Callable,
                 params: Any, opt_state: Any,
                 shardings: Any = None):
        self.cfg = cfg
        self.step_fn = step_fn
        self.params = params
        self.opt_state = opt_state
        self.start_step = 0
        self.ckpt = (CheckpointManager(cfg.ckpt_dir)
                     if cfg.ckpt_dir else None)
        if self.ckpt is not None:
            step, state = self.ckpt.restore_latest(
                {"params": params, "opt": opt_state}, shardings)
            if step is not None:
                self.params = state["params"]
                self.opt_state = state["opt"]
                self.start_step = step
        self.metrics_log: list = []
        self.straggler_steps = 0
        self._ewma = None

    def run(self, data_it: Iterator, extra: Optional[Dict] = None) -> Dict:
        cfg = self.cfg
        pf = _Prefetcher(data_it, cfg.prefetch)
        step = self.start_step
        try:
            for batch in pf:
                if step >= cfg.total_steps:
                    break
                t0 = time.perf_counter()
                self.params, self.opt_state, metrics = self.step_fn(
                    self.params, self.opt_state, batch, extra)
                jax.block_until_ready(metrics["loss"])
                dt = time.perf_counter() - t0
                if self._ewma is None:
                    self._ewma = dt
                elif dt > cfg.straggler_factor * self._ewma:
                    self.straggler_steps += 1   # surface to orchestrator
                    self._ewma = ((1 - cfg.ewma_alpha) * self._ewma
                                  + cfg.ewma_alpha * dt)
                else:
                    self._ewma = ((1 - cfg.ewma_alpha) * self._ewma
                                  + cfg.ewma_alpha * dt)
                step += 1
                if step % cfg.log_every == 0 or step == cfg.total_steps:
                    self.metrics_log.append(
                        {"step": step, "loss": float(metrics["loss"]),
                         "sec_per_step": dt})
                if self.ckpt is not None and step % cfg.ckpt_every == 0:
                    self.ckpt.save_async(
                        step, {"params": self.params, "opt": self.opt_state})
        finally:
            pf.close()
            if self.ckpt is not None:
                self.ckpt.wait()
        if self.ckpt is not None and step > self.start_step:
            self.ckpt.save(step, {"params": self.params, "opt": self.opt_state})
        return {"final_step": step, "log": self.metrics_log,
                "straggler_steps": self.straggler_steps,
                "ewma_sec_per_step": self._ewma}
