"""Step builders — the functions the launcher jits / the dry-run lowers.

``make_train_step``: loss → grads (with optional microbatch accumulation and
int8 error-feedback grad sync) → optimizer update.  Parameters and optimizer
state are donated.

``make_serve_steps``: (prefill, decode) pair for the inference cells.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed import compression
from repro.models.model import Model

__all__ = ["make_train_step", "make_serve_steps"]


def make_train_step(
    model: Model,
    optimizer: Tuple[Callable, Callable],
    *,
    microbatches: int = 1,
    compress_grads: bool = False,
) -> Callable:
    """Returns train_step(params, opt_state, batch, extra?) ->
    (params, opt_state, metrics).

    ``microbatches`` splits the global batch and accumulates grads with a
    lax.scan (gradient accumulation — the memory lever for the 1T config).
    ``compress_grads`` applies int8 error-feedback quantization to the
    gradient before the optimizer (EF state lives in metrics-free aux slot
    of opt_state via closure-free wrapper: see TrainLoop).
    """
    _, opt_update = optimizer

    def loss_fn(params, batch, extra):
        loss, metrics = model.loss(params, batch, extra)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(params, opt_state, batch: Dict, extra: Optional[Dict] = None):
        if microbatches == 1:
            (loss, metrics), grads = grad_fn(params, batch, extra)
        else:
            def split(x):
                return x.reshape(microbatches, x.shape[0] // microbatches,
                                 *x.shape[1:])

            mb = jax.tree.map(split, batch)
            mb_extra = jax.tree.map(split, extra) if extra else None

            def acc_body(carry, xs):
                g_acc, l_acc = carry
                b = xs[0] if mb_extra is not None else xs
                e = xs[1] if mb_extra is not None else None
                (l, _), g = grad_fn(params, b, e)
                g_acc = jax.tree.map(jnp.add, g_acc, g)
                return (g_acc, l_acc + l), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            xs = (mb, mb_extra) if mb_extra is not None else mb
            (grads, loss), _ = jax.lax.scan(acc_body, (g0, 0.0), xs)
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            loss = loss / microbatches
            metrics = {"nll": loss, "aux": jnp.zeros((), jnp.float32)}

        if compress_grads:
            residual = opt_state[1]
            grads, residual = compression.ef_compress_tree(grads, residual)
            inner, _ = opt_state
            params, inner = opt_update(grads, inner, params)
            new_opt = (inner, residual)
        else:
            params, new_opt = opt_update(grads, opt_state, params)
        metrics = dict(metrics, loss=loss,
                       grad_norm=jnp.sqrt(sum(
                           jnp.sum(jnp.square(g.astype(jnp.float32)))
                           for g in jax.tree.leaves(grads))))
        return params, new_opt, metrics

    return train_step


def make_serve_steps(model: Model):
    """(prefill_step, decode_step) for the inference dry-run cells."""

    def prefill_step(params, tokens, extra=None, cache_len=None):
        return model.prefill(params, tokens, extra, cache_len=cache_len)

    def decode_step(params, cache, tokens):
        return model.decode(params, cache, tokens)

    return prefill_step, decode_step
