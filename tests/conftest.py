import os

# Smoke tests and benches must see ONE device (the dry-run sets its own
# XLA_FLAGS before any jax import — never here).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402

jax.config.update("jax_enable_x64", True)
