import os
import sys

# Allow running plain `pytest` (CI sets PYTHONPATH=src; this covers the rest).
_SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                    "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)

# Smoke tests and benches run on CPU (the dry-run sets its own platform
# before any jax import — never here).  The host platform is split into 4
# virtual devices so the CVEngine mesh tests exercise real shard_map
# partitioning; single-device tests are unaffected (unsharded arrays live
# on device 0).
os.environ.setdefault("JAX_PLATFORMS", "cpu")
if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                               " --xla_force_host_platform_device_count=4").strip()

import jax  # noqa: E402

jax.config.update("jax_enable_x64", True)

# Property tests use hypothesis (requirements-dev.txt).  Hermetic containers
# without it fall back to the deterministic in-repo shim so the tier-1 suite
# still collects and runs.
try:
    import hypothesis  # noqa: F401
except ImportError:  # pragma: no cover - depends on environment
    from repro.testing import hypothesis_fallback

    hypothesis_fallback.install()
