"""Pipelined async λ-sweep: staged parity, early stopping, cache composition.

The tentpole contracts live here:

* **pipelined ≡ serial bit-for-bit** — ``sweep_async(pipelined=True)`` and
  ``pipelined=False`` run the *same* jitted stage functions in different
  dispatch orders, so their error curves must be identical to the last bit,
  on both backends, cold and warm-replay, chunked and unchunked;
* **early-stop correctness** — ``stop_tol=0`` terminates the stream only on
  strict non-improvement, so on the suite's (unimodal) hold-out curves the
  returned minimum is exactly the full curve's argmin;
* **cache composition** — a warm hit streams with zero factorizations, and
  an early-stopped cold sweep still populates a *complete* entry (the state
  stage finishes and writes before the λ stream starts).
"""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import engine, factor_cache
from repro.core.backends import CountingBackend, ReferenceBackend
from repro.distributed import sharding as shardlib
from repro.testing import strategies as props


@pytest.fixture(scope="module")
def folds():
    return props.regression_folds(h=32, n=256, k=4)


LAMS = props.log_grid(31)
#: grid whose hold-out minimum sits mid-grid (the (-3, 2) decades put it at
#: the edge for this problem) — the early-stop cases need a curve that
#: bottoms out with chunks left to skip
WIDE = props.log_grid(48, -3, 6)


def _strat(**kw):
    kw.setdefault("g", 4)
    kw.setdefault("block", 8)
    return engine.PiCholeskyStrategy(**kw)


def _chunk_curves(parts):
    return (np.concatenate([p.errors for p in parts]),
            np.concatenate([p.lams for p in parts]))


# --------------------------------------------- pipelined ≡ serial (bitwise)


@pytest.mark.tier2
@given(backend=props.backend_names(), q=props.grid_sizes(2, 48),
       chunk=props.lam_chunks(), warm=st.booleans())
@settings(max_examples=8, deadline=None)
def test_pipelined_equals_serial_bitwise(backend, q, chunk, warm):
    """Property: for any grid density (incl. q % chunk ≠ 0, q < g and
    chunk > q), on both backends, cold and warm-replay, the pipelined
    dispatch order reproduces the serial one bit-for-bit."""
    folds = props.regression_folds(h=24)
    bk = props.make_backend(backend)
    grid = props.log_grid(q)
    cache = warm_cache = None
    if warm:
        cache = factor_cache.FactorCache()
        engine.CVEngine(_strat(), backend=bk, cache=cache,
                        lam_chunk=chunk).run(folds, grid)   # populate
        warm_cache = cache
    pipe = engine.CVEngine(_strat(), backend=bk, cache=warm_cache,
                           lam_chunk=chunk)
    ser = engine.CVEngine(_strat(), backend=bk, cache=warm_cache,
                          lam_chunk=chunk)
    parts_p = list(pipe.sweep_async(folds, grid))
    parts_s = list(ser.sweep_async(folds, grid, pipelined=False))
    assert len(parts_p) == len(parts_s)
    for cp, cs in zip(parts_p, parts_s):
        np.testing.assert_array_equal(cp.fold_errors, cs.fold_errors)
        assert (cp.index, cp.start, cp.best_lam) == \
            (cs.index, cs.start, cs.best_lam)
    if warm:
        assert parts_p[-1].cache["status"] == "hit"
        assert parts_p[-1].n_exact_chol == 0


def test_pipelined_equals_serial_smoke(folds):
    """Tier-1 pin of the bitwise contract (one cold + one warm case)."""
    cache = factor_cache.FactorCache()
    for _ in range(2):   # pass 1 cold (populates), pass 2 warm (hits)
        r_pipe = engine.CVEngine(_strat(), cache=cache, lam_chunk=7
                                 ).run_async(folds, LAMS)
        r_ser = engine.CVEngine(_strat(), cache=cache, lam_chunk=7
                                ).run_async(folds, LAMS, pipelined=False)
        np.testing.assert_array_equal(r_pipe.errors, r_ser.errors)
        assert r_pipe.best_lam == r_ser.best_lam
    assert r_pipe.extras["engine"]["cache"]["status"] == "hit"


def test_run_async_matches_fused_run(folds):
    """The staged sweep computes the same curve as the one-jit fused sweep
    (different XLA fusion ⇒ tolerance, not bitwise)."""
    for chunk in (None, 7):
        r_async = engine.CVEngine(_strat(), lam_chunk=chunk
                                  ).run_async(folds, LAMS)
        r_fused = engine.CVEngine(_strat(), lam_chunk=chunk).run(folds, LAMS)
        np.testing.assert_allclose(r_async.errors, r_fused.errors,
                                   rtol=1e-9, atol=1e-12)
        assert r_async.best_lam == pytest.approx(r_fused.best_lam, rel=1e-9)
        assert r_async.n_exact_chol == r_fused.n_exact_chol


@pytest.mark.parametrize("name,params", [
    ("exact", {}),
    ("picholesky_warmstart", dict(block=8, g_rest=3)),
    ("svd", dict(mode="truncated", k_trunc=16)),
    ("pinrmse", {}),
])
def test_staged_sweep_is_strategy_agnostic(folds, name, params):
    """Every built-in strategy runs through the staged fold_state /
    fold_errors seam — pipelined ≡ serial bitwise, both ≈ the fused sweep."""
    mk = lambda: engine.make_strategy(name, **params)  # noqa: E731
    r_pipe = engine.CVEngine(mk(), lam_chunk=7).run_async(folds, LAMS)
    r_ser = engine.CVEngine(mk(), lam_chunk=7).run_async(folds, LAMS,
                                                         pipelined=False)
    np.testing.assert_array_equal(r_pipe.errors, r_ser.errors)
    r_fused = engine.CVEngine(mk(), lam_chunk=7).run(folds, LAMS)
    np.testing.assert_allclose(r_pipe.errors, r_fused.errors, rtol=1e-9)


def test_sweep_async_yields_incremental_chunks(folds):
    """The stream is genuinely incremental: chunk boundaries tile the grid,
    per-chunk curves concatenate to the full curve, and the running best
    is monotonically non-increasing."""
    parts = list(engine.CVEngine(_strat(), lam_chunk=7
                                 ).sweep_async(folds, LAMS))
    assert [p.index for p in parts] == list(range(5))   # ceil(31 / 7)
    assert [p.start for p in parts] == [0, 7, 14, 21, 28]
    assert [p.lams.size for p in parts] == [7, 7, 7, 7, 3]  # 31 % 7 == 3
    errors, lams = _chunk_curves(parts)
    np.testing.assert_array_equal(lams, np.asarray(LAMS))
    full = engine.CVEngine(_strat(), lam_chunk=7).run_async(folds, LAMS)
    np.testing.assert_array_equal(errors, full.errors)
    bests = [p.best_error for p in parts]
    assert all(b2 <= b1 for b1, b2 in zip(bests, bests[1:]))
    assert parts[-1].best_error == errors.min()


# ----------------------------------------------------- early-stop λ-search


@pytest.mark.tier2
@given(q=props.grid_sizes(8, 64), chunk=st.sampled_from([3, 4, 7, 16]),
       backend=props.backend_names())
@settings(max_examples=8, deadline=None)
def test_early_stop_tol0_returns_full_argmin(q, chunk, backend):
    """Property: stop_tol=0 stops only on strict non-improvement, so for
    any grid density / chunking / backend the early-stopped search returns
    exactly the argmin of the full curve."""
    folds = props.regression_folds(h=24)
    bk = props.make_backend(backend)
    grid = props.log_grid(q, -3, 6)
    full = engine.CVEngine(_strat(), backend=bk, lam_chunk=chunk
                           ).run_async(folds, grid)
    es = engine.CVEngine(_strat(), backend=bk, lam_chunk=chunk
                         ).run_async(folds, grid, stop_tol=0.0)
    assert es.best_lam == full.best_lam
    assert es.best_error == full.best_error
    # the evaluated prefix is bitwise the full curve's prefix
    np.testing.assert_array_equal(es.errors,
                                  full.errors[:es.errors.size])


def test_early_stop_skips_tail_chunks(folds):
    """On a curve that bottoms out mid-grid the stream stops early, the
    result records how far it ran, and (for the exact strategy) the skipped
    chunks are factorizations never performed."""
    es = engine.CVEngine(_strat(), lam_chunk=4).run_async(folds, WIDE,
                                                          stop_tol=0.0)
    info = es.extras["engine"]["async"]
    assert info["stopped"] and info["chunks_evaluated"] < info["chunks_total"]
    assert es.errors.size == info["lams_evaluated"] < WIDE.size
    full = engine.CVEngine(_strat(), lam_chunk=4).run_async(folds, WIDE)
    assert es.best_lam == full.best_lam

    r_exact = engine.CVEngine("exact", lam_chunk=4).run_async(
        folds, WIDE, stop_tol=0.0)
    assert r_exact.n_exact_chol < 4 * WIDE.size
    assert r_exact.n_exact_chol == \
        4 * r_exact.extras["engine"]["async"]["lams_evaluated"]


def test_early_stop_patience_and_tol_semantics(folds):
    """Higher patience streams at least as far; a huge stop_tol (nothing
    counts as improvement) stops after exactly `patience` chunks."""
    runs = {p: engine.CVEngine(_strat(), lam_chunk=4).run_async(
        folds, WIDE, stop_tol=0.0, stop_patience=p) for p in (1, 2, 4)}
    evaluated = {p: r.extras["engine"]["async"]["chunks_evaluated"]
                 for p, r in runs.items()}
    assert evaluated[1] <= evaluated[2] <= evaluated[4]

    greedy = engine.CVEngine(_strat(), lam_chunk=4).run_async(
        folds, WIDE, stop_tol=1e9, stop_patience=3)
    assert greedy.extras["engine"]["async"]["chunks_evaluated"] == 4  # 1 + 3


def test_early_stop_validation_and_degenerate_cases(folds):
    with pytest.raises(ValueError, match="stop_tol"):
        next(engine.CVEngine(_strat()).sweep_async(folds, LAMS,
                                                   stop_tol=-0.1))
    with pytest.raises(ValueError, match="stop_patience"):
        next(engine.CVEngine(_strat()).sweep_async(folds, LAMS, stop_tol=0.0,
                                                   stop_patience=0))
    # unchunked: a single chunk can never stop early
    r = engine.CVEngine(_strat(), lam_chunk=None).run_async(folds, LAMS,
                                                            stop_tol=0.0)
    info = r.extras["engine"]["async"]
    assert info["chunks_total"] == 1 and not info["stopped"]
    np.testing.assert_allclose(
        r.errors, engine.CVEngine(_strat()).run(folds, LAMS).errors,
        rtol=1e-9)


# ------------------------------------------------ non-finite hold-out means


def test_early_stop_refuses_nonfinite_chunk(folds):
    """Regression: a NaN hold-out mean (poisoned fold) used to feed the
    non-improvement streak silently — ``mean[i] < best`` is always False
    for NaN — so the search 'stopped' with ``best_lam=nan``.  It must
    refuse instead."""
    bad = folds._replace(y_folds=folds.y_folds.at[0, 0].set(jnp.nan))
    eng = engine.CVEngine(_strat(), lam_chunk=4)
    with pytest.raises(FloatingPointError, match="non-finite"):
        eng.run_async(bad, LAMS, stop_tol=0.0, stop_patience=2)
    # without early stopping the sweep still refuses to RANK the all-NaN
    # curve (regression: it used to return best_lam=nan silently), but
    # only after streaming the full grid — the generator yields every
    # chunk first, so a caller iterating sweep_async sees the whole curve
    parts = []
    with pytest.raises(FloatingPointError, match="no finite"):
        for p in engine.CVEngine(_strat(), lam_chunk=4).sweep_async(
                bad, LAMS):
            parts.append(p)
    assert sum(p.lams.size for p in parts) == LAMS.size
    assert not np.isfinite(np.concatenate([p.errors for p in parts])).any()
    with pytest.raises(FloatingPointError, match="no finite"):
        engine.CVEngine(_strat(), lam_chunk=4).run_async(bad, LAMS)


def test_singular_fold_raises_not_nan_selection(folds):
    """Satellite regression: a fold whose training Hessian is not PD at
    any grid λ (here: a hold-out block so heavy the training split goes
    indefinite, the production symptom of a singular/duplicated fold)
    poisons the fold mean at every λ.  run() and run_async() must raise —
    never yield ``best_lam=nan``."""
    sing = folds._replace(fold_hess=folds.fold_hess.at[0].mul(1e6))
    for run in (lambda e: e.run(sing, LAMS),
                lambda e: e.run_async(sing, LAMS),
                lambda e: e.run_async(sing, LAMS, stop_tol=0.0)):
        with pytest.raises(FloatingPointError):
            run(engine.CVEngine(_strat(), lam_chunk=4))


def test_partial_nonfinite_chunk_tracks_finite_argmin(folds):
    """A chunk that is only partially non-finite (e.g. overflow at large
    λ) must rank its finite entries — np.argmin would return the first
    NaN's index and poison the running ``best_lam``."""
    import dataclasses

    @dataclasses.dataclass(frozen=True, eq=False)
    class PoisonTail(engine.PiCholeskyStrategy):
        cutoff: float = 1e2

        def fold_errors(self, state, f_idx, h_tr_f, g_tr_f, x_f, y_f,
                        lams, aux, bk):
            errs = super().fold_errors(state, f_idx, h_tr_f, g_tr_f,
                                       x_f, y_f, lams, aux, bk)
            return jnp.where(lams > self.cutoff, jnp.nan, errs)

    strat = PoisonTail(g=4, block=8, cutoff=1e2)
    parts = list(engine.CVEngine(strat, lam_chunk=8).sweep_async(
        folds, WIDE))
    curve = np.concatenate([p.errors for p in parts])
    finite = np.isfinite(curve)
    assert finite.any() and not finite.all()    # the poison straddles
    expect = float(np.asarray(WIDE)[
        np.flatnonzero(finite)[np.argmin(curve[finite])]])
    assert parts[-1].best_lam == expect
    assert np.isfinite(parts[-1].best_error)
    # under stop_tol the poisoned chunk refuses, same as the all-NaN case
    with pytest.raises(FloatingPointError, match="non-finite"):
        engine.CVEngine(strat, lam_chunk=8).run_async(folds, WIDE,
                                                      stop_tol=0.0)


# ------------------------------------------------------- cache composition


def test_early_stopped_cold_sweep_populates_complete_entry(folds):
    """Partial-population contract: the cache entry is written when the
    state stage completes, before the λ stream — an early-stopped cold
    sweep leaves a complete Θ that a later FULL sweep replays with zero
    factorizations, matching an uncached full sweep."""
    cache = factor_cache.FactorCache()
    es = engine.CVEngine(_strat(), cache=cache, lam_chunk=4).run_async(
        folds, WIDE, stop_tol=0.0)
    assert es.extras["engine"]["async"]["stopped"]
    assert es.extras["engine"]["cache"]["status"] == "miss"
    assert len(cache) == 1

    bk = CountingBackend(ReferenceBackend())
    warm = engine.CVEngine(_strat(), backend=bk, cache=cache, lam_chunk=4)
    r_warm = warm.run_async(folds, WIDE)
    assert bk.n_cholesky == 0
    assert r_warm.extras["engine"]["cache"]["status"] == "hit"
    assert r_warm.errors.size == WIDE.size
    base = engine.CVEngine(_strat(), lam_chunk=4).run_async(folds, WIDE,
                                                            pipelined=False)
    np.testing.assert_allclose(r_warm.errors, base.errors,
                               rtol=1e-9, atol=1e-12)


def test_warm_async_replay_zero_factorizations(folds):
    """A run()-populated cache serves the async stream: zero cholesky
    traces, hit reported on every yielded chunk."""
    cache = factor_cache.FactorCache()
    engine.CVEngine(_strat(), cache=cache).run(folds, LAMS)
    bk = CountingBackend(ReferenceBackend())
    eng = engine.CVEngine(_strat(), backend=bk, cache=cache, lam_chunk=5)
    parts = list(eng.sweep_async(folds, LAMS))
    assert bk.n_cholesky == 0
    assert all(p.cache["status"] == "hit" for p in parts)
    assert all(p.n_exact_chol == 0 for p in parts)
    assert bk.stage_count("fold_errors", "interp_solve") > 0


def test_async_cache_bypass_for_uncacheable(folds):
    """exact (no cache_meta) and chol_fn overrides bypass, like run()."""
    cache = factor_cache.FactorCache()
    r = engine.CVEngine("exact", cache=cache).run_async(folds, LAMS)
    assert r.extras["engine"]["cache"]["status"] == "bypass"
    assert len(cache) == 0
    r2 = engine.CVEngine(_strat(chol_fn=jnp.linalg.cholesky), cache=cache
                         ).run_async(folds, LAMS)
    assert r2.extras["engine"]["cache"]["status"] == "bypass"
    assert len(cache) == 0


# ------------------------------------------------- stage-granular counting


def test_stage_counters_attribute_ops_to_stages(folds):
    """Cold piCholesky: factorizations trace under 'fold_state', only
    fused interpolant solves under 'fold_errors'.  Exact: factorizations
    trace under 'fold_errors' (that is where its work lives)."""
    bk = CountingBackend(ReferenceBackend())
    engine.CVEngine(_strat(), backend=bk, lam_chunk=7).run_async(folds, LAMS)
    assert bk.stage_count("fold_state", "cholesky") > 0
    assert bk.stage_count("fold_errors", "cholesky") == 0
    assert bk.stage_count("fold_errors", "interp_solve") > 0
    assert bk.n_cholesky == sum(rec.get("cholesky", 0)
                                for rec in bk.by_stage.values())

    bk2 = CountingBackend(ReferenceBackend())
    engine.CVEngine("exact", backend=bk2, lam_chunk=7).run_async(folds, LAMS)
    assert bk2.stage_count("fold_errors", "cholesky") > 0
    assert bk2.stage_count("fold_state", "cholesky") == 0
    bk2.reset()
    assert bk2.n_cholesky == 0 and bk2.by_stage == {}


def test_warmstart_prepare_counts_under_prepare_stage(folds):
    """picholesky_warmstart factorizes its anchor fit in prepare and its
    per-fold refresh in fold_state — both attributed."""
    bk = CountingBackend(ReferenceBackend())
    strat = engine.PiCholeskyWarmstart(block=8, g_rest=3)
    engine.CVEngine(strat, backend=bk, lam_chunk=7).run_async(folds, LAMS)
    assert bk.stage_count("prepare", "cholesky") > 0
    assert bk.stage_count("fold_state", "cholesky") > 0


# --------------------------------------------------------- mesh composition


@pytest.mark.tier2
def test_async_sweep_on_mesh_matches_unsharded(folds):
    """The staged sweep composes with the folds × lams mesh (conftest
    forces 4 host devices): chunk λs pad to the λ axis, state shards over
    folds, pipelined ≡ serial bitwise, and the curve matches unsharded."""
    pipe = engine.CVEngine(_strat(), mesh="auto", lam_chunk=3)
    r_pipe = pipe.run_async(folds, LAMS)
    assert r_pipe.extras["engine"]["mesh"] is not None
    r_ser = engine.CVEngine(_strat(), mesh="auto", lam_chunk=3).run_async(
        folds, LAMS, pipelined=False)
    np.testing.assert_array_equal(r_pipe.errors, r_ser.errors)
    base = engine.CVEngine(_strat()).run(folds, LAMS)
    np.testing.assert_allclose(r_pipe.errors, base.errors, rtol=1e-8)

    # 2×2 mesh: the λ chunk (3) pads to the λ-axis multiple (4) and the
    # padded tail is stripped before the chunk is yielded
    mesh22 = shardlib.make_cv_mesh(2)
    r22 = engine.CVEngine(_strat(), mesh=mesh22, lam_chunk=3
                          ).run_async(folds, LAMS)
    assert r22.errors.shape == (31,)
    np.testing.assert_allclose(r22.errors, base.errors, rtol=1e-8)

    # early stop under shard_map: same semantics, stops the global stream
    es = engine.CVEngine(_strat(), mesh="auto", lam_chunk=4).run_async(
        folds, WIDE, stop_tol=0.0)
    full = engine.CVEngine(_strat(), mesh="auto", lam_chunk=4).run_async(
        folds, WIDE)
    assert es.best_lam == full.best_lam
    assert es.extras["engine"]["async"]["stopped"]


def test_async_indivisible_fold_axis_raises():
    """Mesh misconfiguration fails with the engine's ValueError (same as
    run()), not a shard_map internal error."""
    folds5 = props.regression_folds(h=32, n=320, k=5)
    mesh = shardlib.make_cv_mesh(2)     # fold axis 2, but k=5
    eng = engine.CVEngine(_strat(), mesh=mesh)
    with pytest.raises(ValueError, match="not divisible"):
        next(eng.sweep_async(folds5, LAMS))
    with pytest.raises(ValueError, match="not divisible"):
        eng.run_async(folds5, LAMS)


@pytest.mark.tier2
def test_async_warm_replay_on_mesh(folds):
    cache = factor_cache.FactorCache()
    engine.CVEngine(_strat(), mesh="auto", cache=cache, lam_chunk=3
                    ).run_async(folds, LAMS)
    bk = CountingBackend(ReferenceBackend())
    warm = engine.CVEngine(_strat(), backend=bk, mesh="auto", cache=cache,
                           lam_chunk=3)
    r = warm.run_async(folds, LAMS)
    assert bk.n_cholesky == 0
    assert r.extras["engine"]["cache"]["status"] == "hit"
    base = engine.CVEngine(_strat()).run(folds, LAMS)
    np.testing.assert_allclose(r.errors, base.errors, rtol=1e-8)
