"""Roofline-guided autotuner: lattice legality, zero-execution scoring,
tuned-vs-untuned parity, tuning-cache hits and cross-process persistence,
and the serving layer's tune-once-per-geometry contract."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.backends import (CountingBackend, PallasBackend,
                                 ReferenceBackend, resolve_backend,
                                 retile_backend)
from repro.core.engine import CVEngine, PiCholeskyStrategy
from repro.core.folds import make_folds
from repro.distributed import autotune
from repro.distributed import sharding as shardlib


def _problem(h=24, n=240, k=4, q=16, seed=0):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(n, h)), jnp.float32)
    y = jnp.asarray(rng.normal(size=(n,)), jnp.float32)
    folds = make_folds(x, y, k)
    lams = jnp.logspace(-3, 1, q, dtype=jnp.float32)
    return folds, lams


# ----------------------------------------------------------------- lattice


def test_lattice_default_first_and_legal():
    default = autotune.TunedConfig(block=32, lam_chunk=4, mesh_shape=None,
                                   source="default")
    cands = autotune.candidate_lattice(
        h=24, k=4, q=16, n_devices=4, default=default,
        blocks=(8, 16, 32), store_dtype=jnp.float32,
        budget=64 * 1024)
    assert cands[0] is default
    keys = [c.key() for c in cands]
    assert len(keys) == len(set(keys))          # deduped
    for c in cands:
        assert 1 <= c.lam_chunk <= 16
        if c.mesh_shape is not None:
            n_fold, n_lam = c.mesh_shape
            assert n_fold * n_lam == 4
            assert 4 % n_fold == 0              # fold axis divides k


def test_lattice_mesh_candidates_respect_fold_divisibility():
    # k=3 on 4 devices: only fold axes 1 divide both → (1,4) (plus None)
    default = autotune.TunedConfig(block=32, lam_chunk=4)
    cands = autotune.candidate_lattice(
        h=16, k=3, q=8, n_devices=4, default=default, blocks=(32,),
        chunks=(4,))
    shapes = {c.mesh_shape for c in cands}
    assert shapes == {None, (1, 4)}
    assert shardlib.mesh_shape_candidates(3, 4) == [(1, 4)]
    assert shardlib.mesh_shape_candidates(4, 4) == [(1, 4), (2, 2), (4, 1)]


def test_chunk_ladder_spans_auto_value():
    ladder = autotune.chunk_ladder(8, 64)
    assert 8 in ladder
    assert any(c < 8 for c in ladder) and any(c > 8 for c in ladder)
    assert all(1 <= c <= 64 for c in ladder)
    assert autotune.chunk_ladder(1, 1) == (1,)   # clipped, never empty


# ----------------------------------------- scoring is compile-time only


def test_tune_zero_candidate_executions():
    """Every candidate is AOT lowered+compiled, but NONE executes: a
    factorization routed through a host callback would fire the callback
    on execution — lowering alone must leave the counter at zero."""
    calls = dict(n=0)

    def host_chol(a):
        calls["n"] += 1
        return np.linalg.cholesky(a)

    def chol_fn(a):
        return jax.pure_callback(
            host_chol, jax.ShapeDtypeStruct(a.shape, a.dtype), a,
            vmap_method="sequential")

    folds, lams = _problem()
    strat = PiCholeskyStrategy(block=32, chol_fn=chol_fn)
    eng = CVEngine(strat, backend="reference")
    cache = autotune.TuningCache()
    cfg = autotune.tune(eng, folds, lams, cache=cache, blocks=(32, 64),
                        mesh_shapes=[None])
    assert calls["n"] == 0                       # nothing ran
    assert cache.lowerings >= 2                  # but candidates compiled
    assert cfg.source == "tuned"
    assert np.isfinite(cfg.predicted_s) and cfg.predicted_s > 0
    # scored candidates all carry finite predictions, chosen is the argmin
    default = autotune.default_config(eng, 4, 24, 16, jnp.float32)
    scored = autotune.score_candidates(
        eng, folds, lams, autotune.candidate_lattice(
            h=24, k=4, q=16, n_devices=len(jax.devices()), default=default,
            blocks=(32, 64), mesh_shapes=[None], store_dtype=jnp.float32,
            budget=64 * 1024))
    assert calls["n"] == 0
    assert min(s.predicted_s for s in scored) == pytest.approx(
        cfg.predicted_s)


# ------------------------------------------------------------ result parity


@pytest.mark.parametrize("backend", ["reference", "pallas"])
def test_tuned_sweep_bitwise_vs_untuned(backend):
    """With the mesh pinned and every lattice block ≥ h (single padded
    tile), tuning may change tiles/chunks but the swept errors are
    BIT-identical to the untuned engine on both backends."""
    folds, lams = _problem()
    kw = dict(block=32) if backend == "pallas" else {}
    eng = CVEngine("picholesky", backend=backend, tune="auto",
                   tune_lattice=dict(blocks=(32, 64), mesh_shapes=[None]),
                   **kw)
    base = CVEngine("picholesky", backend=backend, **kw)
    r_t = eng.run(folds, lams)
    r_b = base.run(folds, lams)
    np.testing.assert_array_equal(np.asarray(r_t.errors),
                                  np.asarray(r_b.errors))
    tune_info = r_t.extras["engine"]["tune"]
    assert tune_info["source"] == "tuned"
    assert tune_info["block"] in (32, 64)


def test_tuned_mesh_selection_allclose_and_same_argmin():
    """Free mesh dimension: the tuner may pick a sharded layout; results
    stay allclose (same tolerance as the engine's own mesh parity tests)
    and select the identical λ*."""
    folds, lams = _problem(h=16, n=160, k=4, q=8)
    eng = CVEngine("picholesky", backend="reference", tune="auto",
                   tune_lattice=dict(blocks=(16, 32)))
    base = CVEngine("picholesky", backend="reference")
    r_t = eng.run(folds, lams)
    r_b = base.run(folds, lams)
    np.testing.assert_allclose(np.asarray(r_t.errors),
                               np.asarray(r_b.errors), rtol=1e-4)
    assert r_t.best_lam == r_b.best_lam
    ms = r_t.extras["engine"]["tune"]["mesh_shape"]
    if ms is not None:
        assert ms[0] * ms[1] == len(jax.devices())


def test_default_always_candidate_ties_resolve_to_default():
    """Pinning the lattice to exactly the default config returns the
    default configuration (strict < keeps the first, default-first
    element on ties)."""
    folds, lams = _problem()
    eng = CVEngine("picholesky", backend="reference")
    default = autotune.default_config(eng, 4, 24, int(lams.shape[0]),
                                      jnp.float32)
    cfg = autotune.tune(eng, folds, lams, blocks=(default.block,),
                        chunks=(default.lam_chunk,),
                        mesh_shapes=[default.mesh_shape])
    assert cfg.key() == default.key()


# ------------------------------------------------------------ tuning cache


def test_tune_cache_hit_skips_lowering():
    folds, lams = _problem()
    cache = autotune.TuningCache()
    eng = CVEngine("picholesky", backend="reference", tune="auto",
                   tune_cache=cache,
                   tune_lattice=dict(blocks=(32,), mesh_shapes=[None]))
    r1 = eng.run(folds, lams)
    n_low = cache.lowerings
    assert n_low > 0 and cache.misses == 1
    r2 = eng.run(folds, lams)
    assert cache.lowerings == n_low              # no re-lowering at all
    assert cache.hits == 1
    assert r2.extras["engine"]["tune"]["source"] == "cache"
    np.testing.assert_array_equal(np.asarray(r1.errors),
                                  np.asarray(r2.errors))
    # a DIFFERENT geometry is a miss, not a false hit
    folds2, lams2 = _problem(h=16, n=160)
    eng.run(folds2, lams2)
    assert cache.misses == 2
    assert cache.lowerings > n_low


def test_tuning_cache_persists_via_checkpoint_manager(tmp_path):
    folds, lams = _problem()
    cache = autotune.TuningCache()
    eng = CVEngine("picholesky", backend="reference", tune="auto",
                   tune_cache=cache,
                   tune_lattice=dict(blocks=(32, 64), mesh_shapes=[None]))
    eng.run(folds, lams)
    cache.save(str(tmp_path))
    # fresh process stand-in: a new cache object loaded from disk
    cache2 = autotune.TuningCache.load(str(tmp_path))
    assert len(cache2) == 1
    assert cache2.configs == cache.configs       # TunedConfig is frozen/eq
    eng2 = CVEngine("picholesky", backend="reference", tune="auto",
                    tune_cache=cache2,
                    tune_lattice=dict(blocks=(32, 64), mesh_shapes=[None]))
    eng2.run(folds, lams)
    assert cache2.hits == 1 and cache2.lowerings == 0
    # save is idempotent/atomic: a second save supersedes the step
    cache2.save(str(tmp_path))
    assert len(autotune.TuningCache.load(str(tmp_path))) == 1


def test_tuning_cache_load_missing_dir_is_empty(tmp_path):
    cache = autotune.TuningCache.load(str(tmp_path / "nope"))
    assert len(cache) == 0


def test_explicit_tuned_config_pins_configuration():
    folds, lams = _problem()
    cfg = autotune.TunedConfig(block=32, lam_chunk=4, mesh_shape=None)
    eng = CVEngine("picholesky", backend="reference", tune=cfg)
    r = eng.run(folds, lams)
    info = r.extras["engine"]["tune"]
    assert (info["block"], info["lam_chunk"]) == (32, 4)
    derived = eng._apply_tuned(cfg)
    assert derived.strategy.block == 32 and derived.lam_chunk == 4
    assert derived.tune is False                 # recursion guard


# ---------------------------------------------------------- backend retile


def test_retile_backend_variants():
    pb = retile_backend(PallasBackend(), chol_block=64)
    assert (pb.chol_block, pb.trsm_block) == (64, 256)
    rb = ReferenceBackend()
    assert retile_backend(rb, chol_block=64) is rb   # no kernel tiles
    cb = CountingBackend(PallasBackend())
    cb.by_stage["unstaged"] = {"cholesky": 3}
    cb2 = retile_backend(cb, chol_block=64, trsm_block=32)
    assert cb2 is not cb
    assert cb2.inner.chol_block == 64 and cb2.inner.trsm_block == 32
    assert cb2.by_stage is cb.by_stage           # counters shared, not forked
    assert resolve_backend("pallas", chol_block=64).chol_block == 64
    assert resolve_backend(cb, trsm_block=128).inner.trsm_block == 128


# -------------------------------------------------------------- serving


def test_server_tunes_once_per_geometry():
    from repro.serving.server import CVSweepServer, ServerConfig, SweepRequest

    folds, lams = _problem()
    srv = CVSweepServer(
        PiCholeskyStrategy(block=32), "reference",
        config=ServerConfig(
            tune="auto",
            tune_lattice=dict(blocks=(32, 64), mesh_shapes=[None])))
    for tenant in ("a", "b", "c"):
        srv.submit(SweepRequest(tenant=tenant, folds=folds, lams=lams))
    srv.drain()
    stats = srv.stats["tuning"]
    assert stats["entries"] == 1                 # one geometry, one verdict
    assert stats["misses"] == 1
    n_low = stats["lowerings"]
    # same geometry again: pure cache hit, zero new lowerings
    srv.submit(SweepRequest(tenant="a", folds=folds, lams=lams))
    srv.drain()
    assert srv.stats["tuning"]["lowerings"] == n_low
    assert srv.stats["tuning"]["hits"] >= 1
    assert len(srv.take_responses("a")) == 2
