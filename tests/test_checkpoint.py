"""Fault-tolerance contract of the checkpoint manager."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"w": jax.random.normal(k, (8, 8)),
            "opt": {"mu": jnp.zeros((8, 8)), "step": jnp.asarray(3)}}


def test_save_restore_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    tree = _tree()
    mgr.save(10, tree)
    step, restored = mgr.restore_latest(tree)
    assert step == 10
    assert np.allclose(restored["w"], tree["w"])
    assert int(restored["opt"]["step"]) == 3


def test_async_save(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    tree = _tree()
    mgr.save_async(5, tree)
    mgr.wait()
    assert mgr.all_steps() == [5]


def test_corrupt_checkpoint_skipped(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    tree = _tree()
    mgr.save(1, tree)
    mgr.save(2, tree)
    # corrupt the newest (simulated torn write / killed host)
    path = os.path.join(str(tmp_path), "step_000000000002", "leaf_000000.npy")
    with open(path, "r+b") as f:
        f.seek(64)
        f.write(b"\xde\xad\xbe\xef")
    step, restored = mgr.restore_latest(tree)
    assert step == 1                         # fell back to the valid one
    assert restored is not None


def test_missing_manifest_rejected(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    tree = _tree()
    mgr.save(1, tree)
    os.remove(os.path.join(str(tmp_path), "step_000000000001", "manifest.json"))
    step, restored = mgr.restore_latest(tree)
    assert step is None and restored is None


def test_gc_keeps_newest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = _tree()
    for s in (1, 2, 3, 4):
        mgr.save(s, tree)
    assert mgr.all_steps() == [3, 4]


def test_atomic_no_tmp_left(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(7, _tree())
    assert not any(n.endswith(".tmp") for n in os.listdir(str(tmp_path)))
