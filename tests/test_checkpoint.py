"""Fault-tolerance contract of the checkpoint manager."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.core import packing, picholesky


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"w": jax.random.normal(k, (8, 8)),
            "opt": {"mu": jnp.zeros((8, 8)), "step": jnp.asarray(3)}}


def test_save_restore_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    tree = _tree()
    mgr.save(10, tree)
    step, restored = mgr.restore_latest(tree)
    assert step == 10
    assert np.allclose(restored["w"], tree["w"])
    assert int(restored["opt"]["step"]) == 3


def test_async_save(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    tree = _tree()
    mgr.save_async(5, tree)
    mgr.wait()
    assert mgr.all_steps() == [5]


def test_corrupt_checkpoint_skipped(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    tree = _tree()
    mgr.save(1, tree)
    mgr.save(2, tree)
    # corrupt the newest (simulated torn write / killed host)
    path = os.path.join(str(tmp_path), "step_000000000002", "leaf_000000.npy")
    with open(path, "r+b") as f:
        f.seek(64)
        f.write(b"\xde\xad\xbe\xef")
    step, restored = mgr.restore_latest(tree)
    assert step == 1                         # fell back to the valid one
    assert restored is not None


def test_missing_manifest_rejected(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    tree = _tree()
    mgr.save(1, tree)
    os.remove(os.path.join(str(tmp_path), "step_000000000001", "manifest.json"))
    step, restored = mgr.restore_latest(tree)
    assert step is None and restored is None


def test_gc_keeps_newest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = _tree()
    for s in (1, 2, 3, 4):
        mgr.save(s, tree)
    assert mgr.all_steps() == [3, 4]


def test_atomic_no_tmp_left(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(7, _tree())
    assert not any(n.endswith(".tmp") for n in os.listdir(str(tmp_path)))


def test_keep_none_disables_gc(tmp_path):
    """keep=None retains every step — the factor cache's content-store
    mode, where entries are addresses, not a rolling history."""
    mgr = CheckpointManager(str(tmp_path), keep=None)
    for s in (1, 2, 3, 4, 5):
        mgr.save(s, _tree())
    assert mgr.all_steps() == [1, 2, 3, 4, 5]


def _interp_state(h=24, block=8, k=3, g=4):
    """A batched-over-folds PiCholesky + PackedFactor pair, as the factor
    cache stores them (theta (k, r+1, P), anchors vec (k, g, P))."""
    key = jax.random.PRNGKey(0)
    hess = jax.vmap(lambda kk: (lambda x: x.T @ x + h * jnp.eye(h))(
        jax.random.normal(kk, (2 * h, h), jnp.float64))
    )(jax.random.split(key, k))
    sample = picholesky.choose_sample_lambdas(1e-2, 1.0, g)
    model = jax.vmap(lambda hf: picholesky.fit(hf, sample, 2, block=block)
                     )(hess)
    ls = jax.vmap(lambda hf: jax.vmap(
        lambda lam: jnp.linalg.cholesky(hf + lam * jnp.eye(h)))(sample)
    )(hess)
    pf = packing.PackedFactor(vec=packing.pack_tril(ls, block), h=h,
                              block=block)
    return model, pf


def test_picholesky_and_packed_factor_roundtrip(tmp_path):
    """Satellite: Θ and PackedFactor are pytrees — a save → load through
    the manager is bit-for-bit, statics (h, block) preserved, and the
    restored interpolant solves identically on the reference backend."""
    model, pf = _interp_state()
    mgr = CheckpointManager(str(tmp_path), keep=None)
    mgr.save(0, {"model": model, "anchors": pf})
    step, back = mgr.restore_latest({"model": model, "anchors": pf})
    assert step == 0
    m2, pf2 = back["model"], back["anchors"]
    np.testing.assert_array_equal(np.asarray(m2.theta),
                                  np.asarray(model.theta))
    np.testing.assert_array_equal(np.asarray(m2.center),
                                  np.asarray(model.center))
    np.testing.assert_array_equal(np.asarray(pf2.vec), np.asarray(pf.vec))
    assert (m2.h, m2.block) == (model.h, model.block)
    assert (pf2.h, pf2.block) == (pf.h, pf.block)

    g_vec = jax.random.normal(jax.random.PRNGKey(7), (model.h,),
                              jnp.float64)
    lams = jnp.logspace(-2, 0, 6)
    for f in range(3):
        a = picholesky.PiCholesky(theta=model.theta[f],
                                  center=model.center[f],
                                  h=model.h, block=model.block)
        b = picholesky.PiCholesky(theta=m2.theta[f], center=m2.center[f],
                                  h=m2.h, block=m2.block)
        np.testing.assert_array_equal(
            np.asarray(a.solve(lams, g_vec)), np.asarray(b.solve(lams, g_vec)))
