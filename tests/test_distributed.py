"""Distributed substrate: compression, sharding resolution, roofline parser."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.distributed import compression, hlo_cost, sharding
from repro.distributed.context import MeshCtx
from repro.models.params import Spec


# ------------------------------------------------------------- compression


@given(seed=st.integers(0, 50))
@settings(max_examples=20, deadline=None)
def test_int8_quantization_bounded_error(seed):
    x = jnp.asarray(np.random.RandomState(seed).randn(64) * 10)
    q, s = compression.quantize_int8(x)
    err = jnp.max(jnp.abs(compression.dequantize_int8(q, s) - x))
    assert float(err) <= float(s) * 0.5 + 1e-6


def test_error_feedback_unbiased_over_steps():
    """EF property: accumulated transported signal ≈ accumulated true signal
    (residual stays bounded, does not drift)."""
    rs = np.random.RandomState(0)
    grads = [jnp.asarray(rs.randn(32) * (1 + i % 3)) for i in range(50)]
    residual = jnp.zeros(32)
    sent = jnp.zeros(32)
    true = jnp.zeros(32)
    for g in grads:
        deq, residual = compression.ef_compress_tree(g, residual)
        sent = sent + deq
        true = true + g
    # total drift equals the final residual — bounded by one quant step
    np.testing.assert_allclose(np.asarray(sent + residual), np.asarray(true),
                               rtol=1e-5, atol=1e-5)
    assert float(jnp.max(jnp.abs(residual))) < 1.0


def test_ef_tree_structure_preserved():
    tree = {"a": jnp.ones((4, 4)), "b": {"c": jnp.zeros(3)}}
    res = jax.tree.map(jnp.zeros_like, tree)
    deq, new_res = compression.ef_compress_tree(tree, res)
    assert jax.tree.structure(deq) == jax.tree.structure(tree)
    assert jax.tree.structure(new_res) == jax.tree.structure(tree)


# ------------------------------------------------------------- sharding


def _ctx():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    return MeshCtx.from_mesh(mesh, fsdp=True)


def test_spec_pspec_resolution():
    ctx = _ctx()
    ps = sharding.spec_pspec(Spec((8, 16), ("fsdp", "model")), ctx)
    assert ps == jax.sharding.PartitionSpec("data", "model")
    ps2 = sharding.spec_pspec(Spec((8,), (None,)), ctx)
    assert ps2 == jax.sharding.PartitionSpec(None)


def test_spec_pspec_divisibility_check():
    mesh = jax.make_mesh((1,), ("model",))
    # fake a 16-wide axis via ctx override
    class FakeCtx:
        fsdp_axis = None
        def axis_size(self, name):
            return 16
    with pytest.raises(ValueError):
        sharding.spec_pspec(Spec((10,), ("model",)), FakeCtx())


def test_meshctx_no_mesh_noop():
    ctx = MeshCtx(None)
    x = jnp.ones((4, 4))
    assert ctx.constrain(x, "data", None) is x
    assert ctx.tp_size == 1 and ctx.dp_size == 1


# ------------------------------------------------------------- hlo parser


def test_hlo_cost_counts_loop_trips():
    n = 64
    def f(x, w):
        def body(c, _):
            return c @ w, None
        out, _ = jax.lax.scan(body, x, None, length=7)
        return out
    c = jax.jit(f).lower(jax.ShapeDtypeStruct((n, n), jnp.float32),
                         jax.ShapeDtypeStruct((n, n), jnp.float32)).compile()
    cost = hlo_cost.analyze_hlo(c.as_text())
    expect = 7 * 2 * n ** 3
    assert abs(cost.flops - expect) / expect < 0.05
    assert cost.unknown_trip_loops == 0


def test_hlo_cost_nested_loops_multiply():
    n = 32
    def f(x, w):
        def outer(c, _):
            def inner(ci, _):
                return ci @ w, None
            c2, _ = jax.lax.scan(inner, c, None, length=3)
            return c2, None
        out, _ = jax.lax.scan(outer, x, None, length=5)
        return out
    c = jax.jit(f).lower(jax.ShapeDtypeStruct((n, n), jnp.float32),
                         jax.ShapeDtypeStruct((n, n), jnp.float32)).compile()
    cost = hlo_cost.analyze_hlo(c.as_text())
    expect = 15 * 2 * n ** 3
    assert abs(cost.flops - expect) / expect < 0.10


def test_collective_formulas():
    text = '''
ENTRY %main (p: f32[16,16]) -> f32[16,16] {
  %p = f32[16,16]{1,0} parameter(0)
  ROOT %ar = f32[16,16]{1,0} all-reduce(%p), replica_groups=[2,8]<=[16], to_apply=%add
}
'''
    cost = hlo_cost.analyze_hlo(text)
    size = 16 * 16 * 4
    assert abs(cost.wire["all-reduce"] - 2 * 7 / 8 * size) < 1e-6
