"""Distributed substrate: compression, sharding resolution, roofline parser."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.distributed import compression, hlo_cost, sharding
from repro.distributed.context import MeshCtx
from repro.models.params import Spec


# ------------------------------------------------------------- compression


@given(seed=st.integers(0, 50))
@settings(max_examples=20, deadline=None)
def test_int8_quantization_bounded_error(seed):
    x = jnp.asarray(np.random.RandomState(seed).randn(64) * 10)
    q, s = compression.quantize_int8(x)
    err = jnp.max(jnp.abs(compression.dequantize_int8(q, s) - x))
    assert float(err) <= float(s) * 0.5 + 1e-6


def test_error_feedback_unbiased_over_steps():
    """EF property: accumulated transported signal ≈ accumulated true signal
    (residual stays bounded, does not drift)."""
    rs = np.random.RandomState(0)
    grads = [jnp.asarray(rs.randn(32) * (1 + i % 3)) for i in range(50)]
    residual = jnp.zeros(32)
    sent = jnp.zeros(32)
    true = jnp.zeros(32)
    for g in grads:
        deq, residual = compression.ef_compress_tree(g, residual)
        sent = sent + deq
        true = true + g
    # total drift equals the final residual — bounded by one quant step
    np.testing.assert_allclose(np.asarray(sent + residual), np.asarray(true),
                               rtol=1e-5, atol=1e-5)
    assert float(jnp.max(jnp.abs(residual))) < 1.0


def test_ef_tree_structure_preserved():
    tree = {"a": jnp.ones((4, 4)), "b": {"c": jnp.zeros(3)}}
    res = jax.tree.map(jnp.zeros_like, tree)
    deq, new_res = compression.ef_compress_tree(tree, res)
    assert jax.tree.structure(deq) == jax.tree.structure(tree)
    assert jax.tree.structure(new_res) == jax.tree.structure(tree)


# ------------------------------------------------------------- sharding


def _ctx():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    return MeshCtx.from_mesh(mesh, fsdp=True)


def test_spec_pspec_resolution():
    ctx = _ctx()
    ps = sharding.spec_pspec(Spec((8, 16), ("fsdp", "model")), ctx)
    assert ps == jax.sharding.PartitionSpec("data", "model")
    ps2 = sharding.spec_pspec(Spec((8,), (None,)), ctx)
    assert ps2 == jax.sharding.PartitionSpec(None)


def test_spec_pspec_divisibility_check():
    mesh = jax.make_mesh((1,), ("model",))
    # fake a 16-wide axis via ctx override
    class FakeCtx:
        fsdp_axis = None
        def axis_size(self, name):
            return 16
    with pytest.raises(ValueError):
        sharding.spec_pspec(Spec((10,), ("model",)), FakeCtx())


def test_meshctx_no_mesh_noop():
    ctx = MeshCtx(None)
    x = jnp.ones((4, 4))
    assert ctx.constrain(x, "data", None) is x
    assert ctx.tp_size == 1 and ctx.dp_size == 1


# ------------------------------------------------------------- hlo parser


def test_hlo_cost_counts_loop_trips():
    n = 64
    def f(x, w):
        def body(c, _):
            return c @ w, None
        out, _ = jax.lax.scan(body, x, None, length=7)
        return out
    c = jax.jit(f).lower(jax.ShapeDtypeStruct((n, n), jnp.float32),
                         jax.ShapeDtypeStruct((n, n), jnp.float32)).compile()
    cost = hlo_cost.analyze_hlo(c.as_text())
    expect = 7 * 2 * n ** 3
    assert abs(cost.flops - expect) / expect < 0.05
    assert cost.unknown_trip_loops == 0


def test_hlo_cost_nested_loops_multiply():
    n = 32
    def f(x, w):
        def outer(c, _):
            def inner(ci, _):
                return ci @ w, None
            c2, _ = jax.lax.scan(inner, c, None, length=3)
            return c2, None
        out, _ = jax.lax.scan(outer, x, None, length=5)
        return out
    c = jax.jit(f).lower(jax.ShapeDtypeStruct((n, n), jnp.float32),
                         jax.ShapeDtypeStruct((n, n), jnp.float32)).compile()
    cost = hlo_cost.analyze_hlo(c.as_text())
    expect = 15 * 2 * n ** 3
    assert abs(cost.flops - expect) / expect < 0.10


def test_collective_formulas():
    text = '''
ENTRY %main (p: f32[16,16]) -> f32[16,16] {
  %p = f32[16,16]{1,0} parameter(0)
  ROOT %ar = f32[16,16]{1,0} all-reduce(%p), replica_groups=[2,8]<=[16], to_apply=%add
}
'''
    cost = hlo_cost.analyze_hlo(text)
    size = 16 * 16 * 4
    assert abs(cost.wire["all-reduce"] - 2 * 7 / 8 * size) < 1e-6


# ------------------------------------------------------- λ-chunk heuristic


def test_auto_lam_chunk_floor_is_one():
    # budget smaller than ONE λ's packed row still streams: floor at 1
    from repro.core import packing
    h, block = 128, 128
    per_lam = packing.packed_nbytes(h, block, jnp.float32)
    assert sharding.auto_lam_chunk(h, block, jnp.float32, per_lam - 1) == 1
    assert sharding.auto_lam_chunk(h, block, jnp.float32, 0) == 1


def test_auto_lam_chunk_bf16_doubles_fp32():
    # storage dtype halves the per-λ bytes → chunk doubles at the same
    # budget (the memory half of the mixed-precision contract)
    h, block, budget = 128, 128, 1 << 20
    c32 = sharding.auto_lam_chunk(h, block, jnp.float32, budget)
    c16 = sharding.auto_lam_chunk(h, block, jnp.bfloat16, budget)
    assert c16 == 2 * c32


def test_auto_lam_chunk_h_smaller_than_block():
    # h < block: one padded tile — the chunk follows the PADDED packed
    # bytes, so it can only shrink (never overflow the budget) vs h=block
    from repro.core import packing
    budget = 1 << 20
    small = sharding.auto_lam_chunk(24, 128, jnp.float32, budget)
    exact = sharding.auto_lam_chunk(128, 128, jnp.float32, budget)
    assert small == budget // packing.packed_nbytes(24, 128, jnp.float32)
    assert small == exact   # both pack one 128-tile
    # and a proportionate block tracks the smaller true working set
    tight = sharding.auto_lam_chunk(24, 32, jnp.float32, budget)
    assert tight >= small


# ------------------------------------------------------------ HW presets


def test_hw_presets_cover_platforms():
    from repro.distributed import roofline as rl
    assert set(rl.HW_PRESETS) == {"cpu", "gpu", "tpu"}
    for hw in rl.HW_PRESETS.values():
        assert hw.peak_flops > 0 and hw.hbm_bw > 0 and hw.link_bw > 0
    # backcompat: module constants ARE the tpu-v5e preset
    tpu = rl.HW_PRESETS["tpu"]
    assert (tpu.peak_flops, tpu.hbm_bw, tpu.link_bw) == \
        (rl.PEAK_FLOPS, rl.HBM_BW, rl.LINK_BW)


def test_detect_hw_platform_and_env_override(monkeypatch):
    from repro.distributed import roofline as rl
    monkeypatch.delenv("REPRO_HW", raising=False)
    assert rl.detect_hw() == rl.HW_PRESETS[jax.devices()[0].platform]
    monkeypatch.setenv("REPRO_HW", "gpu")
    assert rl.detect_hw().name == "gpu-a100"
    monkeypatch.setenv("REPRO_HW_PEAK_FLOPS", "1e12")
    hw = rl.detect_hw()
    assert hw.peak_flops == 1e12 and hw.name.endswith("+env")
    assert hw.hbm_bw == rl.HW_PRESETS["gpu"].hbm_bw   # others untouched
    monkeypatch.setenv("REPRO_HW", "hal9000")
    with pytest.raises(ValueError, match="no such preset"):
        rl.detect_hw()


def test_roofline_uses_hw_rates():
    from repro.distributed import roofline as rl
    hw = rl.HW(name="toy", peak_flops=100.0, hbm_bw=10.0, link_bw=1.0)
    roof = rl.Roofline(flops=200.0, hbm_bytes=50.0, wire_bytes=3.0,
                       by_collective={}, chips=1, hw=hw)
    assert roof.compute_s == 2.0 and roof.memory_s == 5.0
    assert roof.collective_s == 3.0
    assert roof.step_s == 5.0 and roof.bottleneck == "memory"
    s = roof.summary()
    assert s["step_s"] == 5.0 and s["hw"] == "toy"


def test_roofline_cache_aware_memory_term():
    """Cache-modelled HW: a cache-resident working set streams at
    cache_bw; a spilled one blends toward hbm_bw by the spilled fraction
    (monotone in working-set size — the property that lets the tuner rank
    λ-chunk/block candidates whose total bytes are flat)."""
    from repro.distributed import roofline as rl
    hw = rl.HW(name="toy", peak_flops=1e9, hbm_bw=10.0, link_bw=1.0,
               cache_bw=100.0, cache_bytes=1000.0)
    mk = lambda ws: rl.Roofline(flops=0.0, hbm_bytes=500.0, wire_bytes=0.0,
                                by_collective={}, chips=1, hw=hw,
                                temp_bytes=ws)
    assert mk(800.0).effective_bw == 100.0          # fits: cache speed
    half = mk(2000.0)                               # 50% resident
    assert half.effective_bw == pytest.approx(0.5 * 100.0 + 0.5 * 10.0)
    assert mk(10_000.0).effective_bw < half.effective_bw   # monotone
    assert mk(None).effective_bw == 10.0            # unknown ws: flat model
    # cache-less HW ignores temp_bytes entirely
    flat = rl.HW(name="flat", peak_flops=1e9, hbm_bw=10.0, link_bw=1.0)
    roof = rl.Roofline(flops=0.0, hbm_bytes=500.0, wire_bytes=0.0,
                       by_collective={}, chips=1, hw=flat, temp_bytes=5.0)
    assert roof.effective_bw == 10.0
    assert mk(2000.0).summary()["effective_bw"] == half.effective_bw


def test_hlo_cost_slice_through_bitcast_not_charged_full():
    """A fusion that consumes its parameter only through view ops
    (bitcast/reshape) feeding a slice is charged the slice bytes, not the
    whole array — the per-tile packed-factor read pattern.  A fusion that
    reads the parameter directly still pays the full operand."""
    text = '''
%fused_computation.1 (param_0.1: f32[1000,16]) -> f32[1,16] {
  %param_0.1 = f32[1000,16]{1,0} parameter(0)
  %bitcast.1 = f32[1000,1,16]{2,1,0} bitcast(f32[1000,16]{1,0} %param_0.1)
  %slice.1 = f32[1,1,16]{2,1,0} slice(f32[1000,1,16]{2,1,0} %bitcast.1), slice={[3:4], [0:1], [0:16]}
  ROOT %bitcast.2 = f32[1,16]{1,0} bitcast(f32[1,1,16]{2,1,0} %slice.1)
}

%fused_computation.2 (param_0.2: f32[1000,16]) -> f32[1000,16] {
  %param_0.2 = f32[1000,16]{1,0} parameter(0)
  ROOT %add.1 = f32[1000,16]{1,0} add(f32[1000,16]{1,0} %param_0.2, f32[1000,16]{1,0} %param_0.2)
}

ENTRY %main (p: f32[1000,16]) -> f32[1000,16] {
  %p = f32[1000,16]{1,0} parameter(0)
  %tile = f32[1,16]{1,0} fusion(f32[1000,16]{1,0} %p), kind=kLoop, calls=%fused_computation.1
  ROOT %dense = f32[1000,16]{1,0} fusion(f32[1000,16]{1,0} %p), kind=kLoop, calls=%fused_computation.2
}
'''
    cost = hlo_cost.analyze_hlo(text)
    full = 1000 * 16 * 4
    tile = 1 * 1 * 16 * 4
    # sliced fusion: result + touched slice; dense fusion: result + operand
    assert cost.hbm_bytes == pytest.approx((1 * 16 * 4 + tile) + 2 * full)
