"""CVEngine: strategy parity vs the host-loop oracles, sharded-mesh parity
on the 4-virtual-device host platform, backend switching, and the driver
compatibility layer."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import cv, cv_host, engine
from repro.distributed import sharding as shardlib
from repro.testing import strategies as props

# fold problems come from the shared generators (repro.testing.strategies)


@pytest.fixture(scope="module")
def folds5():
    return props.regression_folds(h=128, n=400, k=5)


@pytest.fixture(scope="module")
def folds4():
    return props.regression_folds(h=128, n=400, k=4)


LAMS = props.log_grid(31)


def _assert_result_close(a, b, rtol=1e-4):
    np.testing.assert_allclose(a.errors, b.errors, rtol=rtol)
    assert a.best_lam == pytest.approx(b.best_lam, rel=rtol)


# ------------------------------------------------- parity vs host oracles


def test_exact_matches_host_oracle(folds5):
    r = engine.CVEngine("exact").run(folds5, LAMS)
    _assert_result_close(r, cv_host.host_cv_exact_cholesky(folds5, LAMS))
    assert r.n_exact_chol == 5 * 31


def test_picholesky_matches_host_oracle(folds5):
    strat = engine.PiCholeskyStrategy(g=4, block=32)
    r = engine.CVEngine(strat).run(folds5, LAMS)
    _assert_result_close(r, cv_host.host_cv_picholesky(folds5, LAMS, g=4,
                                                       block=32))
    assert r.n_exact_chol == 5 * 4


@pytest.mark.parametrize("mode,k_trunc", [("full", 0), ("truncated", 32)])
def test_svd_matches_host_oracle(folds5, mode, k_trunc):
    strat = engine.SVDStrategy(mode=mode, k_trunc=k_trunc)
    r = engine.CVEngine(strat).run(folds5, LAMS)
    _assert_result_close(r, cv_host.host_cv_svd(folds5, LAMS, mode=mode,
                                                k_trunc=k_trunc))


def test_randomized_svd_matches_host_oracle(folds5):
    key = jax.random.PRNGKey(2)
    strat = engine.SVDStrategy(mode="randomized", k_trunc=32, key=key)
    r = engine.CVEngine(strat).run(folds5, LAMS)
    _assert_result_close(r, cv_host.host_cv_svd(folds5, LAMS,
                                                mode="randomized",
                                                k_trunc=32, key=key))


def test_pinrmse_matches_host_oracle(folds5):
    strat = engine.PinrmseStrategy(g=4, degree=2)
    r = engine.CVEngine(strat).run(folds5, LAMS)
    _assert_result_close(r, cv_host.host_cv_pinrmse(folds5, LAMS, g=4))


def test_warmstart_selects_exact_lambda(folds5):
    """No host oracle (the engine's metric-ridge refresh replaced the broken
    host version) — the contract is selection parity with exact CV at a
    fraction of the factorizations."""
    r_exact = engine.CVEngine("exact").run(folds5, LAMS)
    strat = engine.PiCholeskyWarmstart(g_first=4, g_rest=3, block=32)
    r_warm = engine.CVEngine(strat).run(folds5, LAMS)
    i_e = int(np.argmin(r_exact.errors))
    i_w = int(np.argmin(r_warm.errors))
    assert abs(i_e - i_w) <= 1
    assert r_warm.n_exact_chol < r_exact.n_exact_chol / 5


# ------------------------------------------------------- sharded execution


def test_host_platform_has_four_devices():
    """conftest forces --xla_force_host_platform_device_count=4."""
    assert len(jax.devices()) >= 4


@pytest.mark.parametrize("name,params", [
    ("exact", {}),
    ("picholesky", dict(block=32)),
    ("picholesky_warmstart", dict(block=32, g_rest=3)),
    ("svd", dict(mode="truncated", k_trunc=32)),
    ("pinrmse", {}),
])
def test_strategies_match_on_auto_mesh(folds4, name, params):
    """Every strategy, sharded over the 4-device (folds × lams) mesh,
    reproduces the single-device sweep (acceptance: rtol 1e-4)."""
    single = engine.CVEngine(engine.make_strategy(name, **params)).run(
        folds4, LAMS)
    sharded = engine.CVEngine(engine.make_strategy(name, **params),
                              mesh="auto").run(folds4, LAMS)
    np.testing.assert_allclose(sharded.errors, single.errors, rtol=1e-4)
    assert sharded.best_lam == pytest.approx(single.best_lam, rel=1e-4)
    assert sharded.extras["engine"]["mesh"] is not None


def test_two_by_two_mesh_pads_lambda_grid(folds4):
    """2×2 mesh: λ grid (31) is padded to 32 for the λ axis and sliced back."""
    mesh = shardlib.make_cv_mesh(2)
    assert dict(mesh.shape) == {shardlib.CV_FOLD_AXIS: 2,
                                shardlib.CV_LAM_AXIS: 2}
    strat = engine.PiCholeskyStrategy(g=4, block=32)
    r = engine.CVEngine(strat, mesh=mesh).run(folds4, LAMS)
    base = engine.CVEngine(engine.PiCholeskyStrategy(g=4, block=32)).run(
        folds4, LAMS)
    assert r.errors.shape == (31,)
    np.testing.assert_allclose(r.errors, base.errors, rtol=1e-4)


def test_indivisible_fold_axis_raises(folds5):
    mesh = shardlib.make_cv_mesh(2)   # fold axis 2, but k=5
    with pytest.raises(ValueError, match="not divisible"):
        engine.CVEngine("exact", mesh=mesh).run(folds5, LAMS)


def test_cv_axis_sizes():
    assert shardlib.cv_axis_sizes(4, 4) == (4, 1)
    assert shardlib.cv_axis_sizes(5, 4) == (1, 4)
    assert shardlib.cv_axis_sizes(6, 4) == (2, 2)


# -------------------------------------------------------- backend switching


def test_pallas_backend_matches_reference(folds4):
    lams = jnp.logspace(-2, 1, 7)
    for strat in (lambda: engine.ExactCholesky(),
                  lambda: engine.PiCholeskyStrategy(g=4, block=16)):
        r_ref = engine.CVEngine(strat(), backend="reference").run(folds4, lams)
        r_pal = engine.CVEngine(strat(), backend="pallas", block=16).run(
            folds4, lams)
        np.testing.assert_allclose(r_pal.errors, r_ref.errors, rtol=1e-6)


def test_auto_backend_is_reference_off_tpu():
    from repro.core.backends import resolve_backend
    assert resolve_backend("auto").name == "reference"  # CPU test platform
    assert resolve_backend(None).name == "reference"
    assert resolve_backend("pallas").name == "pallas"
    with pytest.raises(ValueError):
        resolve_backend("no-such-backend")


# ------------------------------------------------------ compatibility layer


def test_drivers_are_engine_wrappers(folds5):
    """cv_* wrappers return engine results (metadata present) identical to a
    directly constructed engine."""
    r = cv.cv_picholesky(folds5, LAMS, g=4, block=32)
    meta = r.extras["engine"]
    assert meta["strategy"] == "picholesky"
    assert meta["backend"] == "reference"
    direct = engine.CVEngine(engine.PiCholeskyStrategy(g=4, block=32)).run(
        folds5, LAMS)
    np.testing.assert_allclose(r.errors, direct.errors, rtol=1e-12)


def test_driver_engine_cache_reused(folds5):
    cv.cv_exact_cholesky(folds5, LAMS)
    n = len(cv._ENGINES)
    cv.cv_exact_cholesky(folds5, LAMS)
    assert len(cv._ENGINES) == n


def test_strategy_registry_round_trip():
    for name in engine.STRATEGIES:
        assert engine.make_strategy(name).name == name
    with pytest.raises(ValueError, match="unknown strategy"):
        engine.make_strategy("nope")


def test_custom_strategy_plugs_in(folds4):
    """The CVStrategy seam: a user strategy (here: exact solve via jnp.solve
    instead of Cholesky) runs through the same engine machinery, sharded."""

    class DirectSolve(engine.StrategyBase):
        name = "direct"

        def n_exact_chol(self, k, q):
            return 0

        def fold_errors(self, state, f_idx, h_tr_f, g_tr_f, x_f, y_f, lams,
                        aux, bk):
            eye = jnp.eye(h_tr_f.shape[-1], dtype=h_tr_f.dtype)

            def theta(lam):
                return jnp.linalg.solve(h_tr_f + lam * eye, g_tr_f)

            thetas = jax.vmap(theta)(lams)
            return jax.vmap(lambda t: engine.holdout_nrmse(t, x_f, y_f))(
                thetas)

    r = engine.CVEngine(DirectSolve(), mesh="auto").run(folds4, LAMS)
    r_exact = engine.CVEngine("exact", mesh="auto").run(folds4, LAMS)
    np.testing.assert_allclose(r.errors, r_exact.errors, rtol=1e-8)
