"""Theorem 4.4/4.7 sanity: the analytic bound dominates the observed error
on random small SPD matrices (exact Fréchet machinery, d ≤ 12)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bound, picholesky
from repro.testing import strategies as props

# shared generator (repro.testing.strategies): unit-scale SPD matrices,
# bit-identical to the RandomState construction this suite used locally
_spd = props.unit_spd_matrix


@pytest.mark.parametrize("seed", [0, 1])
def test_taylor_factor_converges_cubically(seed):
    d = 8
    a = _spd(d, seed)
    lam_c = jnp.asarray(0.5)
    errs = []
    gammas = [0.2, 0.1, 0.05]
    for g in gammas:
        lam = lam_c + g
        p = bound.taylor_factor(a, lam, lam_c)
        l = jnp.linalg.cholesky(a + lam * jnp.eye(d))
        errs.append(float(jnp.linalg.norm(p - l)))
    # halving γ should shrink error ≈ 8×; allow slack
    assert errs[1] < errs[0] / 4
    assert errs[2] < errs[1] / 4


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_thm47_bound_dominates_observed_error(seed):
    d = 8
    a = _spd(d, seed)
    lam_c, w, gamma = 0.6, 0.15, 0.15
    sample = jnp.linspace(lam_c - w, lam_c + w, 5)
    model = picholesky.fit(a, sample, 2, block=4)
    rhs = float(bound.picholesky_bound(a, sample, lam_c, gamma))
    big_d = d * (d + 1) / 2.0
    worst = 0.0
    for lam in np.linspace(lam_c - gamma, lam_c + gamma, 9):
        l_i = model.eval_factor(jnp.asarray(lam))
        l_e = jnp.linalg.cholesky(a + lam * jnp.eye(d))
        worst = max(worst, float(jnp.linalg.norm(l_i - l_e)) / np.sqrt(big_d))
    assert worst <= rhs * 1.01, (worst, rhs)


def test_remainder_r_positive_and_monotone_interval():
    d = 6
    a = _spd(d, 3)
    r_small = float(bound.remainder_r(a, 0.5, 0.6))
    r_big = float(bound.remainder_r(a, 0.1, 0.6))
    assert r_small > 0
    # larger interval -> max over superset -> at least as large
    assert r_big >= r_small - 1e-12


# ------------------------------------------------- reduced-precision storage


def _observed_worst(model, a, lam_c, gamma, big_d):
    """max_λ ‖L_I(λ) − L(λ)‖_F / √D over the Thm 4.7 interval."""
    d = a.shape[0]
    worst = 0.0
    for lam in np.linspace(lam_c - gamma, lam_c + gamma, 9):
        l_i = np.asarray(model.eval_factor(jnp.asarray(lam)), np.float64)
        l_e = jnp.linalg.cholesky(a + lam * jnp.eye(d))
        worst = max(worst, float(np.linalg.norm(l_i - l_e)) / np.sqrt(big_d))
    return worst


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_bound_degrades_as_predicted_under_reduced_precision(seed):
    """Property (mixed-precision satellite): the Thm 4.4/4.7 bound still
    dominates the observed interpolation error when Θ is stored at fp32,
    and under bf16 storage the error grows by at most the storage
    quantization term — degradation as *predicted* (bound + ε·‖Θ‖ Horner
    envelope), never a violation beyond it.  The quantization envelope is
    the triangle inequality over the Horner evaluation: rounding every
    coefficient tile and λ offset to a dtype with unit roundoff ε perturbs
    each packed entry by ≤ ~2ε·Σ_k |Θ_k||λ|^k."""
    from repro.core.precision import tree_astype

    d = 8
    a = _spd(d, seed)
    lam_c, w, gamma = 0.6, 0.15, 0.15
    sample = jnp.linspace(lam_c - w, lam_c + w, 5)
    model = picholesky.fit(a, sample, 2, block=4)
    big_d = d * (d + 1) / 2.0
    rhs = float(bound.picholesky_bound(a, sample, lam_c, gamma))

    worst = {"f64": _observed_worst(model, a, lam_c, gamma, big_d)}
    for tag, dt in (("fp32", jnp.float32), ("bf16", jnp.bfloat16)):
        worst[tag] = _observed_worst(tree_astype(model, dt), a, lam_c,
                                     gamma, big_d)

    # quantization envelope per storage dtype: 2ε · Σ_k ‖Θ_k‖_F · max|λ−c|^k
    lam_max = float(lam_c + gamma)
    theta = np.asarray(model.theta, np.float64)
    envelope = sum(np.linalg.norm(theta[k]) * lam_max ** k
                   for k in range(theta.shape[0])) / np.sqrt(big_d)
    eps = {"fp32": 2.0 ** -24, "bf16": 2.0 ** -8}

    assert worst["f64"] <= rhs * 1.01
    # fp32 storage: quantization is far below the analytic remainder — the
    # bound must still dominate outright
    assert worst["fp32"] <= rhs * 1.01 + 2 * eps["fp32"] * envelope
    assert worst["fp32"] <= rhs * 1.05
    # bf16 storage: error grows (reduced precision is not free)...
    assert worst["bf16"] >= worst["fp32"] - 1e-12
    # ...but stays within bound + the predicted quantization envelope
    assert worst["bf16"] <= rhs * 1.01 + 2 * eps["bf16"] * envelope, \
        (worst, rhs, envelope)
