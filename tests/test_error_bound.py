"""Theorem 4.4/4.7 sanity: the analytic bound dominates the observed error
on random small SPD matrices (exact Fréchet machinery, d ≤ 12)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bound, picholesky


def _spd(d, seed):
    x = np.random.RandomState(seed).randn(3 * d, d)
    return jnp.asarray(x.T @ x / 3.0 + np.eye(d))


@pytest.mark.parametrize("seed", [0, 1])
def test_taylor_factor_converges_cubically(seed):
    d = 8
    a = _spd(d, seed)
    lam_c = jnp.asarray(0.5)
    errs = []
    gammas = [0.2, 0.1, 0.05]
    for g in gammas:
        lam = lam_c + g
        p = bound.taylor_factor(a, lam, lam_c)
        l = jnp.linalg.cholesky(a + lam * jnp.eye(d))
        errs.append(float(jnp.linalg.norm(p - l)))
    # halving γ should shrink error ≈ 8×; allow slack
    assert errs[1] < errs[0] / 4
    assert errs[2] < errs[1] / 4


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_thm47_bound_dominates_observed_error(seed):
    d = 8
    a = _spd(d, seed)
    lam_c, w, gamma = 0.6, 0.15, 0.15
    sample = jnp.linspace(lam_c - w, lam_c + w, 5)
    model = picholesky.fit(a, sample, 2, block=4)
    rhs = float(bound.picholesky_bound(a, sample, lam_c, gamma))
    big_d = d * (d + 1) / 2.0
    worst = 0.0
    for lam in np.linspace(lam_c - gamma, lam_c + gamma, 9):
        l_i = model.eval_factor(jnp.asarray(lam))
        l_e = jnp.linalg.cholesky(a + lam * jnp.eye(d))
        worst = max(worst, float(jnp.linalg.norm(l_i - l_e)) / np.sqrt(big_d))
    assert worst <= rhs * 1.01, (worst, rhs)


def test_remainder_r_positive_and_monotone_interval():
    d = 6
    a = _spd(d, 3)
    r_small = float(bound.remainder_r(a, 0.5, 0.6))
    r_big = float(bound.remainder_r(a, 0.1, 0.6))
    assert r_small > 0
    # larger interval -> max over superset -> at least as large
    assert r_big >= r_small - 1e-12
