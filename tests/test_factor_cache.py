"""Warm-replay factor cache: content-keyed reuse of fitted Θ across sweeps.

The acceptance contract lives here: a second sweep over an overlapping λ
grid with a warm cache performs **zero Cholesky factorizations** — asserted
through the :class:`~repro.core.backends.CountingBackend` hook — and matches
the cold sweep.  The negative half is just as load-bearing: a perturbed
train Hessian, changed anchor grid, dtype, block, or backend MUST miss (no
silent stale hit), and the miss must repopulate correctly.
"""
import os

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import engine, factor_cache, packing, picholesky
from repro.core.backends import CountingBackend, ReferenceBackend
from repro.testing import strategies as props

# shared generators (repro.testing.strategies) — one definition of the
# backend/fold-problem builders across the property suites
_backend = props.make_backend
_folds = props.regression_folds


@pytest.fixture(scope="module")
def folds():
    return _folds()


LAMS = props.log_grid(31)


def _strat(**kw):
    kw.setdefault("g", 4)
    kw.setdefault("block", 8)
    return engine.PiCholeskyStrategy(**kw)


def _train_stats(folds):
    return (folds.hess[None] - folds.fold_hess,
            folds.grad[None] - folds.fold_grad)


# ----------------------------------------------------------- acceptance


def test_warm_sweep_zero_factorizations(folds):
    """ISSUE acceptance: cold run populates; a fresh engine over the same
    grid with the warm cache traces ZERO cholesky calls, reports
    n_exact_chol == 0, and reproduces the cold error grid bit-for-bit."""
    cache = factor_cache.FactorCache()
    cold_bk = CountingBackend(_backend("reference"))
    cold = engine.CVEngine(_strat(), backend=cold_bk, cache=cache)
    r_cold = cold.run(folds, LAMS)
    assert cold_bk.n_cholesky > 0
    assert r_cold.extras["engine"]["cache"]["status"] == "miss"
    assert r_cold.n_exact_chol == 4 * 4
    assert len(cache) == 1 and cache.misses == 1

    warm_bk = CountingBackend(_backend("reference"))
    warm = engine.CVEngine(_strat(), backend=warm_bk, cache=cache)
    r_warm = warm.run(folds, LAMS)
    assert warm_bk.n_cholesky == 0          # the whole point
    assert r_warm.extras["engine"]["cache"]["status"] == "hit"
    assert r_warm.n_exact_chol == 0
    assert cache.hits == 1
    np.testing.assert_array_equal(r_warm.errors, r_cold.errors)


def test_cache_off_and_uncacheable_bypass(folds):
    """cache=None keeps the fused sweep; exact/svd strategies (no
    cache_meta support) bypass the cache even when one is supplied."""
    r = engine.CVEngine(_strat()).run(folds, LAMS)
    assert r.extras["engine"]["cache"] is None
    cache = factor_cache.FactorCache()
    r2 = engine.CVEngine("exact", cache=cache).run(folds, LAMS)
    assert r2.extras["engine"]["cache"]["status"] == "bypass"
    assert len(cache) == 0
    # chol_fn override is opaque — unkeyable, must bypass
    r3 = engine.CVEngine(_strat(chol_fn=jnp.linalg.cholesky),
                         cache=cache).run(folds, LAMS)
    assert r3.extras["engine"]["cache"]["status"] == "bypass"
    np.testing.assert_allclose(r2.errors.shape, r3.errors.shape)


# ------------------------------------------------- warm == cold property


@given(backend=props.backend_names(), q=props.grid_sizes(2, 64),
       chunk=props.lam_chunks())
@settings(max_examples=10, deadline=None)
def test_warm_replay_matches_cold_sweep(backend, q, chunk):
    """Property: for ANY grid over the cached anchor range — denser or
    sparser than the cached one, larger than the anchor count (q > g) or
    smaller, with q % lam_chunk ≠ 0 — the warm replay equals a fresh cold
    sweep on both backends, with zero factorizations traced."""
    folds = _folds(h=24)
    bk = _backend(backend)
    cache = factor_cache.FactorCache()
    engine.CVEngine(_strat(), backend=bk, cache=cache,
                    lam_chunk=chunk).run(folds, LAMS)   # populate

    grid = props.log_grid(q)              # same range ⇒ same derived anchors
    warm_bk = CountingBackend(bk)
    warm = engine.CVEngine(_strat(), backend=warm_bk, cache=cache,
                           lam_chunk=chunk)
    r_warm = warm.run(folds, grid)
    assert warm_bk.n_cholesky == 0
    assert r_warm.extras["engine"]["cache"]["status"] == "hit"

    r_cold = engine.CVEngine(_strat(), backend=bk, lam_chunk=chunk
                             ).run(folds, grid)
    np.testing.assert_allclose(r_warm.errors, r_cold.errors,
                               **props.parity_tol(1e-9, 1e-12))
    props.assert_selection_close(r_warm.errors, r_cold.errors)


def test_subgrid_slice_hits(folds):
    """A strided subset that keeps the endpoints derives the same anchors
    and therefore hits; q=16 is not a multiple of lam_chunk=7."""
    cache = factor_cache.FactorCache()
    engine.CVEngine(_strat(), cache=cache).run(folds, LAMS)
    sub = LAMS[::2]                       # 16 points, endpoints preserved
    r = engine.CVEngine(_strat(), cache=cache, lam_chunk=7).run(folds, sub)
    assert r.extras["engine"]["cache"]["status"] == "hit"
    base = engine.CVEngine(_strat()).run(folds, sub)
    np.testing.assert_allclose(r.errors, base.errors,
                               **props.parity_tol(1e-9, 1e-12))


def test_warmstart_strategy_is_cacheable(folds):
    ws = lambda: engine.PiCholeskyWarmstart(block=8, g_rest=3)  # noqa: E731
    cache = factor_cache.FactorCache()
    r1 = engine.CVEngine(ws(), cache=cache).run(folds, LAMS)
    bk = CountingBackend(_backend("reference"))
    r2 = engine.CVEngine(ws(), backend=bk, cache=cache).run(folds, LAMS)
    assert bk.n_cholesky == 0
    assert r2.extras["engine"]["cache"]["status"] == "hit"
    np.testing.assert_array_equal(r1.errors, r2.errors)


def test_warm_replay_on_mesh(folds):
    """Cache shards follow the folds × lams mesh (conftest forces 4 host
    devices): warm replay under shard_map equals the unsharded sweep."""
    cache = factor_cache.FactorCache()
    r_cold = engine.CVEngine(_strat(), mesh="auto", cache=cache,
                             lam_chunk=3).run(folds, LAMS)
    assert r_cold.extras["engine"]["mesh"] is not None
    warm = engine.CVEngine(_strat(), mesh="auto", cache=cache, lam_chunk=3)
    r_warm = warm.run(folds, LAMS)
    assert r_warm.extras["engine"]["cache"]["status"] == "hit"
    base = engine.CVEngine(_strat()).run(folds, LAMS)
    np.testing.assert_allclose(r_warm.errors, base.errors,
                               **props.parity_tol(1e-8, 1e-12))


# ------------------------------------------------- invalidation (negative)


def _mutations(folds):
    return {
        "perturbed_hessian": dict(folds=_folds(jitter=1e-2)),
        "changed_anchor_range": dict(lams=jnp.logspace(-2, 1, 31)),
        "changed_anchor_count": dict(strat=_strat(g=5)),
        "changed_degree": dict(strat=_strat(degree=3)),
        "changed_block": dict(strat=_strat(block=4)),
        "changed_dtype": dict(folds=_folds(dtype=jnp.float32)),
        "changed_backend": dict(backend=_backend("pallas")),
    }


@pytest.mark.parametrize("mutation", [
    "perturbed_hessian", "changed_anchor_range", "changed_anchor_count",
    "changed_degree", "changed_block", "changed_dtype", "changed_backend"])
def test_fingerprint_mismatch_misses_and_repopulates(folds, mutation):
    """Negative contract: every fingerprint ingredient invalidates.  The
    mutated run MUST miss (no silent stale hit), must equal a fresh cold
    run of the mutated problem, and must add a second entry that then
    serves a hit for the mutated configuration."""
    cache = factor_cache.FactorCache()
    engine.CVEngine(_strat(), cache=cache).run(folds, LAMS)
    assert len(cache) == 1

    mut = _mutations(folds)[mutation]
    m_folds = mut.get("folds", folds)
    m_lams = mut.get("lams", LAMS)
    m_strat = mut.get("strat", _strat())
    m_bk = mut.get("backend", ReferenceBackend())

    r = engine.CVEngine(m_strat, backend=m_bk, cache=cache
                        ).run(m_folds, m_lams)
    assert r.extras["engine"]["cache"]["status"] == "miss", mutation
    assert len(cache) == 2

    fresh = engine.CVEngine(mut.get("strat", _strat()), backend=m_bk
                            ).run(m_folds, m_lams)
    tol = (props.parity_tol(1e-7, 1e-9)
           if m_folds.hess.dtype == jnp.float64   # split vs fused jit can
           else props.parity_tol(3e-5, 1e-6))     # fuse differently in f32
    np.testing.assert_allclose(r.errors, fresh.errors, **tol)

    # the miss repopulated: the same mutated run now hits
    r2 = engine.CVEngine(m_strat, backend=m_bk, cache=cache
                         ).run(m_folds, m_lams)
    assert r2.extras["engine"]["cache"]["status"] == "hit", mutation
    np.testing.assert_array_equal(r2.errors, r.errors)


def test_no_silent_stale_hit_after_perturbation(folds):
    """The stale answer is numerically wrong for the perturbed problem —
    prove the cache never returns it."""
    cache = factor_cache.FactorCache()
    r_orig = engine.CVEngine(_strat(), cache=cache).run(folds, LAMS)
    perturbed = _folds(jitter=5e-2)
    r_pert = engine.CVEngine(_strat(), cache=cache).run(perturbed, LAMS)
    assert r_pert.extras["engine"]["cache"]["status"] == "miss"
    assert not np.allclose(r_pert.errors, r_orig.errors)   # stale ≠ right
    fresh = engine.CVEngine(_strat()).run(perturbed, LAMS)
    np.testing.assert_allclose(r_pert.errors, fresh.errors,
                               **props.parity_tol(1e-9, 1e-12))


def test_reuse_false_is_write_only(folds):
    cache = factor_cache.FactorCache()
    eng = engine.CVEngine(_strat(), cache=cache, reuse=False)
    r1 = eng.run(folds, LAMS)
    r2 = eng.run(folds, LAMS)
    assert {r1.extras["engine"]["cache"]["status"],
            r2.extras["engine"]["cache"]["status"]} == {"miss"}
    assert cache.hits == 0 and len(cache) == 1   # same digest, overwritten
    with pytest.raises(ValueError, match="reuse"):
        engine.CVEngine(_strat(), cache=cache, reuse="bogus")


# ----------------------------------------------- covering + anchor reuse


def test_covering_policy_serves_subrange(folds):
    """reuse='covering' replays a cached Θ whose anchor range covers the
    requested grid; 'exact' refuses the same request.  The replayed values
    equal solving straight from the cached interpolant."""
    cache = factor_cache.FactorCache()
    engine.CVEngine(_strat(), cache=cache).run(folds, LAMS)
    sub = jnp.logspace(-2, 1, 21)

    bk = CountingBackend(_backend("reference"))
    cov = engine.CVEngine(_strat(), backend=bk, cache=cache,
                          reuse="covering")
    r = cov.run(folds, sub)
    assert r.extras["engine"]["cache"]["status"] == "hit"
    assert bk.n_cholesky == 0

    # oracle: per-fold interp_solve from the cached state, no engine
    entry = next(iter(cache.entries.values()))
    _, g_tr = _train_stats(folds)
    errs = []
    for f in range(4):
        model = picholesky.PiCholesky(theta=entry.state.theta[f],
                                      center=entry.state.center[f],
                                      h=entry.state.h,
                                      block=entry.state.block)
        thetas = model.solve(sub, g_tr[f])
        pred = jnp.einsum("nh,qh->qn", folds.x_folds[f], thetas)
        mse = jnp.mean((pred - folds.y_folds[f][None]) ** 2, axis=1)
        errs.append(jnp.sqrt(mse) / (jnp.std(folds.y_folds[f]) + 1e-30))
    np.testing.assert_allclose(r.errors, np.mean(errs, axis=0),
                               **props.parity_tol(1e-9, 1e-12))

    r_exact = engine.CVEngine(_strat(), cache=cache, reuse="exact"
                              ).run(folds, sub)
    assert r_exact.extras["engine"]["cache"]["status"] == "miss"


def test_covering_serves_tightest_range_and_reports_it(folds):
    """With several covering entries, the narrowest anchor range wins (its
    Θ answers the sub-range most accurately) and the result carries the
    SERVED entry's digest, not the requested key's."""
    cache = factor_cache.FactorCache()
    wide = jnp.logspace(-5, 4, 31)
    narrow = jnp.logspace(-3, 2, 31)
    engine.CVEngine(_strat(), cache=cache).run(folds, wide)    # inserted 1st
    engine.CVEngine(_strat(), cache=cache).run(folds, narrow)
    narrow_digest = [e.key.digest() for e in cache.entries.values()
                     if max(e.key.anchors) < 1e3]
    assert len(narrow_digest) == 1

    sub = jnp.logspace(-2, 1, 11)           # covered by both
    r = engine.CVEngine(_strat(), cache=cache, reuse="covering"
                        ).run(folds, sub)
    info = r.extras["engine"]["cache"]
    assert info["status"] == "hit"
    assert info["digest"] == narrow_digest[0][:12]


def test_anchor_refit_skips_factorization(folds):
    """cache_anchors=True stores the per-(fold, λ_s) packed factors; a
    degree change over the same anchors refits Θ from them — status
    'refit', zero factorizations, same answer as a cold degree-3 fit."""
    cache = factor_cache.FactorCache()
    engine.CVEngine(_strat(degree=2), cache=cache,
                    cache_anchors=True).run(folds, LAMS)
    entry = next(iter(cache.entries.values()))
    assert isinstance(entry.anchors, packing.PackedFactor)
    assert entry.anchors.vec.shape == (4, 4, packing.packed_size(32, 8))

    bk = CountingBackend(_backend("reference"))
    eng = engine.CVEngine(_strat(degree=3), backend=bk, cache=cache,
                          cache_anchors=True)
    r = eng.run(folds, LAMS)
    assert r.extras["engine"]["cache"]["status"] == "refit"
    assert bk.n_cholesky == 0 and r.n_exact_chol == 0
    fresh = engine.CVEngine(_strat(degree=3)).run(folds, LAMS)
    if props.active_precision().is_native:
        np.testing.assert_allclose(r.errors, fresh.errors,
                                   rtol=1e-7, atol=1e-9)
    else:
        # a degree-3 monomial fit at an fp32 fit dtype is ill-conditioned
        # at the top of the λ decades — refit and cold legitimately diverge
        # there; the contract that must survive is equivalent selection
        props.assert_selection_close(r.errors, fresh.errors)
    assert len(cache) == 2                  # refit result cached too
    r2 = engine.CVEngine(_strat(degree=3), cache=cache).run(folds, LAMS)
    assert r2.extras["engine"]["cache"]["status"] == "hit"


# ------------------------------------------------------- byte-budget LRU


def _one_entry_bytes(folds, **kw):
    """Array payload of a single cached entry for this problem size."""
    probe = factor_cache.FactorCache()
    engine.CVEngine(_strat(), cache=probe, **kw).run(folds, LAMS)
    return probe.total_bytes


def test_byte_budget_lru_evicts_oldest(folds):
    """Three same-size entries against a two-entry budget: the oldest is
    evicted, counters and stats() report it, and the evicted configuration
    MISSES and repopulates — identical to a fresh cold run, never stale."""
    one = _one_entry_bytes(folds)
    cache = factor_cache.FactorCache(max_bytes=2 * one + one // 2)
    for g in (4, 5, 6):     # Θ is (degree+1, P): same payload per entry
        engine.CVEngine(_strat(g=g), cache=cache).run(folds, LAMS)
    assert len(cache) == 2 and cache.evictions == 1
    assert cache.total_bytes <= cache.max_bytes
    assert cache.stats["evictions"] == 1
    assert cache.stats["bytes"] == cache.total_bytes
    assert cache.stats["max_bytes"] == cache.max_bytes

    r = engine.CVEngine(_strat(g=4), cache=cache).run(folds, LAMS)
    assert r.extras["engine"]["cache"]["status"] == "miss"
    fresh = engine.CVEngine(_strat(g=4)).run(folds, LAMS)
    np.testing.assert_allclose(r.errors, fresh.errors,
                               **props.parity_tol(1e-7, 1e-9))
    assert cache.evictions == 2          # repopulation displaced the next LRU


def test_lru_clock_respects_hits(folds):
    """A hit refreshes an entry's recency: the un-hit sibling is the one
    displaced by the next insert."""
    one = _one_entry_bytes(folds)
    cache = factor_cache.FactorCache(max_bytes=2 * one + one // 2)
    engine.CVEngine(_strat(g=4), cache=cache).run(folds, LAMS)   # A
    engine.CVEngine(_strat(g=5), cache=cache).run(folds, LAMS)   # B
    engine.CVEngine(_strat(g=4), cache=cache).run(folds, LAMS)   # hit A
    engine.CVEngine(_strat(g=6), cache=cache).run(folds, LAMS)   # C evicts B
    assert engine.CVEngine(_strat(g=4), cache=cache).run(
        folds, LAMS).extras["engine"]["cache"]["status"] == "hit"
    assert engine.CVEngine(_strat(g=5), cache=cache).run(
        folds, LAMS).extras["engine"]["cache"]["status"] == "miss"


def test_budget_smaller_than_one_entry_keeps_newest(folds):
    """The entry being written always survives (capacity degrades to one,
    writes are never refused); max_bytes=0 is rejected."""
    cache = factor_cache.FactorCache(max_bytes=1)
    engine.CVEngine(_strat(), cache=cache).run(folds, LAMS)
    assert len(cache) == 1
    engine.CVEngine(_strat(g=5), cache=cache).run(folds, LAMS)
    assert len(cache) == 1 and cache.evictions == 1
    assert engine.CVEngine(_strat(g=5), cache=cache).run(
        folds, LAMS).extras["engine"]["cache"]["status"] == "hit"
    with pytest.raises(ValueError, match="max_bytes"):
        factor_cache.FactorCache(max_bytes=0)


def test_eviction_purges_anchor_index(folds):
    """Evicting an entry drops its cached anchor factors too: a later
    degree change over the same anchors must run cold ('miss'), not refit
    from a purged PackedFactor ('refit')."""
    one = _one_entry_bytes(folds, cache_anchors=True)
    cache = factor_cache.FactorCache(max_bytes=one + one // 2)
    eng = engine.CVEngine(_strat(degree=2), cache=cache, cache_anchors=True)
    eng.run(folds, LAMS)
    # a different problem displaces the entry (and its anchors)
    engine.CVEngine(_strat(degree=2), cache=cache, cache_anchors=True
                    ).run(_folds(jitter=1e-2), LAMS)
    assert cache.evictions == 1 and len(cache) == 1
    r = engine.CVEngine(_strat(degree=3), cache=cache, cache_anchors=True
                        ).run(folds, LAMS)
    assert r.extras["engine"]["cache"]["status"] == "miss"
    fresh = engine.CVEngine(_strat(degree=3)).run(folds, LAMS)
    np.testing.assert_allclose(r.errors, fresh.errors,
                               **props.parity_tol(1e-7, 1e-9))


def test_eviction_purges_covering_index(folds):
    """The 'covering' route cannot resolve to an evicted digest: a
    sub-range only the evicted wide entry covered misses cleanly, while a
    sub-range the surviving entry covers still hits."""
    one = _one_entry_bytes(folds)
    cache = factor_cache.FactorCache(max_bytes=one + one // 2)
    engine.CVEngine(_strat(), cache=cache).run(folds, jnp.logspace(-5, 4, 31))
    engine.CVEngine(_strat(), cache=cache).run(folds, LAMS)   # evicts wide
    assert cache.evictions == 1
    r_wide_sub = engine.CVEngine(_strat(), cache=cache, reuse="covering"
                                 ).run(folds, jnp.logspace(-4.5, 3, 11))
    assert r_wide_sub.extras["engine"]["cache"]["status"] == "miss"
    r_narrow_sub = engine.CVEngine(_strat(), cache=cache, reuse="covering"
                                   ).run(folds, jnp.logspace(-2, 1, 11))
    assert r_narrow_sub.extras["engine"]["cache"]["status"] == "hit"


@pytest.mark.tier2
@given(n_keep=st.integers(1, 3), backend=props.backend_names())
@settings(max_examples=6, deadline=None)
def test_eviction_never_serves_stale(n_keep, backend):
    """Property: under any budget, after any eviction/repopulation history,
    every configuration's result equals its fresh cold run — an evicted
    digest can only miss, never alias another entry."""
    folds = _folds(h=24)
    bk = _backend(backend)
    probe = factor_cache.FactorCache()
    engine.CVEngine(_strat(), backend=bk, cache=probe).run(folds, LAMS)
    one = probe.total_bytes
    cache = factor_cache.FactorCache(max_bytes=n_keep * one + one // 2)
    gs = [4, 5, 6, 7]
    for g in gs:
        engine.CVEngine(_strat(g=g), backend=bk, cache=cache
                        ).run(folds, LAMS)
    assert len(cache) == n_keep
    assert cache.evictions == len(gs) - n_keep
    for g in gs:
        r = engine.CVEngine(_strat(g=g), backend=bk, cache=cache
                            ).run(folds, LAMS)
        fresh = engine.CVEngine(_strat(g=g), backend=bk).run(folds, LAMS)
        np.testing.assert_allclose(r.errors, fresh.errors,
                                   **props.parity_tol(1e-7, 1e-9))


def test_budgeted_load_applies_lru(folds, tmp_path):
    """Reloading a persisted cache under a budget keeps only what fits,
    and the survivors still replay bit-for-bit."""
    cache = factor_cache.FactorCache()
    engine.CVEngine(_strat(g=4), cache=cache).run(folds, LAMS)
    engine.CVEngine(_strat(g=5), cache=cache).run(folds, LAMS)
    cache.save(str(tmp_path))
    one = cache.total_bytes // 2
    loaded = factor_cache.FactorCache.load(str(tmp_path),
                                           max_bytes=one + one // 2)
    assert len(loaded) == 1 and loaded.evictions == 1
    # which g survived is a detail of the load order (digest sort); the
    # survivor must HIT, the evictee MISS.  Query the survivor first — a
    # miss repopulates by design and would evict it under this budget.
    survivor = dict(next(iter(loaded.entries.values())).key.params)["g"]
    evictee = ({4, 5} - {survivor}).pop()
    r_hit = engine.CVEngine(_strat(g=survivor), cache=loaded).run(folds, LAMS)
    assert r_hit.extras["engine"]["cache"]["status"] == "hit"
    r_miss = engine.CVEngine(_strat(g=evictee), cache=loaded).run(folds, LAMS)
    assert r_miss.extras["engine"]["cache"]["status"] == "miss"


# ------------------------------------------------------------ persistence


def test_cache_save_load_sweep_parity_bitwise(folds, tmp_path):
    """save → load → warm sweep is bit-for-bit identical to the in-memory
    warm sweep on the reference backend (satellite: checkpoint round-trip
    through repro.checkpoint.CheckpointManager)."""
    cache = factor_cache.FactorCache()
    engine.CVEngine(_strat(), cache=cache, cache_anchors=True
                    ).run(folds, LAMS)
    cache.save(str(tmp_path))
    loaded = factor_cache.FactorCache.load(str(tmp_path))
    assert sorted(loaded.entries) == sorted(cache.entries)
    (orig,), (back,) = cache.entries.values(), loaded.entries.values()
    np.testing.assert_array_equal(orig.state.theta, back.state.theta)
    np.testing.assert_array_equal(orig.anchors.vec, back.anchors.vec)
    assert (back.state.h, back.state.block) == (orig.state.h,
                                                orig.state.block)

    r_mem = engine.CVEngine(_strat(), cache=cache).run(folds, LAMS)
    r_disk = engine.CVEngine(_strat(), cache=loaded).run(folds, LAMS)
    assert r_disk.extras["engine"]["cache"]["status"] == "hit"
    np.testing.assert_array_equal(r_mem.errors, r_disk.errors)


def test_cache_load_skips_corrupt_entries(folds, tmp_path):
    """A torn write (corrupted leaf) drops that entry on load — never a
    half-loaded state — while intact entries survive."""
    cache = factor_cache.FactorCache()
    engine.CVEngine(_strat(), cache=cache).run(folds, LAMS)
    engine.CVEngine(_strat(g=5), cache=cache).run(folds, LAMS)
    cache.save(str(tmp_path))
    victim = os.path.join(str(tmp_path), "step_000000000000",
                          "leaf_000000.npy")
    with open(victim, "r+b") as f:
        f.seek(128)
        f.write(b"\xde\xad\xbe\xef")
    loaded = factor_cache.FactorCache.load(str(tmp_path))
    assert len(loaded) == 1
    assert len(factor_cache.FactorCache.load(str(tmp_path / "nowhere"))) == 0


def test_cache_resave_never_rewrites_referenced_steps(folds, tmp_path):
    """Re-saving a grown cache takes fresh step numbers (a torn second
    save must leave the first index's steps untouched), prunes only after
    the index flips, and the final state loads completely."""
    from repro.checkpoint import CheckpointManager

    cache = factor_cache.FactorCache()
    engine.CVEngine(_strat(), cache=cache).run(folds, LAMS)
    cache.save(str(tmp_path))
    first_steps = set(CheckpointManager(str(tmp_path), keep=None).all_steps())

    engine.CVEngine(_strat(g=5), cache=cache).run(folds, LAMS)
    engine.CVEngine(_strat(g=6), cache=cache).run(folds, LAMS)
    cache.save(str(tmp_path))
    second_steps = set(CheckpointManager(str(tmp_path), keep=None).all_steps())
    assert not (first_steps & second_steps)      # never rewritten in place
    loaded = factor_cache.FactorCache.load(str(tmp_path))
    assert sorted(loaded.entries) == sorted(cache.entries)
    r1 = engine.CVEngine(_strat(g=6), cache=cache).run(folds, LAMS)
    r2 = engine.CVEngine(_strat(g=6), cache=loaded).run(folds, LAMS)
    np.testing.assert_array_equal(r1.errors, r2.errors)


def test_cache_key_fingerprint_fields(folds):
    h_tr, _ = _train_stats(folds)
    meta = _strat().cache_meta(LAMS)
    key = factor_cache.make_key(h_tr, meta["anchors"], block=8,
                                backend="reference", params=meta["params"])
    assert len(key.fold_hashes) == 4 and key.h == 32
    assert key.dtype == "float64" and key.backend == "reference"
    # digest is content-derived and stable across reconstruction
    key2 = factor_cache.CacheKey.from_json(key.to_json())
    assert key2.digest() == key.digest()
    # anchor digest ignores the polynomial, base digest ignores anchors
    meta3 = _strat(degree=3).cache_meta(LAMS)
    key3 = factor_cache.make_key(h_tr, meta3["anchors"], block=8,
                                 backend="reference", params=meta3["params"])
    assert key3.digest() != key.digest()
    assert key3.anchor_digest() == key.anchor_digest()
    assert key3.base_digest() != key.base_digest()


# ------------------------------------------------------- byte accounting


def test_bytes_saved_survives_eviction():
    """Regression: ``stats['bytes_saved']`` was derived from live entries
    only, so evicting a bf16 entry retroactively shrank the reported
    savings.  It is a cumulative counter now (like hits/evictions);
    ``live_bytes_saved`` keeps the old live-entries meaning."""
    folds32 = _folds(dtype=jnp.float32)
    cache = factor_cache.FactorCache()
    engine.CVEngine(_strat(), cache=cache, cache_anchors=True,
                    precision="bf16_store").run(folds32, LAMS)
    one = next(iter(cache.entries.values()))
    saved_one = one.bytes_saved
    assert saved_one > 0                      # bf16 storage shrank fp32 data

    budget = factor_cache.FactorCache(max_bytes=2 * one.nbytes +
                                      one.nbytes // 2)
    for seed in (1, 2, 3):                    # same payload size per entry
        engine.CVEngine(_strat(), cache=budget, cache_anchors=True,
                        precision="bf16_store").run(
            _folds(seed=seed, dtype=jnp.float32), LAMS)
    assert budget.evictions == 1 and len(budget) == 2
    # cumulative: all three puts' savings, eviction does not claw back
    assert budget.stats["bytes_saved"] == 3 * saved_one
    # live: only the two surviving entries
    assert budget.stats["live_bytes_saved"] == 2 * saved_one
    assert budget.live_bytes_saved == sum(
        e.bytes_saved for e in budget.entries.values())

    # native-precision data stored at its own dtype saves nothing, evicted
    # or not
    native = factor_cache.FactorCache()
    engine.CVEngine(_strat(), cache=native).run(_folds(), LAMS)
    assert native.stats["bytes_saved"] == 0
    assert native.stats["live_bytes_saved"] == 0
