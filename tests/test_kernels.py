"""Per-kernel shape/dtype sweeps against the pure-jnp oracles (ref.py).

All Pallas kernels run in interpret mode on CPU (the TPU lowering is the
same kernel body with real BlockSpecs).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import packing, picholesky
from repro.kernels import ref
from repro.kernels.chol_blocked import cholesky_blocked
from repro.kernels.packed_trsm import solve_lower_packed, solve_packed
from repro.kernels.poly_interp import interp_factors, interp_solve
from repro.kernels.tri_pack import pack_tril, unpack_tril
from repro.kernels.trsm import solve_lower_blocked, solve_factor_sweep


def _spd(h, dtype, seed=0):
    x = jax.random.normal(jax.random.PRNGKey(seed), (2 * h, h), jnp.float32)
    a = x.T @ x + h * jnp.eye(h)
    return a.astype(dtype)


@pytest.mark.parametrize("h", [16, 24, 37, 64])
@pytest.mark.parametrize("block", [8, 16])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
def test_tri_pack_kernel(h, block, dtype):
    m = jax.random.normal(jax.random.PRNGKey(h), (h, h), jnp.float32).astype(dtype)
    v = pack_tril(m, block)
    np.testing.assert_allclose(v, ref.pack_tril(m, block), rtol=1e-6)
    back = unpack_tril(v, h, block)
    np.testing.assert_allclose(back, jnp.tril(m), rtol=1e-6)


@pytest.mark.parametrize("h,block", [(16, 8), (37, 8), (64, 16), (100, 16)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
def test_cholesky_kernel(h, block, dtype):
    a = _spd(h, dtype)
    l = cholesky_blocked(a, block=block)
    l_ref = ref.cholesky(a)
    tol = 5e-5 if dtype == jnp.float32 else 1e-10
    err = float(jnp.max(jnp.abs(l - l_ref)) / jnp.max(jnp.abs(l_ref)))
    assert err < tol


@given(h=st.sampled_from([16, 33, 48]), seed=st.integers(0, 100))
@settings(max_examples=10, deadline=None)
def test_cholesky_kernel_property(h, seed):
    """L Lᵀ must reconstruct A (system invariant, any SPD input)."""
    a = _spd(h, jnp.float64, seed)
    l = cholesky_blocked(a, block=8)
    np.testing.assert_allclose(l @ l.T, a, rtol=1e-8, atol=1e-8)


@pytest.mark.parametrize("h,block,q", [(32, 8, 1), (37, 8, 5), (64, 16, 31)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
def test_trsm_kernel(h, block, q, dtype):
    a = _spd(h, dtype)
    l = jnp.linalg.cholesky(a)
    g = jax.random.normal(jax.random.PRNGKey(1), (h, q), jnp.float32).astype(dtype)
    tol = 1e-3 if dtype == jnp.float32 else 1e-9
    w = solve_lower_blocked(l, g, block)
    np.testing.assert_allclose(w, ref.solve_lower(l, g), rtol=tol, atol=tol)
    t = solve_lower_blocked(l, w, block, transpose=True)
    np.testing.assert_allclose(t, ref.solve_lower(l, w, transpose=True),
                               rtol=tol, atol=tol)


def test_solve_factor_sweep_kernel():
    h, q = 48, 7
    a = _spd(h, jnp.float32)
    lams = jnp.logspace(-2, 0, q)
    ls = jax.vmap(lambda lam: jnp.linalg.cholesky(a + lam * jnp.eye(h)))(lams)
    g = jax.random.normal(jax.random.PRNGKey(3), (h,), jnp.float32)
    thetas = solve_factor_sweep(ls, g, block=16)
    np.testing.assert_allclose(thetas, ref.solve_factor_sweep(ls, g),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("h,block,q", [(16, 8, 1), (37, 8, 5), (64, 16, 3)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
def test_packed_trsm_kernel(h, block, q, dtype):
    """Packed-domain trsm ≡ the pure-jnp packed oracle, both sweeps."""
    a = _spd(h, dtype)
    l = jnp.linalg.cholesky(a.astype(jnp.float64)).astype(dtype)
    vec = packing.pack_tril(l, block)
    g = jax.random.normal(jax.random.PRNGKey(1), (h, q),
                          jnp.float32).astype(dtype)
    tol = 1e-3 if dtype == jnp.float32 else 1e-9
    for transpose in (False, True):
        w = solve_lower_packed(vec, g, h, block, transpose=transpose)
        np.testing.assert_allclose(
            w, ref.solve_lower_packed(vec, g, h, block, transpose=transpose),
            rtol=tol, atol=tol)
    th = solve_packed(vec, g[:, 0], h, block)
    np.testing.assert_allclose(th, ref.solve_packed(vec, g[:, 0], h, block),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("h,block,degree", [(32, 8, 2), (48, 16, 3)])
def test_interp_solve_kernel(h, block, degree):
    """Fused Horner + packed substitution ≡ eval_packed → packed solve."""
    a = _spd(h, jnp.float64)
    sample = picholesky.choose_sample_lambdas(1e-2, 1.0, degree + 3)
    model = picholesky.fit(a, sample, degree, block=block)
    lams = jnp.logspace(-2, 0, 9)
    g = jax.random.normal(jax.random.PRNGKey(5), (h,), jnp.float64)
    out = interp_solve(model.theta, lams, g, h, block, center=model.center)
    expect = ref.interp_solve(model.theta, lams, g, h, block,
                              center=model.center)
    np.testing.assert_allclose(out, expect, rtol=1e-8, atol=1e-8)
    # and against the exact dense solves at the sample nodes themselves,
    # where the interpolant passes through the data (g > degree fit is
    # least-squares, so compare interpolant-to-interpolant elsewhere)
    dense = model.eval_factor(lams)
    exact = jax.vmap(lambda l: ref.solve_lower(
        l, ref.solve_lower(l, g), transpose=True))(dense)
    np.testing.assert_allclose(out, exact, rtol=1e-6, atol=1e-8)


@pytest.mark.parametrize("h,block,degree", [(32, 8, 2), (48, 16, 3)])
def test_poly_interp_kernel(h, block, degree):
    a = _spd(h, jnp.float32)
    sample = picholesky.choose_sample_lambdas(1e-2, 1.0, degree + 3)
    model = picholesky.fit(a, sample, degree, block=block)
    lams = jnp.logspace(-2, 0, 9)
    out = interp_factors(model.theta, lams, h, block, center=model.center)
    expect = ref.interp_factors(model.theta, lams, h, block)
    np.testing.assert_allclose(out, expect, rtol=1e-5, atol=1e-5)


def test_end_to_end_kernel_pipeline():
    """chol kernel -> pack kernel -> fit -> fused interp -> trsm solve,
    matching the all-jnp pipeline."""
    h, block = 64, 16
    a = _spd(h, jnp.float32)
    g = jax.random.normal(jax.random.PRNGKey(9), (h,), jnp.float32)
    sample = picholesky.choose_sample_lambdas(1e-2, 1.0, 5)
    eye = jnp.eye(h)
    factors = jax.vmap(lambda lam: cholesky_blocked(a + lam * eye, block=block)
                       )(sample)
    model = picholesky.fit(a, sample, 2, block=block, factors=factors)
    lams = jnp.logspace(-2, 0, 5)
    ls = interp_factors(model.theta, lams, h, block, center=model.center)
    thetas = solve_factor_sweep(ls, g, block=block)
    expect = jax.vmap(
        lambda lam: ref.solve_lower(
            jnp.linalg.cholesky(a + lam * eye),
            ref.solve_lower(jnp.linalg.cholesky(a + lam * eye), g),
            transpose=True))(lams)
    np.testing.assert_allclose(thetas, expect, rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("shape", [(2, 32, 16, 4, 8, 8), (1, 64, 32, 8, 16, 16)])
def test_ssm_scan_kernel(shape):
    from repro.kernels.ssm_scan import ssm_scan
    b, s, di, n, chunk, dblk = shape
    ks = jax.random.split(jax.random.PRNGKey(0), 6)
    xc = jax.random.normal(ks[0], (b, s, di))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, di)))
    bm = jax.random.normal(ks[2], (b, s, n))
    cm = jax.random.normal(ks[3], (b, s, n))
    a = -jnp.exp(jax.random.normal(ks[4], (di, n)) * 0.3)
    d = jax.random.normal(ks[5], (di,))
    y_k, h_k = ssm_scan(xc, dt, bm, cm, a, d, chunk=chunk, di_block=dblk)
    y_r, h_r = ref.ssm_scan(xc, dt, bm, cm, a, d)
    np.testing.assert_allclose(y_k, y_r, atol=1e-4)
    np.testing.assert_allclose(h_k, h_r, atol=1e-4)
