"""Custom-vjp layer primitives vs naive AD oracles (flash attention,
linear recurrence, rms_norm) — values AND gradients."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.models import layers


# ------------------------------------------------------------ flash attn


def _dense_attn(q, k, v, causal, window, n_rep):
    kk, vv = layers._repeat_kv(k, n_rep), layers._repeat_kv(v, n_rep)
    s_len = q.shape[1]
    s = jnp.einsum("bqhd,bkhd->bqhk", q, kk) / np.sqrt(q.shape[-1])
    qp = jnp.arange(s_len)[:, None]
    kp = jnp.arange(s_len)[None, :]
    mask = jnp.ones((s_len, s_len), bool)
    if causal:
        mask &= qp >= kp
    if window:
        mask &= qp - kp < window
    s = jnp.where(mask[None, :, None, :], s, -jnp.inf)
    return jnp.einsum("bqhk,bkhd->bqhd", jax.nn.softmax(s, -1), vv)


@pytest.mark.parametrize("causal,window", [(True, None), (True, 24),
                                           (False, None)])
def test_flash_attention_matches_dense(causal, window):
    key = jax.random.PRNGKey(0)
    b, s, h, kv, hd = 2, 70, 4, 2, 16
    q = jax.random.normal(key, (b, s, h, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, kv, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, kv, hd))

    out = layers.flash_attention(q, k, v, causal=causal, window=window,
                                 chunk=32)
    ref = _dense_attn(q, k, v, causal, window, h // kv)
    np.testing.assert_allclose(out, ref, atol=2e-5)

    f = lambda *a: layers.flash_attention(*a, causal=causal, window=window,
                                          chunk=32).sum()
    g = lambda *a: _dense_attn(*a, causal, window, h // kv).sum()
    gf = jax.grad(f, (0, 1, 2))(q, k, v)
    gg = jax.grad(g, (0, 1, 2))(q, k, v)
    for a, b_ in zip(gf, gg):
        np.testing.assert_allclose(a, b_, atol=1e-4)


@given(s=st.sampled_from([17, 33, 64]), chunk=st.sampled_from([8, 16, 32]))
@settings(max_examples=8, deadline=None)
def test_flash_attention_chunk_invariance(s, chunk):
    """Output must not depend on the chunking (system invariant)."""
    key = jax.random.PRNGKey(s)
    q = jax.random.normal(key, (1, s, 2, 8))
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, s, 2, 8))
    v = jax.random.normal(jax.random.fold_in(key, 2), (1, s, 2, 8))
    a = layers.flash_attention(q, k, v, causal=True, chunk=chunk)
    b = layers.flash_attention(q, k, v, causal=True, chunk=s)
    np.testing.assert_allclose(a, b, atol=2e-5)


# ------------------------------------------------------------ recurrence


def _naive_recurrence(a, b, h0):
    def step(h, ab):
        h = ab[0] * h + ab[1]
        return h, h
    h_last, hs = jax.lax.scan(step, h0, (jnp.moveaxis(a, 1, 0),
                                         jnp.moveaxis(b, 1, 0)))
    return jnp.moveaxis(hs, 0, 1), h_last


@pytest.mark.parametrize("s,chunk", [(24, 8), (30, 8), (16, 16)])
def test_recurrence_matches_naive(s, chunk):
    key = jax.random.PRNGKey(0)
    a = jax.random.uniform(key, (2, s, 5), minval=0.3, maxval=0.99)
    b = jax.random.normal(jax.random.fold_in(key, 1), (2, s, 5))
    h0 = jax.random.normal(jax.random.fold_in(key, 2), (2, 5))
    hs, hl = layers.chunked_linear_recurrence(a, b, h0, chunk)
    hs_n, hl_n = _naive_recurrence(a, b, h0)
    np.testing.assert_allclose(hs, hs_n, atol=1e-4)
    np.testing.assert_allclose(hl, hl_n, atol=1e-4)

    def f(a, b, h0):
        hs, hl = layers.chunked_linear_recurrence(a, b, h0, chunk)
        return (hs ** 2).sum() + (hl * 3).sum()

    def g(a, b, h0):
        hs, hl = _naive_recurrence(a, b, h0)
        return (hs ** 2).sum() + (hl * 3).sum()

    gf = jax.grad(f, (0, 1, 2))(a, b, h0)
    gg = jax.grad(g, (0, 1, 2))(a, b, h0)
    for x, y in zip(gf, gg):
        np.testing.assert_allclose(x, y, atol=1e-3)


# ------------------------------------------------------------ rms_norm


def test_rms_norm_grads_match_naive():
    def naive(x, s, eps=1e-6):
        xf = x.astype(jnp.float32)
        var = jnp.mean(xf * xf, -1, keepdims=True)
        return (xf * jax.lax.rsqrt(var + eps)
                * (1 + s.astype(jnp.float32))).astype(x.dtype)

    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (4, 32), jnp.float32)
    s = jax.random.normal(jax.random.fold_in(key, 1), (32,)) * 0.1
    np.testing.assert_allclose(layers.rms_norm(x, s), naive(x, s), atol=1e-5)
    g1 = jax.grad(lambda x, s: (layers.rms_norm(x, s) ** 2).sum(), (0, 1))(x, s)
    g2 = jax.grad(lambda x, s: (naive(x, s) ** 2).sum(), (0, 1))(x, s)
    np.testing.assert_allclose(g1[0], g2[0], atol=1e-4)
    np.testing.assert_allclose(g1[1], g2[1], atol=1e-4)


def test_rms_norm_cotangent_dtype_preserved():
    x = jnp.ones((2, 16), jnp.bfloat16)
    s = jnp.zeros((16,), jnp.bfloat16)
    dx = jax.grad(lambda x: layers.rms_norm(x, s).astype(jnp.float32).sum())(x)
    assert dx.dtype == jnp.bfloat16


# ------------------------------------------------------------ conv


def test_causal_conv_streaming_matches_full():
    """Processing a sequence in two halves with carried state == one pass."""
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (2, 20, 6))
    w = jax.random.normal(jax.random.fold_in(key, 1), (6, 4))
    full, _ = layers.causal_conv1d(x, w)
    y1, st = layers.causal_conv1d(x[:, :9], w)
    y2, _ = layers.causal_conv1d(x[:, 9:], w, st)
    np.testing.assert_allclose(jnp.concatenate([y1, y2], 1), full, atol=1e-5)
