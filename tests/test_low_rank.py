"""Low-rank ACV strategy (Stephenson et al., arXiv:2008.10547) for rank-r
designs in the n ≪ h regime.

Contracts:

* **algebra** — the spectral sweep is the Woodbury form of
  (XᵀX + λI)⁻¹Xᵀy: exact (to rounding) against a dense Cholesky solve
  at full rank, with rank truncation degrading gracefully toward it on a
  planted low-rank design (zeroed-eval form: no catastrophic
  cancellation, see :class:`repro.core.solvers.LowRankFactors`);
* **engine** — ``CVEngine('low_rank')`` matches the exact strategy's
  hold-out curve and λ* with ZERO Cholesky factorizations;
* **cache** — λ-independent factors key with EMPTY anchors (any grid
  over the same folds hits), carry the ``lowrank/…`` descriptor so they
  can never serve an exact or sketched request, persist through
  save/load bitwise, and invalidate on rank or Hessian perturbation;
* **downstream unchanged** — λ-chunking, the async sweep, adaptive
  search, and both backends consume the low-rank state unchanged.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings

from repro.core import engine, factor_cache, solvers
from repro.core.backends import CountingBackend
from repro.data import make_low_rank_dataset
from repro.testing import strategies as props

LAMS = props.log_grid(17)


@pytest.fixture(scope="module")
def folds():
    return props.low_rank_folds()          # h=96, n=32, k=4, planted rank 8


def _train_design(folds, f=0):
    x = np.asarray(folds.x_folds)
    y = np.asarray(folds.y_folds)
    keep = [i for i in range(x.shape[0]) if i != f]
    return (jnp.asarray(np.concatenate([x[i] for i in keep])),
            jnp.asarray(np.concatenate([y[i] for i in keep])))


# ---------------------------------------------------------------- algebra


def test_factors_keep_full_vt_and_zero_truncated_evals(folds):
    """Rank truncation zeroes evals but keeps every right singular vector
    — the cancellation-free representation the sweep depends on."""
    x, _ = _train_design(folds)
    r0 = min(x.shape)
    full = solvers.lowrank_ridge_factors(x)
    assert full.vt.shape == (r0, x.shape[1])
    assert full.evals.shape == (r0,)
    assert float(full.evals.min()) > 0

    trunc = solvers.lowrank_ridge_factors(x, rank=5)
    assert trunc.vt.shape == (r0, x.shape[1])      # vt NOT truncated
    np.testing.assert_array_equal(np.asarray(trunc.evals[5:]), 0.0)
    np.testing.assert_array_equal(np.asarray(trunc.evals[:5]),
                                  np.asarray(full.evals[:5]))
    # vt rows stay orthonormal
    gram = np.asarray(full.vt @ full.vt.T)
    np.testing.assert_allclose(gram, np.eye(r0), atol=1e-10)


def test_sweep_is_woodbury_exact_at_full_rank(folds):
    """Full-rank spectral sweep == dense (XᵀX + λI)⁻¹Xᵀy for every λ."""
    x, y = _train_design(folds)
    h_tr, g_tr = x.T @ x, x.T @ y
    fac = solvers.lowrank_ridge_factors(x)
    got = solvers.lowrank_ridge_sweep(fac, g_tr, LAMS)
    eye = jnp.eye(x.shape[1], dtype=x.dtype)
    want = jnp.stack([jnp.linalg.solve(h_tr + lam * eye, g_tr)
                      for lam in np.asarray(LAMS)])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-8, atol=1e-10)


def test_truncated_directions_solve_at_one_over_lambda(folds):
    """A rank-r sweep equals the spectral formula with the truncated
    curvature treated as zero: those directions of g pass through at 1/λ
    — the zeroed-eval expression computes this without any subtraction."""
    x, y = _train_design(folds)
    g_tr = x.T @ y
    r = 6
    fac = solvers.lowrank_ridge_factors(x, rank=r)
    lam = jnp.asarray(0.37)
    got = solvers.lowrank_ridge_sweep(fac, g_tr, lam)[0]
    vt = np.asarray(solvers.lowrank_ridge_factors(x).vt)
    ev = np.asarray(solvers.lowrank_ridge_factors(x).evals)
    vg = vt @ np.asarray(g_tr)
    coef = np.where(np.arange(ev.size) < r, 1.0 / (ev + 0.37), 1.0 / 0.37)
    want = vt.T @ (coef * vg)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-9, atol=1e-10)


def test_dataset_plants_the_requested_rank():
    x, y = make_low_rank_dataset(jax.random.PRNGKey(0), 32, 96, 8,
                                 dtype=jnp.float64)
    assert x.shape == (32, 96) and y.shape == (32,)
    s = np.linalg.svd(np.asarray(x), compute_uv=False)
    assert s[7] > 50 * s[8]                # numerical rank 8
    with pytest.raises(ValueError, match="rank"):
        make_low_rank_dataset(jax.random.PRNGKey(0), 32, 96, 0)
    with pytest.raises(ValueError, match="rank"):
        make_low_rank_dataset(jax.random.PRNGKey(0), 32, 96, 33)


# ----------------------------------------------------------------- engine


@given(cfg=props.low_rank_design())
@settings(max_examples=3, deadline=None)
def test_engine_matches_exact_strategy(cfg):
    """Property: over every planted-rank geometry, the low-rank engine's
    hold-out curve equals the exact strategy's, with the same λ*."""
    f = props.low_rank_folds(**cfg)
    r_lr = engine.CVEngine("low_rank").run(f, LAMS)
    r_ex = engine.CVEngine("exact").run(f, LAMS)
    if props.active_precision().is_native:
        np.testing.assert_allclose(r_lr.errors, r_ex.errors,
                                   **props.parity_tol(1e-8, 1e-10))
    else:
        # reduced-precision storage quantizes the two pipelines
        # differently (spectral reweighting vs Cholesky solves), so raw
        # curve parity cannot hold at parity_tol near the curve minimum;
        # the reduced-precision contract is a curve-level envelope plus
        # the strict selection parity below
        ee = np.asarray(r_ex.errors, np.float64)
        span = float(ee.max() - ee.min())
        np.testing.assert_allclose(r_lr.errors, r_ex.errors,
                                   atol=0.5 * span)
    props.assert_selection_close(r_lr.errors, r_ex.errors)


def test_engine_zero_cholesky(folds):
    """The strategy's entire cost is one SVD per fold: no Cholesky is ever
    traced, cold or not, and the result reports n_exact_chol == 0."""
    bk = CountingBackend(props.make_backend("reference"))
    r = engine.CVEngine("low_rank", backend=bk).run(folds, LAMS)
    assert bk.n_cholesky == 0
    assert r.n_exact_chol == 0
    assert np.isfinite(np.asarray(r.errors)).all()


def test_rank_truncation_converges_to_exact(folds):
    """On the planted rank-8 design, curve error vs exact shrinks as the
    kept rank crosses the planted rank and vanishes at full rank."""
    exact = np.asarray(engine.CVEngine("exact").run(folds, LAMS).errors)

    def diff(rank):
        r = engine.CVEngine(engine.LowRankStrategy(rank=rank)
                            ).run(folds, LAMS)
        return float(np.max(np.abs(np.asarray(r.errors) - exact)))

    d4, d8, dfull = diff(4), diff(8), diff(None)
    assert dfull <= props.parity_tol(1e-8, 1e-8)["atol"] * 100 + 1e-10
    assert d8 < d4, (d4, d8)
    assert d8 < 0.1 * d4 + 1e-9, (d4, d8)


# ------------------------------------------------------------------ cache


def test_cache_any_grid_hits_cold_warm_bitwise(folds):
    """λ-independent factors key with EMPTY anchors: a warm cache serves
    ANY λ grid over the same folds, bitwise-reproducing a fresh run of
    the same grid."""
    cache = factor_cache.FactorCache()
    r_cold = engine.CVEngine("low_rank", cache=cache).run(folds, LAMS)
    assert r_cold.extras["engine"]["cache"]["status"] == "miss"
    (entry,) = cache.entries.values()
    assert entry.key.anchors == ()
    assert entry.key.sketch == engine.LowRankStrategy().descriptor()
    assert isinstance(entry.state, solvers.LowRankFactors)

    other_grid = props.log_grid(9, -2.0, 1.0)       # different q AND range
    r_warm = engine.CVEngine("low_rank", cache=cache).run(folds, other_grid)
    assert r_warm.extras["engine"]["cache"]["status"] == "hit"
    # warm replay is bitwise-reproducible; vs the fused cold path it can
    # differ by jit-fusion freedom only (last-ulp)
    r_warm2 = engine.CVEngine("low_rank", cache=cache).run(folds, other_grid)
    np.testing.assert_array_equal(np.asarray(r_warm.errors),
                                  np.asarray(r_warm2.errors))
    fresh = engine.CVEngine("low_rank").run(folds, other_grid)
    np.testing.assert_allclose(np.asarray(r_warm.errors),
                               np.asarray(fresh.errors),
                               **props.parity_tol(1e-12, 1e-14))


@pytest.mark.parametrize("mutation", ["changed_rank", "perturbed_design",
                                      "lowrank_vs_exact"])
def test_fingerprint_mismatch_misses_and_repopulates(folds, mutation):
    """Negative contract: rank is part of the descriptor, the design is
    part of the Hessian fingerprint, and a low-rank entry can never serve
    the exact strategy.  Every mutation misses, matches its fresh cold
    run, and repopulates to a hit."""
    cache = factor_cache.FactorCache()
    engine.CVEngine("low_rank", cache=cache).run(folds, LAMS)
    assert len(cache) == 1

    mut = {
        "changed_rank": dict(strat="picked_below"),
        "perturbed_design": dict(folds=props.low_rank_folds(seed=11)),
        "lowrank_vs_exact": dict(strat="picholesky"),
    }[mutation]
    m_folds = mut.get("folds", folds)
    m_strat = (engine.LowRankStrategy(rank=8)
               if mut.get("strat") == "picked_below"
               else mut.get("strat", "low_rank"))

    r = engine.CVEngine(m_strat, cache=cache).run(m_folds, LAMS)
    assert r.extras["engine"]["cache"]["status"] == "miss", mutation
    assert len(cache) == 2
    fresh = engine.CVEngine(m_strat).run(m_folds, LAMS)
    np.testing.assert_allclose(r.errors, fresh.errors,
                               **props.parity_tol(1e-8, 1e-10))
    r2 = engine.CVEngine(m_strat, cache=cache).run(m_folds, LAMS)
    assert r2.extras["engine"]["cache"]["status"] == "hit", mutation


def test_persistence_roundtrip_bitwise(folds, tmp_path):
    """LowRankFactors survive save/load (the 'low_rank' state record
    kind): vt/evals bitwise, and the disk-warm sweep equals memory-warm
    bitwise."""
    cache = factor_cache.FactorCache()
    engine.CVEngine("low_rank", cache=cache).run(folds, LAMS)
    cache.save(str(tmp_path))
    loaded = factor_cache.FactorCache.load(str(tmp_path))
    (orig,), (back,) = cache.entries.values(), loaded.entries.values()
    assert isinstance(back.state, solvers.LowRankFactors)
    np.testing.assert_array_equal(np.asarray(orig.state.vt),
                                  np.asarray(back.state.vt))
    np.testing.assert_array_equal(np.asarray(orig.state.evals),
                                  np.asarray(back.state.evals))

    r_mem = engine.CVEngine("low_rank", cache=cache).run(folds, LAMS)
    r_disk = engine.CVEngine("low_rank", cache=loaded).run(folds, LAMS)
    assert r_disk.extras["engine"]["cache"]["status"] == "hit"
    np.testing.assert_array_equal(np.asarray(r_mem.errors),
                                  np.asarray(r_disk.errors))


# ----------------------------------------------------- downstream parity


@pytest.mark.tier2
@given(backend=props.backend_names(), chunk=props.lam_chunks())
@settings(max_examples=6, deadline=None)
def test_chunking_and_backend_parity(backend, chunk):
    """Property: any λ-chunk policy on either backend reproduces the
    unchunked reference curve (the sweep is a pure spectral evaluation —
    chunking must only batch it)."""
    f = props.low_rank_folds(h=64, n=24, k=4, rank=6, seed=0)
    base = engine.CVEngine("low_rank").run(f, LAMS)
    alt = engine.CVEngine("low_rank", backend=props.make_backend(backend),
                          lam_chunk=chunk).run(f, LAMS)
    np.testing.assert_allclose(alt.errors, base.errors,
                               **props.parity_tol(1e-8, 1e-10))
    props.assert_selection_close(alt.errors, base.errors)


def test_run_async_matches_run(folds):
    r_fused = engine.CVEngine("low_rank").run(folds, LAMS)
    r_async = engine.CVEngine("low_rank", lam_chunk=5).run_async(folds, LAMS)
    np.testing.assert_allclose(r_async.errors, r_fused.errors,
                               **props.parity_tol(1e-9, 1e-12))
    props.assert_selection_close(r_async.errors, r_fused.errors)


def test_search_finds_dense_argmin(folds):
    """The adaptive search composes with the low-rank state (λ* within
    tol + one dense step, strictly fewer evaluations)."""
    dense = props.log_grid(48)
    eng = engine.CVEngine("low_rank", lam_chunk=8)
    r_dense = eng.run(folds, dense)
    r = engine.CVEngine("low_rank", lam_chunk=8).search(folds, dense,
                                                        tol_decades=0.05)
    info = r.extras["engine"]["search"]
    assert info["lams_evaluated"] < dense.size
    step = 5.0 / 47
    gap = abs(np.log10(r.best_lam) - np.log10(r_dense.best_lam))
    assert gap <= 0.05 + step, (r.best_lam, r_dense.best_lam)
