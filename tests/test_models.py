"""Per-architecture smoke tests (reduced configs): one forward/train step on
CPU, shape + finiteness asserts, and decode-vs-forward consistency."""
import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.models.model import Model
from repro.optim import adamw
from repro.train.steps import make_train_step

ARCHS = configs.names()


def _extra(cfg, key, batch, seq):
    if cfg.family == "audio":
        return {"enc_frames": jax.random.normal(
            key, (batch, seq // cfg.enc_seq_ratio, cfg.d_model), jnp.float32)}
    if cfg.family == "vlm":
        return {"image_embeds": jax.random.normal(
            key, (batch, cfg.n_image_tokens, cfg.d_model), jnp.float32)}
    return None


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_smoke(arch):
    cfg = configs.get(arch).reduced()
    m = Model(cfg)
    key = jax.random.PRNGKey(0)
    params = m.init(key)
    b, s = 2, 32
    tokens = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    logits, aux = jax.jit(m.forward)(params, tokens, _extra(cfg, key, b, s))
    assert logits.shape == (b, s, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_smoke(arch):
    cfg = configs.get(arch).reduced()
    m = Model(cfg)
    key = jax.random.PRNGKey(0)
    params = m.init(key)
    opt = adamw(lr=1e-3)
    opt_state = opt[0](params)
    step = jax.jit(make_train_step(m, opt))
    b, s = 2, 16
    batch = {
        "tokens": jax.random.randint(key, (b, s), 0, cfg.vocab_size),
        "labels": jax.random.randint(key, (b, s), 0, cfg.vocab_size),
    }
    params2, opt_state2, metrics = step(params, opt_state, batch,
                                        _extra(cfg, key, b, s))
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    # params actually moved
    moved = jax.tree.reduce(
        lambda a, b: a + b,
        jax.tree.map(lambda p, q: float(jnp.max(jnp.abs(p - q))),
                     params, params2))
    assert moved > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_forward(arch):
    cfg = configs.get(arch).reduced()
    m = Model(cfg)
    key = jax.random.PRNGKey(0)
    params = m.init(key)
    b, s = 2, 24
    tokens = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    extra = _extra(cfg, key, b, s)
    logits_p, cache = jax.jit(m.prefill)(params, tokens, extra)
    nt = jnp.argmax(logits_p[:, -1], -1)[:, None]
    logits_d, cache2 = jax.jit(m.decode)(params, cache, nt)
    logits_f, _ = jax.jit(m.forward)(
        params, jnp.concatenate([tokens, nt], 1), extra)
    dev = float(jnp.max(jnp.abs(logits_f[:, -1] - logits_d[:, 0])))
    assert dev < 1e-3, dev
    assert int(cache2["pos"]) == s + 1


def test_two_step_decode():
    cfg = configs.get("qwen2-1.5b").reduced()
    m = Model(cfg)
    key = jax.random.PRNGKey(0)
    params = m.init(key)
    tokens = jax.random.randint(key, (1, 16), 0, cfg.vocab_size)
    _, cache = jax.jit(m.prefill)(params, tokens)
    t1 = jnp.zeros((1, 1), jnp.int32)
    l1, cache = jax.jit(m.decode)(params, cache, t1)
    t2 = jnp.argmax(l1[:, -1], -1)[:, None]
    l2, cache = jax.jit(m.decode)(params, cache, t2)
    full = jnp.concatenate([tokens, t1, t2], 1)
    lf, _ = jax.jit(m.forward)(params, full)
    assert float(jnp.max(jnp.abs(lf[:, -1] - l2[:, 0]))) < 1e-3


def test_moe_capacity_drops_tokens():
    """With a tight capacity factor some tokens must be dropped (output is
    attenuated, never NaN) — the production dropless path is capacity≥E."""
    import dataclasses
    cfg = dataclasses.replace(configs.get("mixtral-8x7b").reduced(),
                              capacity_factor=0.5)
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    tokens = jnp.zeros((2, 16), jnp.int32)
    logits, aux = jax.jit(m.forward)(params, tokens)
    assert bool(jnp.isfinite(logits).all())


def test_param_counts_sane():
    for arch in ARCHS:
        cfg = configs.get(arch)
        n = cfg.n_params()
        assert n > 0
        if cfg.family == "moe":
            assert cfg.n_active_params() < n
    assert configs.get("kimi-k2-1t-a32b").n_params() > 8e11   # ~1T
    assert abs(configs.get("falcon-mamba-7b").n_params() - 7e9) < 2e9
    assert abs(configs.get("mixtral-8x7b").n_params() - 47e9) < 8e9
