"""Optimizers + piCholesky-damped Gauss-Newton head."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import adafactor, adamw, damped_gauss_newton_head


def _quadratic_problem(d=16, seed=0):
    rs = np.random.RandomState(seed)
    x = rs.randn(4 * d, d)
    a = jnp.asarray(x.T @ x / 4 + np.eye(d))
    b = jnp.asarray(rs.randn(d))
    def loss(w):
        return 0.5 * w @ a @ w - b @ w
    return a, b, loss


def _run(opt, loss, w0, steps=200):
    init, update = opt
    state = init(w0)
    w = w0
    for _ in range(steps):
        g = jax.grad(loss)(w)
        w, state = update(g, state, w)
    return w


def test_adamw_decreases_quadratic():
    _, _, loss = _quadratic_problem()
    w0 = jnp.zeros(16)
    w = _run(adamw(lr=3e-2, weight_decay=0.0), loss, w0)
    assert float(loss(w)) < float(loss(w0)) - 0.1


def test_adafactor_decreases_quadratic():
    _, _, loss = _quadratic_problem()
    w0 = {"m": jnp.zeros((4, 4))}
    def loss2(t):
        return loss(t["m"].reshape(-1))
    t = w0
    init, update = adafactor(lr=5e-2)
    state = init(t)
    for _ in range(300):
        g = jax.grad(loss2)(t)
        t, state = update(g, state, t)
    assert float(loss2(t)) < float(loss2(w0)) - 0.1


def test_adafactor_state_is_factored():
    init, _ = adafactor()
    params = {"w": jnp.zeros((32, 64))}
    st = init(params)
    assert st.vr["w"].shape == (32,)
    assert st.vc["w"].shape == (64,)


def test_gauss_newton_head_solves_damped_system():
    a, b, _ = _quadratic_problem(d=32, seed=1)
    state, step = damped_gauss_newton_head(a, lam_range=(1e-2, 1e0),
                                           g_samples=6, block=8)
    lam = jnp.asarray(0.2)
    delta, state = step(state, b, lam)
    expect = jnp.linalg.solve(a + lam * jnp.eye(32), b)
    rel = float(jnp.linalg.norm(delta - expect) / jnp.linalg.norm(expect))
    assert rel < 1e-2


def test_gauss_newton_clips_to_fitted_range():
    a, b, _ = _quadratic_problem(d=16, seed=2)
    state, step = damped_gauss_newton_head(a, lam_range=(1e-2, 1e0),
                                           g_samples=6, block=8)
    delta, state2 = step(state, b, jnp.asarray(1e3))   # way outside range
    assert float(state2.lam) <= 1.0 + 1e-9
    assert bool(jnp.isfinite(delta).all())
