"""Packed-domain factor pipeline: PackedFactor currency, packed triangular
solves, fused interpolant solves, and the chunked constant-memory λ sweep.

The acceptance contract for the streamed sweep lives here:
``test_sweep_peak_memory_independent_of_q`` asserts the jitted sweep's
live-buffer proxy (XLA ``temp_size_in_bytes``) does not grow with the λ-grid
size at fixed chunk, and the parity tests assert chunked == unchunked across
chunk sizes including q % chunk ≠ 0 and chunk > q.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import engine, packing, picholesky, solvers
from repro.core.backends import ReferenceBackend
from repro.distributed import sharding as shardlib
from repro.testing import strategies as props

# shared generators (repro.testing.strategies): SPD builder + backend
# constructor (kernel tiles sized 16 for this suite's h=64 problems)
_spd = props.spd_matrix


def _backend(name):
    return props.make_backend(name, block=16)


@pytest.fixture(scope="module")
def folds4():
    return props.regression_folds(h=64, n=400, k=4)


LAMS = props.log_grid(31)


# ------------------------------------------------------ PackedFactor currency


def test_packed_factor_round_trip_and_pytree():
    h, block = 37, 8
    l = jnp.linalg.cholesky(_spd(h))
    pf = packing.PackedFactor.from_dense(l, block)
    assert pf.vec.shape == (packing.packed_size(h, block),)
    np.testing.assert_allclose(pf.dense(), l, atol=1e-12)
    # pytree: static (h, block) survive flatten/unflatten and jit
    leaves, treedef = jax.tree.flatten(pf)
    pf2 = jax.tree.unflatten(treedef, leaves)
    assert (pf2.h, pf2.block) == (h, block)
    out = jax.jit(lambda p: p.vec.sum())(pf)
    np.testing.assert_allclose(out, pf.vec.sum())


@pytest.mark.parametrize("backend", ["reference", "pallas"])
@pytest.mark.parametrize("h,block", [(32, 8), (37, 8), (64, 16)])
def test_solve_packed_matches_dense_solve(backend, h, block):
    """solve_packed ≡ dense solve_from_factor on both backends."""
    bk = _backend(backend)
    a = _spd(h)
    l = jnp.linalg.cholesky(a)
    g = jax.random.normal(jax.random.PRNGKey(2), (h,), jnp.float64)
    pf = packing.PackedFactor.from_dense(l, block)
    dense = ReferenceBackend().solve_from_factor(l, g)
    np.testing.assert_allclose(solvers.solve_packed(pf, g, backend=bk),
                               dense, **props.parity_tol(1e-8, 1e-10))
    # the dispatch path: solve_from_factor on a PackedFactor never unpacks
    np.testing.assert_allclose(solvers.solve_from_factor(pf, g, backend=bk),
                               dense, **props.parity_tol(1e-8, 1e-10))


@pytest.mark.parametrize("backend", ["reference", "pallas"])
def test_solve_packed_batched_factors(backend):
    bk = _backend(backend)
    h, block, q = 32, 8, 5
    a = _spd(h)
    lams = jnp.logspace(-2, 0, q)
    ls = jax.vmap(lambda lam: jnp.linalg.cholesky(a + lam * jnp.eye(h)))(lams)
    g = jax.random.normal(jax.random.PRNGKey(3), (h,), jnp.float64)
    pf = packing.PackedFactor(vec=packing.pack_tril(ls, block), h=h,
                              block=block)
    out = solvers.solve_packed(pf, g, backend=bk)
    exp = jax.vmap(lambda l: ReferenceBackend().solve_from_factor(l, g))(ls)
    np.testing.assert_allclose(out, exp, **props.parity_tol(1e-8, 1e-10))


@given(h=props.heights(), block=props.blocks(), transpose=st.booleans())
@settings(max_examples=15, deadline=None)
def test_solve_lower_packed_property(h, block, transpose):
    """Packed sweep ≡ dense triangular solve for any shape, incl. h % B ≠ 0."""
    l = jnp.linalg.cholesky(_spd(h, seed=h))
    vec = packing.pack_tril(l, block)
    g = jnp.asarray(np.random.RandomState(h).randn(h, 3))
    out = packing.solve_lower_packed(vec, g, h, block, transpose=transpose)
    exp = jax.lax.linalg.triangular_solve(l, g, left_side=True, lower=True,
                                          transpose_a=transpose)
    np.testing.assert_allclose(out, exp, rtol=1e-8, atol=1e-8)


# ------------------------------------------------------ fused interp solves


@pytest.mark.parametrize("backend", ["reference", "pallas"])
@pytest.mark.parametrize("h,block", [(37, 8), (64, 16)])
def test_interp_solve_matches_dense_route(backend, h, block):
    """Fused eval+solve ≡ the demoted dense route (eval_factor + trsm)."""
    bk = _backend(backend)
    a = _spd(h)
    sample = picholesky.choose_sample_lambdas(1e-2, 1.0, 5)
    model = picholesky.fit(a, sample, 2, block=block)
    lams = jnp.logspace(-2, 0, 9)
    g = jax.random.normal(jax.random.PRNGKey(4), (h,), jnp.float64)
    out = solvers.solve_interpolant_sweep(model, lams, g, backend=bk)
    dense = model.eval_factor(lams)   # debug escape hatch
    exp = jax.vmap(lambda l: ReferenceBackend().solve_from_factor(l, g))(dense)
    np.testing.assert_allclose(out, exp, **props.parity_tol(1e-7, 1e-9))


def test_eval_factor_is_debug_escape_hatch():
    """eval_packed_factor stays packed; eval_factor unpacks equivalently."""
    h, block = 32, 8
    model = picholesky.fit(_spd(h), picholesky.choose_sample_lambdas(
        1e-2, 1.0, 4), 2, block=block)
    lams = jnp.logspace(-2, 0, 5)
    pf = model.eval_packed_factor(lams)
    assert isinstance(pf, packing.PackedFactor)
    assert pf.vec.shape == (5, packing.packed_size(h, block))
    np.testing.assert_allclose(pf.dense(), model.eval_factor(lams),
                               atol=1e-12)


def test_fit_consumes_packed_factors():
    h, block = 32, 8
    a = _spd(h)
    sample = picholesky.choose_sample_lambdas(1e-2, 1.0, 4)
    ls = jax.vmap(lambda lam: jnp.linalg.cholesky(a + lam * jnp.eye(h))
                  )(sample)
    pf = packing.PackedFactor(vec=packing.pack_tril(ls, block), h=h,
                              block=block)
    m_dense = picholesky.fit(a, sample, 2, block=block, factors=ls)
    m_packed = picholesky.fit(a, sample, 2, block=block, factors=pf)
    np.testing.assert_allclose(m_packed.theta, m_dense.theta, atol=1e-12)
    with pytest.raises(ValueError, match="block"):
        picholesky.fit(a, sample, 2, block=16, factors=pf)


# ---------------------------------------- escape hatches vs dense oracle


@pytest.mark.parametrize("h,block", props.PACKED_SHAPES)
def test_dense_escape_hatch_non_tile_multiple(h, block):
    """PackedFactor.dense() at sizes that are NOT a multiple of the tile
    (incl. h < block): round-trips the exact factor and solve_packed_ref
    matches a dense ``jnp.linalg`` oracle, single and multi RHS."""
    a = _spd(h, seed=h)
    l = jnp.linalg.cholesky(a)
    pf = packing.PackedFactor.from_dense(l, block)
    np.testing.assert_allclose(pf.dense(), l, atol=1e-12)
    rng = np.random.RandomState(h)
    g1 = jnp.asarray(rng.randn(h))
    gq = jnp.asarray(rng.randn(h, 3))
    np.testing.assert_allclose(
        packing.solve_packed_ref(pf.vec, g1, h, block),
        jnp.linalg.solve(a, g1), rtol=1e-8, atol=1e-10)
    np.testing.assert_allclose(
        packing.solve_packed_ref(pf.vec, gq, h, block),
        jnp.linalg.solve(a, gq), rtol=1e-8, atol=1e-10)


def test_eval_factor_non_tile_multiple_vs_dense_fit():
    """The interpolant's dense escape hatch agrees with a dense-domain
    polynomial fit when h % block ≠ 0 (padding columns must not leak)."""
    h, block = 21, 8
    a = _spd(h)
    sample = picholesky.choose_sample_lambdas(1e-2, 1.0, 5)
    model = picholesky.fit(a, sample, 2, block=block)
    lams = jnp.logspace(-2, 0, 4)
    dense = model.eval_factor(lams)
    assert dense.shape == (4, h, h)
    # oracle: fit each dense entry directly (full-matrix vectorization)
    ls = jax.vmap(lambda lam: jnp.linalg.cholesky(a + lam * jnp.eye(h))
                  )(sample)
    v = picholesky.vandermonde(sample, 2)
    theta = jnp.linalg.solve(v.T @ v, v.T @ ls.reshape(5, -1))
    expect = (picholesky.vandermonde(lams, 2) @ theta).reshape(4, h, h)
    np.testing.assert_allclose(dense, jnp.tril(expect),
                               **props.parity_tol(1e-7, 1e-9))


def test_packed_factor_vec_size_validated():
    """A vec whose length disagrees with (h, block) fails at construction,
    not deep inside a tile reshape."""
    good = packing.PackedFactor(vec=jnp.zeros(packing.packed_size(32, 8)),
                                h=32, block=8)
    assert good.n_blocks == 10
    with pytest.raises(ValueError, match="packed_size"):
        packing.PackedFactor(vec=jnp.zeros(17), h=32, block=8)
    # non-array leaves (specs/placeholders from tree ops) must still pass
    from jax.sharding import PartitionSpec
    pf = jax.tree.map(lambda _: PartitionSpec("folds"), good)
    assert isinstance(pf, packing.PackedFactor)


# ------------------------------------------------- chunked λ-sweep parity


@pytest.mark.parametrize("chunk", [1, 5, 7, 16, 31, 40, 64])
def test_chunked_sweep_matches_unchunked(folds4, chunk):
    """Chunked vs unchunked error grids agree bitwise-tolerantly across
    chunk sizes, including q % chunk ≠ 0 (5, 7, 16) and chunk > q (40, 64)."""
    strat = lambda: engine.PiCholeskyStrategy(g=4, block=16)  # noqa: E731
    base = engine.CVEngine(strat(), lam_chunk=None).run(folds4, LAMS)
    r = engine.CVEngine(strat(), lam_chunk=chunk).run(folds4, LAMS)
    np.testing.assert_allclose(r.errors, base.errors,
                               **props.parity_tol(1e-10, 1e-12))
    props.assert_selection_close(r.errors, base.errors)
    assert r.extras["engine"]["lam_chunk"] == chunk


@pytest.mark.parametrize("name,params", [
    ("exact", {}),
    ("picholesky_warmstart", dict(block=16, g_rest=3)),
    ("svd", dict(mode="truncated", k_trunc=16)),
    ("pinrmse", {}),
])
def test_chunking_is_strategy_agnostic(folds4, name, params):
    """Every built-in strategy is λ-elementwise, so streaming is exact."""
    base = engine.CVEngine(engine.make_strategy(name, **params),
                           lam_chunk=None).run(folds4, LAMS)
    r = engine.CVEngine(engine.make_strategy(name, **params),
                        lam_chunk=7).run(folds4, LAMS)
    np.testing.assert_allclose(r.errors, base.errors,
                               **props.parity_tol(1e-10, 1e-12))


def test_chunked_sweep_on_mesh(folds4):
    """Chunking composes with the folds × lams shard_map (per-shard chunks;
    conftest forces 4 host devices)."""
    strat = lambda: engine.PiCholeskyStrategy(g=4, block=16)  # noqa: E731
    base = engine.CVEngine(strat(), lam_chunk=None).run(folds4, LAMS)
    r = engine.CVEngine(strat(), mesh="auto", lam_chunk=3).run(folds4, LAMS)
    assert r.extras["engine"]["mesh"] is not None
    np.testing.assert_allclose(r.errors, base.errors,
                               **props.parity_tol(1e-8, 1e-12))


def test_chunk_lams_helper():
    lams = jnp.arange(7.0)
    chunks, n = shardlib.chunk_lams(lams, 3)
    assert chunks.shape == (3, 3) and n == 7
    np.testing.assert_allclose(chunks[-1], [6.0, 6.0, 6.0])  # edge padding
    chunks, n = shardlib.chunk_lams(lams, 16)                # chunk > q
    assert chunks.shape == (1, 16) and n == 7
    with pytest.raises(ValueError, match="positive"):
        shardlib.chunk_lams(lams, 0)


def test_auto_chunk_sized_to_vmem_budget():
    eng = engine.CVEngine(engine.PiCholeskyStrategy(g=4, block=16))
    # the auto chunk budgets at the policy's STORAGE dtype: bf16 storage
    # halves the per-λ bytes and doubles the chunk
    store = props.active_precision().store_dtype(jnp.float64)
    per_lam = packing.packed_nbytes(64, 16, store)
    assert eng._resolve_chunk(1024, 64, jnp.float64) == \
        engine.LAM_CHUNK_BUDGET_BYTES // per_lam
    assert engine.CVEngine("exact", lam_chunk=None)._resolve_chunk(
        1024, 64, jnp.float64) is None
    assert engine.CVEngine("exact", lam_chunk=12)._resolve_chunk(
        1024, 64, jnp.float64) == 12
    with pytest.raises(ValueError, match="positive"):
        engine.CVEngine("exact", lam_chunk=-1)._resolve_chunk(
            1024, 64, jnp.float64)


# ------------------------------------------- constant-memory acceptance


def test_sweep_peak_memory_independent_of_q(folds4):
    """Acceptance: at fixed chunk, the λ sweep's peak device memory is
    independent of q (q=64 vs q=1024), up to the O(q) bookkeeping of the
    λ grid / error outputs themselves (≤ 64 B per extra λ — no h² term).
    The unchunked sweep at q=1024 is an order of magnitude above it."""
    strat = lambda: engine.PiCholeskyStrategy(g=4, block=16)  # noqa: E731
    chunked = engine.CVEngine(strat(), lam_chunk=16, donate=False)
    t64 = chunked.sweep_temp_bytes(folds4, jnp.logspace(-3, 2, 64))
    t1024 = chunked.sweep_temp_bytes(folds4, jnp.logspace(-3, 2, 1024))
    assert abs(t1024 - t64) <= 64 * (1024 - 64), (t64, t1024)

    dense = engine.CVEngine(strat(), lam_chunk=None, donate=False)
    t_dense = dense.sweep_temp_bytes(folds4, jnp.logspace(-3, 2, 1024))
    assert t_dense > 10 * t1024, (t_dense, t1024)
