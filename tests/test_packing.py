"""Property-based tests for the tile-major triangular packing (§5)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import packing


@given(h=st.integers(2, 60), block=st.sampled_from([4, 8, 16]))
@settings(max_examples=25, deadline=None)
def test_pack_unpack_roundtrip(h, block):
    m = jnp.asarray(np.random.RandomState(h).randn(h, h))
    v = packing.pack_tril(m, block)
    back = packing.unpack_tril(v, h, block)
    assert np.allclose(back, np.tril(m))


@given(h=st.integers(2, 40))
@settings(max_examples=15, deadline=None)
def test_rowwise_matches_tril_indices(h):
    m = jnp.asarray(np.random.RandomState(h).randn(h, h))
    v = packing.pack_tril_rowwise(m)
    r, c = np.tril_indices(h)
    assert np.allclose(v, np.asarray(m)[r, c])
    back = packing.unpack_tril_rowwise(v, h)
    assert np.allclose(back, np.tril(m))


def test_packed_size_overhead_shrinks():
    """Tile padding overhead is ≈ 1 + B/h: negligible for h >> B."""
    h, block = 1024, 128
    d = h * (h + 1) // 2
    p = packing.packed_size(h, block)
    assert p / d < 1.15


def test_pack_is_linear_and_batched():
    key = jax.random.PRNGKey(0)
    a = jax.random.normal(key, (3, 20, 20))
    v = packing.pack_tril(a, 8)            # batched
    assert v.shape[0] == 3
    v2 = packing.pack_tril(2.0 * a[0], 8)
    assert np.allclose(v2, 2.0 * v[0])


def test_mask_identifies_padding():
    h, block = 20, 8
    mask = packing.tril_mask_packed(h, block)
    assert int(mask.sum()) == h * (h + 1) // 2
