"""Algorithm 1 behaviour: interpolation accuracy, basis options, CV parity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import cv, packing, picholesky, solvers
from repro.data import make_regression_dataset


@pytest.fixture(scope="module")
def ridge_problem():
    x, y = make_regression_dataset(jax.random.PRNGKey(1), 400, 128,
                                   dtype=jnp.float64)
    return x, y, x.T @ x, x.T @ y


def test_interpolation_tracks_exact_factors(ridge_problem):
    _, _, hess, _ = ridge_problem
    sample = picholesky.choose_sample_lambdas(1e-2, 1.0, 5)
    model = picholesky.fit(hess, sample, 2, block=32)
    lams = jnp.logspace(-2, 0, 21)
    l_i = model.eval_factor(lams)
    l_e = jax.vmap(lambda l: jnp.linalg.cholesky(
        hess + l * jnp.eye(hess.shape[0], dtype=hess.dtype)))(lams)
    rel = jnp.linalg.norm(l_i - l_e, axis=(1, 2)) / jnp.linalg.norm(l_e, axis=(1, 2))
    assert float(rel.max()) < 1e-3          # paper Fig. 4 regime


def test_interp_exact_at_sample_points(ridge_problem):
    """g=r+1 samples -> interpolation, exact at the nodes."""
    _, _, hess, _ = ridge_problem
    sample = jnp.asarray([0.01, 0.1, 1.0])
    model = picholesky.fit(hess, sample, 2, block=32)
    for lam in sample:
        l_i = model.eval_factor(lam)
        l_e = jnp.linalg.cholesky(hess + lam * jnp.eye(hess.shape[0],
                                                       dtype=hess.dtype))
        assert float(jnp.max(jnp.abs(l_i - l_e))) < 1e-8


def test_centered_basis_matches_monomial(ridge_problem):
    _, _, hess, _ = ridge_problem
    sample = picholesky.choose_sample_lambdas(1e-2, 1.0, 5)
    lams = jnp.logspace(-2, 0, 7)
    m1 = picholesky.fit(hess, sample, 2, block=32, basis="monomial")
    m2 = picholesky.fit(hess, sample, 2, block=32, basis="centered")
    d = float(jnp.max(jnp.abs(m1.eval_factor(lams) - m2.eval_factor(lams))))
    assert d < 1e-7


def test_solve_from_interpolated_factor(ridge_problem):
    _, _, hess, grad = ridge_problem
    sample = picholesky.choose_sample_lambdas(1e-2, 1.0, 5)
    model = picholesky.fit(hess, sample, 2, block=32)
    lam = jnp.asarray(0.3)
    theta_i = solvers.solve_from_factor(model.eval_factor(lam), grad)
    theta_e = solvers.solve_cholesky(hess, grad, lam)
    rel = float(jnp.linalg.norm(theta_i - theta_e) / jnp.linalg.norm(theta_e))
    assert rel < 1e-3


def test_cv_picholesky_selects_same_lambda(ridge_problem):
    x, y, _, _ = ridge_problem
    folds = cv.make_folds(x, y, 5)
    lams = jnp.logspace(-3, 2, 31)
    r_exact = cv.cv_exact_cholesky(folds, lams)
    r_pi = cv.cv_picholesky(folds, lams, g=4, block=32)
    # paper Table 4: selected λ within one grid step of exact
    i_e = int(np.argmin(r_exact.errors))
    i_p = int(np.argmin(r_pi.errors))
    assert abs(i_e - i_p) <= 1
    assert r_pi.n_exact_chol < r_exact.n_exact_chol / 5


def test_cv_cost_accounting(ridge_problem):
    x, y, _, _ = ridge_problem
    folds = cv.make_folds(x, y, 5)
    lams = jnp.logspace(-3, 2, 31)
    r = cv.cv_picholesky(folds, lams, g=4, block=32)
    assert r.n_exact_chol == 5 * 4          # k folds × g samples


def test_svd_baseline_matches_cholesky(ridge_problem):
    x, y, hess, grad = ridge_problem
    lams = jnp.asarray([0.1, 1.0])
    th_svd = solvers.solve_svd(x, y, lams)
    th_chol = solvers.solve_cholesky_sweep(hess, grad, lams)
    assert float(jnp.max(jnp.abs(th_svd - th_chol))) < 1e-6


def test_randomized_svd_close_to_truncated(ridge_problem):
    x, y, _, _ = ridge_problem
    lams = jnp.asarray([0.5])
    k = 32
    t1 = solvers.solve_truncated_svd(x, y, lams, k)
    t2 = solvers.solve_randomized_svd(x, y, lams, k, jax.random.PRNGKey(2))
    cos = float(jnp.vdot(t1, t2) / (jnp.linalg.norm(t1) * jnp.linalg.norm(t2)))
    # random-feature spectra decay slowly, so r-SVD is only loosely aligned
    # with t-SVD — consistent with the paper's §6.5 finding that r-SVD gives
    # poor hold-out estimates despite being fastest
    assert cos > 0.7


def test_warmstart_cv_matches_selection(ridge_problem):
    """Beyond-paper: cross-fold warm-starting (paper §7 future work) keeps
    the selected λ while cutting factorizations below plain PIChol."""
    x, y, _, _ = ridge_problem
    folds = cv.make_folds(x, y, 5)
    lams = jnp.logspace(-3, 2, 31)
    r_exact = cv.cv_exact_cholesky(folds, lams)
    r_warm = cv.cv_picholesky_warmstart(folds, lams, g_first=4, g_rest=3,
                                        block=32)
    i_e = int(np.argmin(r_exact.errors))
    i_w = int(np.argmin(r_warm.errors))
    assert abs(i_e - i_w) <= 1
    assert r_warm.n_exact_chol < 5 * 4       # fewer than plain PIChol's k·g
