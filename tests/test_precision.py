"""Mixed-precision factor pipeline: one PrecisionPolicy from the Pallas
kernels to the CV engine.

The acceptance contract lives here:

* ``bf16_store`` halves ``PackedFactor`` bytes and cached-entry bytes
  (``FactorCache.stats['bytes_saved']`` reports the saving),
* ``bf16_refined`` reproduces the fp32 hold-out **argmin bit-for-bit** on
  the Table-4 regression grid, on both backends — the chunk-granular fp32
  residual refinement is what buys the accuracy back,
* the policy is part of the cache fingerprint: a bf16 entry can never
  silently serve an fp32 request,
* the VMEM-auto λ chunk doubles under bf16 storage,
* λ never quantizes to a reduced-precision data dtype (fit-dtype floor).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine, factor_cache, packing, picholesky
from repro.core.backends import (CountingBackend, PallasBackend,
                                 ReferenceBackend, resolve_backend)
from repro.core.precision import (PRESETS, PrecisionPolicy,
                                  resolve_precision, tree_astype)
from repro.testing import strategies as props

LAMS = props.log_grid(31)


def _bk(name, policy, block=16):
    return props.make_backend(name, block=block).with_precision(
        resolve_precision(policy))


# ------------------------------------------------------------ policy object


def test_presets_resolution_and_errors():
    assert resolve_precision("fp32") is PRESETS["fp32"]
    assert resolve_precision(PRESETS["bf16_store"]) is PRESETS["bf16_store"]
    assert resolve_precision("native").is_native
    with pytest.raises(ValueError, match="unknown precision"):
        resolve_precision("fp8_dreams")
    with pytest.raises(ValueError, match="refine_iters"):
        PrecisionPolicy(refine_iters=-1)
    with pytest.raises(TypeError):
        PrecisionPolicy(store="not_a_dtype")


def test_dtype_role_resolution():
    nat = PRESETS["native"]
    assert nat.store_dtype(jnp.float64) == jnp.float64
    assert nat.compute_dtype(jnp.float32) == jnp.float32
    # accum never 16-bit: native policy on a bf16 input promotes to fp32
    assert nat.accum_dtype(jnp.bfloat16) == jnp.float32
    # fit floors at fp32 (the λ grid must not quantize), inherits above it
    assert nat.fit_dtype(jnp.bfloat16) == jnp.float32
    assert nat.fit_dtype(jnp.float64) == jnp.float64

    bf = PRESETS["bf16_refined"]
    assert bf.store_dtype(jnp.float64) == jnp.bfloat16
    assert bf.accum_dtype(jnp.float64) == jnp.float32
    assert bf.fit_dtype(jnp.float64) == jnp.float32
    assert bf.refine_iters == 1
    assert bf.bytes_ratio(jnp.float32) == 2.0


def test_descriptor_is_content_derived():
    """Fingerprints come from the dtype roles, not the preset name — and
    the store/refine roles both separate policies."""
    assert PRESETS["native"].descriptor() == "native"
    renamed = PrecisionPolicy(name="whatever", store="float32",
                              compute="float32", accum="float32",
                              fit="float32")
    assert renamed.descriptor() == PRESETS["fp32"].descriptor()
    assert (PRESETS["bf16_store"].descriptor()
            != PRESETS["bf16_refined"].descriptor())
    assert PRESETS["fp32"].descriptor() != PRESETS["bf16_store"].descriptor()


# ------------------------------------------------------------ packed layer


def test_packed_factor_astype_round_trips_pytree():
    h, block = 37, 8
    l = jnp.linalg.cholesky(props.spd_matrix(h))
    pf = packing.PackedFactor.from_dense(l, block)
    half = pf.astype(jnp.bfloat16)
    assert half.dtype == jnp.bfloat16 and (half.h, half.block) == (h, block)
    assert pf.nbytes == packing.packed_nbytes(h, block, jnp.float64)
    assert half.nbytes * 4 == pf.nbytes          # f64 -> bf16 is 4x
    # pytree round-trip keeps statics and the cast dtype
    leaves, treedef = jax.tree.flatten(half)
    back = jax.tree.unflatten(treedef, leaves)
    assert back.dtype == jnp.bfloat16 and back.h == h
    # the stored values are the bf16 rounding of the exact factor
    np.testing.assert_allclose(half.dense(), l, rtol=1e-2, atol=1e-2)
    # tree_astype round-trips the whole dataclass too
    up = tree_astype(half, jnp.float32)
    assert up.dtype == jnp.float32 and up.block == block


def test_fit_stores_theta_at_policy_dtype():
    a = props.spd_matrix(32)
    sample = picholesky.choose_sample_lambdas(1e-2, 1.0, 4)
    native = picholesky.fit(a, sample, 2, block=8,
                            backend=ReferenceBackend())   # pinned native
    assert native.theta.dtype == a.dtype          # inherit — pre-policy fit

    bk = _bk("reference", "bf16_store", block=8)
    half = picholesky.fit(a, sample, 2, block=8, backend=bk)
    assert half.theta.dtype == jnp.bfloat16       # stored at policy dtype
    assert half.center.dtype == jnp.float32       # λ center at fit dtype
    # the bf16 Θ is the rounding of a full-precision fit, not a bf16 fit
    np.testing.assert_allclose(np.asarray(half.theta, np.float64),
                               np.asarray(native.theta, np.float64),
                               rtol=1e-2, atol=1e-2)


# ------------------------------------------------ kernels + refinement


@pytest.mark.parametrize("backend", ["reference", "pallas"])
def test_bf16_solves_accumulate_in_fp32(backend):
    """solve_packed under bf16 compute returns fp32 solutions within bf16
    rounding of the exact solve — accumulation never happens in bf16."""
    h, block = 32, 8
    a = props.spd_matrix(h, dtype=jnp.float32)
    l = jnp.linalg.cholesky(a)
    g = jax.random.normal(jax.random.PRNGKey(2), (h,), jnp.float32)
    pf = packing.PackedFactor.from_dense(l, block).astype(jnp.bfloat16)
    out = _bk(backend, "bf16_store", block=block).solve_packed(pf, g)
    assert out.dtype == jnp.float32
    exact = jnp.linalg.solve(a, g)
    rel = float(jnp.linalg.norm(out - exact) / jnp.linalg.norm(exact))
    assert rel < 5e-2, rel


@pytest.mark.parametrize("backend", ["reference", "pallas"])
def test_refinement_recovers_fp32_accuracy(backend):
    """One fp32 residual-refinement sweep contracts the bf16 interp_solve
    error by at least an order of magnitude (the bf16_refined mechanism)."""
    h, block = 48, 8
    a = props.spd_matrix(h, dtype=jnp.float32)
    g = jax.random.normal(jax.random.PRNGKey(3), (h,), jnp.float32)
    sample = picholesky.choose_sample_lambdas(1e-2, 1e1, 5)
    lams = jnp.logspace(-2, 1, 9)

    bk32 = _bk(backend, "fp32", block=block)
    model32 = picholesky.fit(a, sample, 2, block=block, backend=bk32)
    ref = model32.solve(lams, g, backend=bk32)

    bk_store = _bk(backend, "bf16_store", block=block)
    bk_ref = _bk(backend, "bf16_refined", block=block)
    model16 = picholesky.fit(a, sample, 2, block=block, backend=bk_store)
    raw = model16.solve(lams, g, backend=bk_store)
    refined = picholesky.refine_solutions(model16, a, g, lams, raw,
                                          backend=bk_ref)
    err_raw = float(jnp.linalg.norm(raw - ref))
    err_ref = float(jnp.linalg.norm(refined - ref))
    assert err_ref < err_raw / 10, (err_raw, err_ref)
    # refine_iters=0 is a strict no-op (same array out)
    same = picholesky.refine_solutions(model16, a, g, lams, raw,
                                       backend=bk_store)
    assert same is raw


# ------------------------------------------------------------------ engine


def test_auto_chunk_doubles_under_bf16_storage():
    strat = engine.PiCholeskyStrategy(g=4, block=16)
    base = engine.CVEngine(strat, precision="fp32")
    half = engine.CVEngine(strat, precision="bf16_store")
    c32 = base._resolve_chunk(10_000, 64, jnp.float32)
    c16 = half._resolve_chunk(10_000, 64, jnp.float32)
    assert c16 == 2 * c32


@pytest.mark.parametrize("backend,h,block", [
    ("reference", 144, 32),       # the Table-4 regression problem size
    ("pallas", 64, 16),           # interpret-mode kernels, CI-sized
])
def test_bf16_refined_reproduces_fp32_argmin(backend, h, block):
    """Acceptance: bf16_refined reproduces the fp32 hold-out argmin
    bit-for-bit on the regression grid, while unrefined bf16_store is NOT
    held to that (on the kernel path it demonstrably shifts selection —
    refinement is load-bearing, not decorative)."""
    folds = props.regression_folds(h=h, n=420 if h == 144 else 3 * h, k=5,
                                   seed=11, dtype=jnp.float32)
    strat = lambda: engine.PiCholeskyStrategy(g=4, block=block)  # noqa: E731

    def run(policy):
        return engine.CVEngine(strat(), backend=backend, block=block,
                               precision=policy).run(folds, LAMS)

    r32 = run("fp32")
    r16 = run("bf16_refined")
    assert r16.extras["engine"]["precision"] == "bf16_refined"
    assert float(r16.best_lam) == float(r32.best_lam)          # bit-for-bit
    assert int(np.argmin(r16.errors)) == int(np.argmin(r32.errors))
    np.testing.assert_allclose(r16.errors, r32.errors, rtol=2e-2, atol=2e-3)
    # the refined curve tracks fp32 tighter than the unrefined one
    r_store = run("bf16_store")
    d_store = np.max(np.abs(r_store.errors - r32.errors))
    d_ref = np.max(np.abs(r16.errors - r32.errors))
    assert d_ref < d_store, (d_ref, d_store)


def test_refinement_composes_with_chunking_and_async(tmp_path):
    """The per-chunk refinement must not break the chunked == unchunked or
    pipelined == serial contracts (same policy both sides ⇒ same math)."""
    folds = props.regression_folds(h=32, k=4, dtype=jnp.float32)
    strat = lambda: engine.PiCholeskyStrategy(g=4, block=8)  # noqa: E731
    base = engine.CVEngine(strat(), precision="bf16_refined",
                           lam_chunk=None).run(folds, LAMS)
    chunked = engine.CVEngine(strat(), precision="bf16_refined",
                              lam_chunk=7).run(folds, LAMS)
    np.testing.assert_allclose(chunked.errors, base.errors,
                               rtol=1e-5, atol=1e-6)
    eng = engine.CVEngine(strat(), precision="bf16_refined", lam_chunk=7)
    r_serial = eng.run_async(folds, LAMS, pipelined=False)
    r_pipe = eng.run_async(folds, LAMS, pipelined=True)
    np.testing.assert_array_equal(r_serial.errors, r_pipe.errors)


def test_pinrmse_fit_dtype_routed_through_policy():
    """The engine's old hardcoded ``jax_enable_x64`` probe is gone: the
    PINRMSE curve fit runs at the policy's fit dtype."""
    folds = props.regression_folds(h=24, k=4)                  # f64 data
    r64 = engine.CVEngine(engine.PinrmseStrategy(g=4),
                          precision="native").run(folds, LAMS)
    assert r64.errors.dtype == np.float64                      # native: inherit
    r32 = engine.CVEngine(engine.PinrmseStrategy(g=4),
                          precision="fp32").run(folds, LAMS)
    assert r32.errors.dtype == np.float32
    assert abs(int(np.argmin(r32.errors)) - int(np.argmin(r64.errors))) <= 1


# ------------------------------------------------------------------- cache


def test_precision_is_part_of_cache_fingerprint():
    """A bf16 entry can never silently serve an fp32 request (and vice
    versa): different policies MISS each other and repopulate."""
    folds = props.regression_folds(h=32, k=4, dtype=jnp.float32)
    cache = factor_cache.FactorCache()
    strat = lambda: engine.PiCholeskyStrategy(g=4, block=8)  # noqa: E731
    r32 = engine.CVEngine(strat(), cache=cache, precision="fp32"
                          ).run(folds, LAMS)
    assert r32.extras["engine"]["cache"]["status"] == "miss"
    r16 = engine.CVEngine(strat(), cache=cache, precision="bf16_store"
                          ).run(folds, LAMS)
    assert r16.extras["engine"]["cache"]["status"] == "miss"   # no stale hit
    assert len(cache) == 2
    # each policy hits its own entry afterwards
    for pol in ("fp32", "bf16_store"):
        r = engine.CVEngine(strat(), cache=cache, precision=pol
                            ).run(folds, LAMS)
        assert r.extras["engine"]["cache"]["status"] == "hit", pol
    # key round-trips precision through JSON
    entry = next(iter(cache.entries.values()))
    key2 = factor_cache.CacheKey.from_json(entry.key.to_json())
    assert key2.digest() == entry.key.digest()
    assert {e.key.precision for e in cache.entries.values()} == {
        PRESETS["fp32"].descriptor(), PRESETS["bf16_store"].descriptor()}


def test_bf16_store_halves_cached_entry_bytes():
    """Acceptance: bf16 storage halves Θ and packed-anchor bytes, the LRU
    byte counters reflect the post-astype sizes, and ``bytes_saved``
    reports the shrink vs the problem's own dtype."""
    folds = props.regression_folds(h=32, k=4, dtype=jnp.float32)
    strat = lambda: engine.PiCholeskyStrategy(g=4, block=8)  # noqa: E731

    c32 = factor_cache.FactorCache()
    engine.CVEngine(strat(), cache=c32, cache_anchors=True,
                    precision="fp32").run(folds, LAMS)
    c16 = factor_cache.FactorCache()
    engine.CVEngine(strat(), cache=c16, cache_anchors=True,
                    precision="bf16_store").run(folds, LAMS)

    e32 = next(iter(c32.entries.values()))
    e16 = next(iter(c16.entries.values()))
    assert e16.state.theta.dtype == jnp.bfloat16
    assert e16.anchors.vec.dtype == jnp.bfloat16
    # Θ and anchors dominate the payload: the entry must land within 10%
    # of exactly half the fp32 entry
    assert e16.nbytes <= 0.55 * e32.nbytes, (e16.nbytes, e32.nbytes)
    assert c16.stats["bytes"] == e16.nbytes
    assert c16.stats["bytes_saved"] >= 0.9 * (e32.nbytes - e16.nbytes)
    assert c32.stats["bytes_saved"] == 0          # fp32 data stored at fp32

    # LRU budgets are honest under mixed precision: a budget sized for one
    # fp32 entry holds TWO bf16 entries
    budget = factor_cache.FactorCache(max_bytes=int(e32.nbytes * 1.1))
    for g in (4, 5):
        engine.CVEngine(engine.PiCholeskyStrategy(g=g, block=8),
                        cache=budget, cache_anchors=True,
                        precision="bf16_store").run(folds, LAMS)
    assert len(budget) == 2 and budget.evictions == 0


def test_bf16_entries_persist_and_replay(tmp_path):
    """bf16 cached states survive the checkpoint round-trip with their
    dtype (np.save keeps extension dtypes only as raw bytes — the manager
    views them back) and replay bit-for-bit."""
    folds = props.regression_folds(h=32, k=4, dtype=jnp.float32)
    strat = lambda: engine.PiCholeskyStrategy(g=4, block=8)  # noqa: E731
    cache = factor_cache.FactorCache()
    eng = engine.CVEngine(strat(), cache=cache, cache_anchors=True,
                          precision="bf16_store")
    r1 = eng.run(folds, LAMS)
    cache.save(str(tmp_path))
    loaded = factor_cache.FactorCache.load(str(tmp_path))
    assert sorted(loaded.entries) == sorted(cache.entries)
    back = next(iter(loaded.entries.values()))
    assert np.asarray(back.state.theta).dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(back.state.theta),
                                  np.asarray(
        next(iter(cache.entries.values())).state.theta))
    r2 = engine.CVEngine(strat(), cache=loaded, precision="bf16_store"
                         ).run(folds, LAMS)
    assert r2.extras["engine"]["cache"]["status"] == "hit"
    np.testing.assert_array_equal(r1.errors, r2.errors)


def test_warm_replay_zero_factorizations_under_bf16():
    """The warm-replay contract survives the policy: a bf16 warm sweep
    traces zero cholesky calls and replays its own cold sweep exactly."""
    folds = props.regression_folds(h=32, k=4, dtype=jnp.float32)
    strat = lambda: engine.PiCholeskyStrategy(g=4, block=8)  # noqa: E731
    cache = factor_cache.FactorCache()
    r_cold = engine.CVEngine(strat(), cache=cache, precision="bf16_refined"
                             ).run(folds, LAMS)
    bk = CountingBackend(ReferenceBackend())
    warm = engine.CVEngine(strat(), backend=bk, cache=cache,
                           precision="bf16_refined")
    r_warm = warm.run(folds, LAMS)
    assert bk.n_cholesky == 0
    assert r_warm.extras["engine"]["cache"]["status"] == "hit"
    np.testing.assert_array_equal(r_warm.errors, r_cold.errors)


# ------------------------------------------------------------- entry points


def test_best_lam_stays_at_fit_dtype_not_data_dtype():
    """ridge_cv satellite: λ* must never quantize to a bf16 design's dtype
    — the refit at λ* uses the CV-selected regularizer, not its bf16
    rounding (a different model)."""
    from repro.core import solvers
    from repro.core.ridge_cv import RidgeCV

    x64 = jax.random.normal(jax.random.PRNGKey(0), (96, 16), jnp.float64)
    y64 = jax.random.normal(jax.random.PRNGKey(1), (96,), jnp.float64)
    x, y = x64.astype(jnp.bfloat16), y64.astype(jnp.bfloat16)
    model = RidgeCV(k_folds=4, n_lambdas=11, block=8)
    theta, result = model.fit_theta(x, y)
    lam_dtype = resolve_precision(None).fit_dtype(x.dtype)
    assert lam_dtype == jnp.float32               # floored, not bf16
    # the λ the solve actually used is the fp32 λ*, not its bf16 rounding
    expect = solvers.solve_cholesky(x.T @ x, x.T @ y,
                                    jnp.asarray(result.best_lam, lam_dtype))
    np.testing.assert_array_equal(theta, expect)
    # and the fp32 λ* genuinely differs from what the old x.dtype cast
    # would have handed the solver (the quantization the fix removes)
    assert float(jnp.asarray(result.best_lam, jnp.bfloat16)) \
        != float(result.best_lam)
