"""Property-based tests (hypothesis) for the packing layout and the
interpolation basis — the two invariants every engine strategy leans on."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import packing, picholesky
from repro.testing import strategies as props

# shared generator (repro.testing.strategies): well-conditioned SPD test
# Hessians — one definition across the property suites
_spd = props.spd_matrix


# ---------------------------------------------------------------- packing


@given(h=st.integers(2, 96), block=st.sampled_from([4, 8, 16, 32]))
@settings(max_examples=30, deadline=None)
def test_pack_unpack_roundtrip_any_shape(h, block):
    """unpack(pack(M)) == tril(M) for arbitrary (h, block), including
    h < block, h == block, and ragged h % block."""
    m = jnp.asarray(np.random.RandomState(h * 101 + block).randn(h, h))
    back = packing.unpack_tril(packing.pack_tril(m, block), h, block)
    np.testing.assert_allclose(np.asarray(back), np.tril(m))


@given(h=st.integers(4, 48), block=st.sampled_from([4, 8, 16]),
       batch=st.integers(1, 4))
@settings(max_examples=20, deadline=None)
def test_pack_unpack_roundtrip_batched(h, block, batch):
    """The round-trip holds under leading batch dims (the engine packs
    (g, h, h) factor stacks under vmap over folds)."""
    m = jnp.asarray(np.random.RandomState(h + block + batch).randn(batch, h, h))
    v = packing.pack_tril(m, block)
    assert v.shape == (batch, packing.packed_size(h, block))
    back = packing.unpack_tril(v, h, block)
    np.testing.assert_allclose(np.asarray(back), np.tril(np.asarray(m)))


@given(h=st.integers(2, 64), block=st.sampled_from([4, 8, 16]))
@settings(max_examples=20, deadline=None)
def test_packed_mask_counts_true_entries(h, block):
    mask = packing.tril_mask_packed(h, block)
    assert int(mask.sum()) == h * (h + 1) // 2


# ------------------------------------------------------------ vandermonde


@given(degree=st.integers(1, 3), g_extra=st.integers(1, 3),
       seed=st.integers(0, 50))
@settings(max_examples=15, deadline=None)
def test_fitted_interpolants_basis_equivalence(degree, g_extra, seed):
    """Monomial and centered Vandermonde bases span the same polynomial
    space, so the *fitted interpolants* (Algorithm 1 output) must agree at
    every λ — for any degree and any sample count g > degree."""
    h = 24
    hess = _spd(h, seed)
    g = degree + g_extra
    sample = picholesky.choose_sample_lambdas(1e-2, 10.0, g)
    lams = jnp.logspace(-2, 1, 9)
    m_mono = picholesky.fit(hess, sample, degree, block=8, basis="monomial")
    m_cent = picholesky.fit(hess, sample, degree, block=8, basis="centered")
    a = np.asarray(m_mono.eval_factor(lams))
    b = np.asarray(m_cent.eval_factor(lams))
    scale = np.max(np.abs(a)) + 1e-30
    assert np.max(np.abs(a - b)) / scale < 1e-6


@given(degree=st.integers(0, 4), seed=st.integers(0, 30))
@settings(max_examples=15, deadline=None)
def test_vandermonde_columns_are_shifted_powers(degree, seed):
    lams = jnp.asarray(np.random.RandomState(seed).uniform(0.1, 5.0, size=6))
    center = float(np.random.RandomState(seed + 1).uniform(0.0, 2.0))
    v = picholesky.vandermonde(lams, degree, center)
    assert v.shape == (6, degree + 1)
    for p in range(degree + 1):
        np.testing.assert_allclose(np.asarray(v[:, p]),
                                   (np.asarray(lams) - center) ** p)


@given(degree=st.integers(1, 2), seed=st.integers(0, 20))
@settings(max_examples=10, deadline=None)
def test_interpolation_at_nodes_when_g_equals_degree_plus_one(degree, seed):
    """g = r+1 makes the least-squares fit an interpolation: exact at the
    sample nodes regardless of basis."""
    hess = _spd(16, seed)
    sample = picholesky.choose_sample_lambdas(1e-1, 1.0, degree + 1)
    for basis in ("monomial", "centered"):
        model = picholesky.fit(hess, sample, degree, block=8, basis=basis)
        for lam in np.asarray(sample):
            l_i = model.eval_factor(jnp.asarray(lam))
            l_e = jnp.linalg.cholesky(
                hess + lam * jnp.eye(16, dtype=hess.dtype))
            assert float(jnp.max(jnp.abs(l_i - l_e))) < 1e-7
