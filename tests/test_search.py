"""Adaptive λ-refinement search and self-tuning interpolation.

The tentpole contracts live here:

* **selection fidelity** — on the suite's unimodal hold-out curves the
  search recovers the dense grid's λ* to within the interval tolerance
  (plus one dense-grid step, the dense argmin's own quantization), using
  STRICTLY fewer λ evaluations than the dense grid, on both backends,
  cold and warm;
* **zero-factorization composition** — a warm cache serves the search's
  state stage with zero cholesky traces, and interpolant selection
  against cached anchor targets factorizes nothing;
* **degenerate-grid refusal** — q=0 and q=1 grids fail fast with typed,
  descriptive errors at every engine entry point instead of opaque shape
  errors deep in jit.
"""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import bound, engine, factor_cache, picholesky
from repro.core.backends import CountingBackend, ReferenceBackend
from repro.core.folds import CVResult
from repro.testing import strategies as props


@pytest.fixture(scope="module")
def folds():
    return props.regression_folds(h=32, n=256, k=4)


#: dense baseline whose argmin sits mid-range (same problem as the async
#: suite) — dense spacing 5/47 ≈ 0.106 decades
DENSE = props.log_grid(48)
#: denser baseline for the ≤ 50 %-of-grid economics the bench commits to
DENSE96 = props.log_grid(96)
LAMS = props.log_grid(17)


def _strat(**kw):
    kw.setdefault("g", 4)
    kw.setdefault("block", 8)
    return engine.PiCholeskyStrategy(**kw)


def _grid_step(lams):
    x = np.log10(np.asarray(lams))
    return float((x.max() - x.min()) / (x.size - 1))


# ----------------------------------------------- search ≈ dense (property)


@pytest.mark.tier2
@given(backend=props.backend_names(), warm=st.booleans(),
       q=st.sampled_from([48, 64]))
@settings(max_examples=6, deadline=None)
def test_search_recovers_dense_argmin(backend, warm, q):
    """Property: the adaptive search's λ* agrees with the dense grid's
    argmin to within ``tol_decades`` + one dense-grid step, with strictly
    fewer evaluations — both backends, cold and warm-cache."""
    folds = props.regression_folds(h=32, n=256, k=4)
    lams = props.log_grid(q)
    tol = 0.05
    bk = props.make_backend(backend)
    cache = factor_cache.FactorCache()
    eng = engine.CVEngine(_strat(), backend=bk, cache=cache, lam_chunk=8)
    dense = eng.run(folds, lams)
    assert eng.search(folds, lams, tol_decades=tol)  # warms the cache
    eng2 = eng if warm else engine.CVEngine(_strat(), backend=bk,
                                            lam_chunk=8)
    r = eng2.search(folds, lams, tol_decades=tol)
    info = r.extras["engine"]["search"]
    assert info["lams_evaluated"] < q
    assert info["lams_evaluated"] == r.errors.size
    gap = abs(np.log10(r.best_lam) - np.log10(dense.best_lam))
    assert gap <= tol + _grid_step(lams), (r.best_lam, dense.best_lam)
    if warm:
        assert r.extras["engine"]["cache"]["status"] in ("hit", "refit")


def test_search_result_contract(folds):
    """The returned CVResult covers every evaluated λ, sorted, with the
    search trace recorded; the coarse wave spans the grid's range."""
    r = engine.CVEngine(_strat(), lam_chunk=8).search(folds, DENSE96)
    info = r.extras["engine"]["search"]
    lams = np.asarray(r.lams)
    assert np.all(np.diff(lams) > 0)
    assert lams.size == info["lams_evaluated"]
    assert lams.min() == pytest.approx(float(np.asarray(DENSE96).min()))
    assert lams.max() == pytest.approx(float(np.asarray(DENSE96).max()))
    assert info["dense_q"] == 96
    assert info["evals_vs_grid"] == pytest.approx(lams.size / 96)
    assert info["stopped_on"] == "interval"
    assert info["interval_decades"] <= info["tol_decades"]
    assert info["waves"] * info["wave"] == lams.size
    # the committed bench economics: ≤ half the dense grid's evaluations
    assert info["evals_vs_grid"] <= 0.5
    dense = engine.CVEngine(_strat()).run(folds, DENSE96)
    gap = abs(np.log10(r.best_lam) - np.log10(dense.best_lam))
    assert gap <= info["tol_decades"] + _grid_step(DENSE96)


def test_search_warm_cache_zero_factorizations(folds):
    """A run()-populated cache serves the search's state stage: zero
    cholesky traces, n_exact_chol == 0, every wave is interp-solves."""
    cache = factor_cache.FactorCache()
    engine.CVEngine(_strat(), cache=cache).run(folds, DENSE)
    bk = CountingBackend(ReferenceBackend())
    eng = engine.CVEngine(_strat(), backend=bk, cache=cache, lam_chunk=8)
    r = eng.search(folds, DENSE)
    assert bk.n_cholesky == 0
    assert r.n_exact_chol == 0
    assert r.extras["engine"]["cache"]["status"] == "hit"
    assert bk.stage_count("fold_errors", "interp_solve") > 0


def test_search_exact_strategy_counts_per_eval(folds):
    """The exact strategy factorizes per evaluated λ — the search's
    n_exact_chol accounting must reflect evaluations, not the dense q."""
    r = engine.CVEngine("exact", lam_chunk=8).search(folds, DENSE)
    info = r.extras["engine"]["search"]
    k = folds.fold_hess.shape[0]
    assert r.n_exact_chol == k * info["lams_evaluated"]
    assert info["lams_evaluated"] < DENSE.size


def test_search_wave_knob_and_padding(folds):
    r = engine.CVEngine(_strat(), lam_chunk=8).search(folds, DENSE, wave=5)
    assert r.extras["engine"]["search"]["wave"] == 5
    # chunk-derived default: capped at 8, floored at 3
    r2 = engine.CVEngine(_strat(), lam_chunk=4).search(folds, DENSE)
    assert r2.extras["engine"]["search"]["wave"] == 4
    r3 = engine.CVEngine(_strat(), lam_chunk=1).search(folds, DENSE)
    assert r3.extras["engine"]["search"]["wave"] == 3


def test_search_plateau_and_max_waves_termination(folds):
    """plateau_tol=1.0 can never register an improvement after the first
    wave, so patience waves later the plateau stop fires; max_waves caps
    the wave count when both tolerances are out of reach."""
    eng = engine.CVEngine(_strat(), lam_chunk=8)
    r = eng.search(folds, DENSE, tol_decades=1e-6, plateau_tol=1.0,
                   plateau_patience=2)
    info = r.extras["engine"]["search"]
    assert info["stopped_on"] == "plateau"
    assert info["waves"] == 3            # first improves, then 2 flat
    r2 = eng.search(folds, DENSE, tol_decades=1e-9, max_waves=2)
    info2 = r2.extras["engine"]["search"]
    assert info2["stopped_on"] == "max_waves" and info2["waves"] == 2


def test_search_knob_validation(folds):
    eng = engine.CVEngine(_strat())
    with pytest.raises(ValueError, match="tol_decades"):
        eng.search(folds, DENSE, tol_decades=0.0)
    with pytest.raises(ValueError, match="plateau_tol"):
        eng.search(folds, DENSE, plateau_tol=-0.1)
    with pytest.raises(ValueError, match="plateau_patience"):
        eng.search(folds, DENSE, plateau_tol=0.1, plateau_patience=0)
    with pytest.raises(ValueError, match="max_waves"):
        eng.search(folds, DENSE, max_waves=0)
    with pytest.raises(ValueError, match="wave"):
        eng.search(folds, DENSE, wave=2)
    with pytest.raises(ValueError, match="positive"):
        eng.search(folds, jnp.asarray([0.0, 1.0, 10.0]))


def test_search_refuses_nonfinite_wave(folds):
    bad = folds._replace(y_folds=folds.y_folds.at[0, 0].set(jnp.nan))
    with pytest.raises(FloatingPointError, match="no finite"):
        engine.CVEngine(_strat(), lam_chunk=8).search(bad, DENSE)


# ------------------------------------------------- degenerate λ grids


def test_empty_grid_raises_everywhere(folds):
    """q=0 fails fast with the engine's message at EVERY entry point —
    regression: run() used to die with an opaque reshape error and
    run_async() with IndexError."""
    empty = jnp.asarray([], dtype=jnp.float64)
    eng = engine.CVEngine(_strat())
    for call in (lambda: eng.run(folds, empty),
                 lambda: eng.run_async(folds, empty),
                 lambda: next(eng.sweep_async(folds, empty)),
                 lambda: eng.run_batch([(folds, empty)]),
                 lambda: eng.search(folds, empty)):
        with pytest.raises(ValueError, match="empty λ grid"):
            call()


def test_single_lam_grid_consistent_and_search_refuses(folds):
    """q=1 is a point evaluation: run/run_async/run_batch agree on the
    exact strategy (no anchors to degenerate), while search refuses —
    a single λ defines no range to refine."""
    one = jnp.asarray([0.1])
    r = engine.CVEngine("exact").run(folds, one)
    ra = engine.CVEngine("exact").run_async(folds, one, stop_tol=0.0,
                                            stop_patience=2)
    (rb,) = engine.CVEngine("exact").run_batch([(folds, one)])
    assert r.best_lam == ra.best_lam == rb.best_lam == 0.1
    np.testing.assert_array_equal(r.errors, ra.errors)
    assert not ra.extras["engine"]["async"]["stopped"]
    with pytest.raises(ValueError, match="single λ"):
        engine.CVEngine(_strat()).search(folds, one)
    # picholesky on q=1: every anchor collapses to the same λ, the fit is
    # singular and the curve all-NaN — flagged, never a silent nan pick
    with pytest.raises(FloatingPointError):
        engine.CVEngine(_strat()).run(folds, one)


def test_from_errors_ranking_guards():
    with pytest.raises(ValueError, match="empty"):
        CVResult.from_errors(np.empty(0), np.empty(0), 0)
    with pytest.raises(FloatingPointError, match="no finite"):
        CVResult.from_errors(np.asarray([0.1, 1.0]),
                             np.asarray([np.nan, np.inf]), 0)
    r = CVResult.from_errors(np.asarray([0.1, 1.0, 2.0]),
                             np.asarray([np.nan, 0.5, 1.0]), 0)
    assert r.best_lam == 1.0 and r.best_error == 0.5


# ------------------------------------------- interpolant self-selection


def _poly_targets(lams, coeffs):
    """(g, P) targets exactly polynomial in λ with vector coefficients."""
    lam = np.asarray(lams)
    return np.sum([np.outer(lam**i, c) for i, c in enumerate(coeffs)],
                  axis=0)


def test_loo_scores_identify_generating_degree():
    """Targets exactly quadratic in λ: degree 1 underfits by orders of
    magnitude, degree ≥ 2 reproduces them to rounding — and the tie
    breaks toward the SIMPLEST candidate, so degree 2 is selected."""
    rng = np.random.default_rng(0)
    lam = np.logspace(-2, 1, 6)
    t = _poly_targets(lam, [rng.normal(size=40) for _ in range(3)])
    scores = picholesky.loo_interp_scores(t, lam, (1, 2, 3),
                                          bases=("monomial",))
    assert scores[(1, "monomial")] > 1e3 * scores[(2, "monomial")]
    sel = picholesky.select_interpolant(t, lam, bases=("monomial",))
    assert sel["degree"] == 2
    assert sel["score"] == pytest.approx(scores[(2, "monomial")], rel=1e-6)
    assert set(sel["scores"]) == {f"monomial/r{r}" for r in (1, 2, 3, 4)}


def test_loo_scores_validation():
    lam = np.logspace(-2, 1, 4)
    t = _poly_targets(lam, [np.ones(8), np.ones(8)])
    with pytest.raises(ValueError, match="g - 1 > degree"):
        picholesky.loo_interp_scores(t, lam, (3,))
    with pytest.raises(ValueError, match="basis"):
        picholesky.loo_interp_scores(t, lam, (1,), bases=("chebyshev",))
    with pytest.raises(ValueError, match="degrees"):
        picholesky.select_interpolant(t, lam, ())


def test_engine_select_interpolant_zero_chol_on_anchor_hit(folds):
    """Selection against a warm anchor cache factorizes NOTHING; a cold
    selection parks an anchors-only entry the subsequent sweep refits
    from — still zero factorizations for the sweep's state stage."""
    cache = factor_cache.FactorCache()
    bk = CountingBackend(ReferenceBackend())
    eng = engine.CVEngine(_strat(), backend=bk, cache=cache,
                          cache_anchors=True)
    sel = eng.select_interpolant(folds, LAMS)
    assert sel["anchor_status"] == "cold+cached"
    assert bk.n_cholesky > 0
    assert len(sel["anchors"]) == sel["g"] == 4

    bk.reset()
    sel2 = eng.select_interpolant(folds, LAMS)
    assert sel2["anchor_status"] == "anchors"
    assert bk.n_cholesky == 0                      # the tentpole floor
    assert (sel2["degree"], sel2["basis"]) == (sel["degree"], sel["basis"])

    # the winning engine's sweep refits Θ from the parked anchors
    win = eng.with_interpolant(sel["degree"], sel["basis"])
    r = win.run(folds, LAMS)
    assert r.extras["engine"]["cache"]["status"] in ("refit", "hit")
    assert bk.n_cholesky == 0


def test_engine_select_interpolant_cold_without_cache(folds):
    eng = engine.CVEngine(_strat())
    sel = eng.select_interpolant(folds, LAMS)
    assert sel["anchor_status"] == "cold"
    assert sel["degree"] in (1, 2) and sel["basis"] in ("monomial",
                                                        "centered")
    with pytest.raises(ValueError, match="picholesky"):
        engine.CVEngine("exact").select_interpolant(folds, LAMS)


def test_search_select_interp_records_choice(folds):
    cache = factor_cache.FactorCache()
    eng = engine.CVEngine(_strat(), cache=cache, cache_anchors=True,
                          lam_chunk=8)
    r = eng.search(folds, DENSE, select_interp=True)
    sel = r.extras["engine"]["interp_selection"]
    assert sel["degree"] in range(1, 3) and "scores" in sel
    assert r.extras["engine"]["search"]["lams_evaluated"] < DENSE.size


def test_with_interpolant_identity_and_memoization(folds):
    eng = engine.CVEngine(_strat())
    assert eng.with_interpolant(eng.strategy.degree,
                                eng.strategy.basis) is eng
    d1 = eng.with_interpolant(1, "centered")
    assert d1 is not eng
    assert (d1.strategy.degree, d1.strategy.basis) == (1, "centered")
    assert d1 is eng.with_interpolant(1, "centered")
    assert d1.strategy.g == eng.strategy.g
    with pytest.raises(ValueError, match="picholesky"):
        engine.CVEngine("exact").with_interpolant(1, "monomial")


# --------------------------------------------- bound-guided anchor advice


def test_anchor_advisor_scores_and_proposal():
    a = props.spd_matrix(8)
    anchors = np.logspace(-2, 2, 4)
    out = bound.anchor_advisor(a, anchors, n_grid=3)
    assert len(out["intervals"]) == len(out["scores"]) == 3
    assert 0 <= out["worst"] < 3
    lo, hi = out["intervals"][out["worst"]]
    assert lo < out["proposal"] < hi
    assert out["proposal"] == pytest.approx(
        10.0 ** (0.5 * (np.log10(lo) + np.log10(hi))))
    assert out["scores"][out["worst"]] == max(out["scores"])


def test_anchor_advisor_validation():
    a = props.spd_matrix(6)
    with pytest.raises(ValueError, match="at least 2"):
        bound.anchor_advisor(a, [1.0])
    with pytest.raises(ValueError, match="positive"):
        bound.anchor_advisor(a, [-1.0, 1.0])


def test_engine_advise_anchor_probe(folds):
    eng = engine.CVEngine(_strat())
    out = eng.advise_anchor(folds, LAMS, probe_dim=16, n_grid=3)
    assert out["probe_dim"] == 16
    assert len(out["anchors"]) == 4
    assert len(out["intervals"]) == 3
    lo, hi = out["intervals"][out["worst"]]
    assert lo < out["proposal"] < hi
    # probe_dim larger than h clamps to h
    out2 = eng.advise_anchor(folds, LAMS, probe_dim=4096, n_grid=3)
    assert out2["probe_dim"] == folds.fold_hess.shape[-1]
    with pytest.raises(ValueError, match="anchored"):
        engine.CVEngine("exact").advise_anchor(folds, LAMS)


# ------------------------------------------------ anchors-only cache entries


def test_anchors_only_entry_semantics(folds, tmp_path):
    """An anchors-only entry (selection's parking spot) serves
    get_anchors but never lookup — and survives a save/load round-trip
    without a state record."""
    cache = factor_cache.FactorCache()
    eng = engine.CVEngine(_strat(), cache=cache, cache_anchors=True)
    eng.select_interpolant(folds, LAMS)
    assert len(cache) == 1
    (entry,) = cache.entries.values()
    assert entry.state is None and entry.anchors is not None
    key = entry.key
    assert cache.lookup(key, policy="exact") is None
    assert cache.lookup(key, policy="covering") is None
    assert cache.get_anchors(key) is not None

    cache.save(str(tmp_path))
    loaded = factor_cache.FactorCache.load(str(tmp_path))
    assert len(loaded) == 1
    (back,) = loaded.entries.values()
    assert back.state is None
    np.testing.assert_array_equal(np.asarray(back.anchors.vec),
                                  np.asarray(entry.anchors.vec))

    with pytest.raises(ValueError, match="anchors"):
        cache.put(key, None, None)
