"""Multi-tenant sweep serving: admission batching, cross-tenant cache
sharing, per-tenant isolation.

The acceptance contracts live here:

* **serving fidelity** — a request served through the batched admission
  path returns exactly what a solo cold :meth:`CVEngine.run` of the same
  problem would (bit-for-bit error curve, hence bit-for-bit argmin);
* **cross-tenant sharing** — two tenants with byte-identical training
  Hessians share anchors across requests (hit or anchor refit, zero new
  factorizations) while a perturbed Hessian MUST miss — and under LRU
  eviction pressure a tenant is never served another problem's stale
  factors;
* **isolation** — ``take_responses(tenant)`` yields only that tenant's
  results.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine, factor_cache
from repro.core.backends import CountingBackend, ReferenceBackend
from repro.serving import (CVSweepServer, ServerConfig, SweepRequest,
                           TrafficConfig, make_traffic)
from repro.testing import strategies as props

LAMS = props.log_grid(17)
LAMS2 = props.log_grid(25)                  # same decades → same anchors
SHIFTED = props.log_grid(17, -2.0, 3.0)     # different decades → different


def _strat(**kw):
    kw.setdefault("g", 4)
    kw.setdefault("block", 8)
    return engine.PiCholeskyStrategy(**kw)


def _folds(seed=1, **kw):
    kw.setdefault("h", 20)
    kw.setdefault("n", 160)
    return props.regression_folds(seed=seed, **kw)


def _server(**cfg_kw):
    return CVSweepServer(_strat(), config=ServerConfig(**cfg_kw))


def _solo(folds, lams, **kw):
    """The solo cold reference: same strategy, fresh cache-attached engine
    (the state+replay split the serving path also runs)."""
    eng = engine.CVEngine(_strat(), cache=factor_cache.FactorCache(),
                          reuse="covering", cache_anchors=True, **kw)
    return eng.run(folds, lams)


# ----------------------------------------------------------- traffic


def test_traffic_is_deterministic():
    cfg = TrafficConfig(n_requests=16, n_problems=3, h=12, n=96)
    a, b = make_traffic(cfg), make_traffic(cfg)
    assert len(a) == len(b) == 16
    for ra, rb in zip(a, b):
        assert ra.tenant == rb.tenant
        np.testing.assert_array_equal(ra.lams, rb.lams)
        np.testing.assert_array_equal(ra.folds.hess, rb.folds.hess)
    # a different seed reshuffles the problem mix
    c = make_traffic(TrafficConfig(n_requests=16, n_problems=3, h=12, n=96,
                                   seed=7))
    assert any(not np.array_equal(ra.folds.hess, rc.folds.hess)
               for ra, rc in zip(a, c))


def test_traffic_zipf_head_dominates():
    """The Zipf mix must actually overlap: the hottest problem draws more
    requests than a uniform share (that overlap IS the cache
    opportunity)."""
    cfg = TrafficConfig(n_requests=64, n_problems=8, h=12, n=96, zipf_a=1.3)
    reqs = make_traffic(cfg)
    counts = {}
    for r in reqs:
        counts[id(r.folds)] = counts.get(id(r.folds), 0) + 1
    assert max(counts.values()) > 64 / 8


# ----------------------------------------------------- serving fidelity


def test_batched_serving_matches_solo_cold_bitwise():
    """Acceptance: per-tenant results through the admission batch are
    bit-for-bit the solo cold sweep's — stacking reorders batching, never
    arithmetic."""
    fa, fb = _folds(seed=1), _folds(seed=2)
    srv = _server(max_batch=4)
    for req in [SweepRequest("a", fa, LAMS), SweepRequest("b", fb, LAMS),
                SweepRequest("c", fa, LAMS2)]:
        srv.submit(req)
    resps = {r.tenant: r for r in srv.drain()}
    for tenant, folds, lams in [("a", fa, LAMS), ("b", fb, LAMS),
                                ("c", fa, LAMS2)]:
        solo = _solo(folds, lams)
        np.testing.assert_array_equal(resps[tenant].result.errors,
                                      solo.errors)
        assert resps[tenant].result.best_lam == solo.best_lam


def test_in_batch_duplicate_is_single_factorization():
    """Two tenants submitting the identical problem in one batch: one cold
    factorization, the duplicate served as a cache hit, identical bits."""
    f = _folds(seed=3)
    bk = CountingBackend(ReferenceBackend())
    srv = CVSweepServer(_strat(), backend=bk, config=ServerConfig())
    srv.submit(SweepRequest("t0", f, LAMS))
    srv.submit(SweepRequest("t1", f, LAMS))
    resps = srv.drain()
    assert sorted(r.status for r in resps) == ["hit", "miss"]
    assert bk.n_cholesky > 0                      # the one cold factorization
    by_status = {r.status: r for r in resps}
    assert by_status["miss"].result.n_exact_chol == _strat().n_exact_chol(
        f.fold_hess.shape[0], LAMS.shape[0])
    assert by_status["hit"].result.n_exact_chol == 0
    np.testing.assert_array_equal(resps[0].result.errors,
                                  resps[1].result.errors)


def test_admission_groups_by_geometry():
    """Different anchor ranges (and fold geometries) are admitted into
    separate groups — each dispatch is one compatible batch."""
    f = _folds(seed=1)
    srv = _server(max_batch=8)
    srv.submit(SweepRequest("a", f, LAMS))
    srv.submit(SweepRequest("b", f, SHIFTED))
    srv.submit(SweepRequest("c", f, LAMS2))     # same anchors as "a"
    assert len(srv._queues) == 2
    first = srv.step()
    assert {r.tenant for r in first} == {"a", "c"}   # one fused dispatch
    assert all(r.batch_size == 2 for r in first)
    second = srv.step()
    assert [r.tenant for r in second] == ["b"]
    assert srv.pending == 0


def test_fifo_across_groups():
    """The group whose head request is oldest is served first."""
    f = _folds(seed=1)
    srv = _server()
    srv.submit(SweepRequest("early", f, SHIFTED))
    srv.submit(SweepRequest("late", f, LAMS))
    assert [r.tenant for r in srv.step()] == ["early"]


# ------------------------------------- cross-tenant sharing (satellite 4)


def test_identical_hessians_share_across_tenants_zero_chol():
    """Two tenants, byte-identical Hessians, different λ grids over the
    same decades: the second tenant's request is served warm with ZERO new
    factorizations."""
    f1 = _folds(seed=5)
    f2 = _folds(seed=5)           # rebuilt → different arrays, same bytes
    np.testing.assert_array_equal(f1.hess, f2.hess)
    bk = CountingBackend(ReferenceBackend())
    srv = CVSweepServer(_strat(), backend=bk, config=ServerConfig())
    srv.submit(SweepRequest("alice", f1, LAMS))
    srv.drain()
    cold = bk.n_cholesky
    srv.submit(SweepRequest("bob", f2, LAMS2))
    (resp,) = srv.drain()
    assert resp.status in ("hit", "refit")
    assert bk.n_cholesky == cold                 # zero new factorizations
    assert srv.cache.tenant_stats["bob"]["hits"] == 1
    assert srv.cache.hit_rate("bob") == 1.0
    np.testing.assert_array_equal(resp.result.errors,
                                  _solo(f2, LAMS2).errors)


def test_perturbed_hessian_misses():
    """A tenant whose design is perturbed at 1e-9 must MISS — content
    addressing, not identity, decides sharing."""
    base = _folds(seed=6)
    pert = _folds(seed=6, jitter=1e-9)
    assert not np.array_equal(base.hess, pert.hess)
    srv = _server()
    srv.submit(SweepRequest("a", base, LAMS))
    srv.submit(SweepRequest("b", pert, LAMS))
    resps = {r.tenant: r for r in srv.drain()}
    assert resps["a"].status == "miss" and resps["b"].status == "miss"
    assert srv.cache.tenant_stats["b"]["hits"] == 0
    np.testing.assert_array_equal(resps["b"].result.errors,
                                  _solo(pert, LAMS).errors)


def test_no_stale_reads_under_eviction_pressure():
    """LRU pressure (budget ≈ 2 entries, 4 distinct problems × 2 tenants)
    must never serve a stale entry: every response still equals its solo
    cold sweep bit-for-bit."""
    problems = [_folds(seed=s) for s in (10, 11, 12, 13)]
    one = _server()
    one.submit(SweepRequest("size", problems[0], LAMS))
    one.drain()
    entry_bytes = next(iter(one.cache.entries.values())).nbytes

    srv = CVSweepServer(_strat(), config=ServerConfig(
        max_batch=2, cache_bytes=2 * entry_bytes + entry_bytes // 2))
    for round_ in range(2):
        for i, f in enumerate(problems):
            srv.submit(SweepRequest(f"t{i % 2}", f, LAMS))
        for resp in srv.drain():
            pass
    assert srv.cache.evictions > 0
    # replay the whole mix once more and check bits against solo refs
    refs = [_solo(f, LAMS).errors for f in problems]
    for i, f in enumerate(problems):
        srv.submit(SweepRequest("probe", f, LAMS))
    for resp, ref in zip(srv.drain(), refs):
        np.testing.assert_array_equal(resp.result.errors, ref)


# ----------------------------------------------------------- isolation


def test_per_tenant_response_isolation():
    f = _folds(seed=1)
    srv = _server(max_batch=4)
    for t in ("a", "b", "a"):
        srv.submit(SweepRequest(t, f, LAMS))
    srv.drain()
    got_a = srv.take_responses("a")
    got_b = srv.take_responses("b")
    assert len(got_a) == 2 and all(r.tenant == "a" for r in got_a)
    assert len(got_b) == 1 and got_b[0].tenant == "b"
    assert srv.take_responses("a") == []        # popped, not peeked
    assert srv.take_responses("nobody") == []


def test_tenant_stats_partition_sums_to_global():
    cfg = TrafficConfig(n_requests=18, n_tenants=3, n_problems=3,
                        h=12, n=96)
    srv = _server(max_batch=6)
    for req in make_traffic(cfg):
        srv.submit(req)
    srv.drain()
    st = srv.stats
    assert st["served"] == 18
    assert sum(t["hits"] for t in st["tenants"].values()) == \
        st["cache"]["hits"]
    assert sum(t["misses"] for t in st["tenants"].values()) == \
        st["cache"]["misses"]
    assert srv.cache.hit_rate() > 0
    assert sum(1 for t in st["tenants"].values() if t["hits"]) >= 2


# ------------------------------------------------------ engine run_batch


def test_run_batch_falls_back_on_mixed_geometry():
    """Incompatible fold shapes degrade to per-problem runs — same
    results, no stacked dispatch."""
    fa, fc = _folds(seed=1), _folds(seed=2, h=12, n=96)
    eng = engine.CVEngine(_strat(), cache=factor_cache.FactorCache(),
                          reuse="covering", cache_anchors=True)
    res = eng.run_batch([(fa, LAMS), (fc, LAMS)], tenants=["a", "c"])
    for r, (f, l) in zip(res, [(fa, LAMS), (fc, LAMS)]):
        assert "batch" not in r.extras["engine"]
        np.testing.assert_array_equal(r.errors, _solo(f, l).errors)
    assert set(eng.cache.tenant_stats) == {"a", "c"}


def test_run_batch_requires_matching_tenants():
    f = _folds(seed=1)
    eng = engine.CVEngine(_strat(), cache=factor_cache.FactorCache())
    with pytest.raises(ValueError, match="tenant"):
        eng.run_batch([(f, LAMS)], tenants=["a", "b"])
    assert eng.run_batch([]) == []


def test_run_batch_without_cache_falls_back():
    f = _folds(seed=1)
    eng = engine.CVEngine(_strat())
    (r,) = eng.run_batch([(f, LAMS)])
    np.testing.assert_array_equal(
        r.errors, engine.CVEngine(_strat()).run(f, LAMS).errors)


# -------------------------------------- admission validation (satellite)


def test_rejected_precision_leaves_pool_untouched():
    """Regression: ``_admission_key`` used to instantiate a pooled engine
    just to read the policy name, so a request with a BOGUS precision
    preset left a zombie engine in the pool even though submit raised.
    Rejection must now be side-effect free."""
    srv = _server()
    with pytest.raises(ValueError, match="precision"):
        srv.submit(SweepRequest("a", _folds(seed=1), LAMS,
                                precision="float128_maybe"))
    assert srv._engines == {}
    assert srv.pending == 0
    assert srv._next_id == 0          # the rejected request got no id


def test_rejected_mode_leaves_queue_untouched():
    srv = _server()
    with pytest.raises(ValueError, match="mode"):
        srv.submit(SweepRequest("a", _folds(seed=1), LAMS, mode="binary"))
    assert srv.pending == 0 and srv._engines == {}


def test_admission_key_includes_lam_dtype_and_mode():
    """The λ-grid dtype shapes the chunk-stage jit signature, so float32
    and float64 grids must not fuse; grid and search requests never fuse
    either.  Computing the key itself must not touch the engine pool."""
    srv = _server()
    f = _folds(seed=1)
    l64 = jnp.asarray(np.asarray(LAMS), jnp.float64)
    l32 = jnp.asarray(np.asarray(LAMS), jnp.float32)
    k64 = srv._admission_key(SweepRequest("a", f, l64))
    k32 = srv._admission_key(SweepRequest("a", f, l32))
    ks = srv._admission_key(SweepRequest("a", f, l64, mode="search"))
    assert "float64" in k64 and "float32" in k32
    assert k64 != k32
    assert ks != k64 and ks[0] == "search" and k64[0] == "grid"
    assert srv._engines == {}


# ----------------------------------------------- mode='search' requests


def test_search_mode_served_with_fewer_evals():
    """A search-mode request is served through the adaptive refinement —
    far fewer λ evaluations than the grid — and its anchor factorizations
    populate the SHARED cache, so a grid request that follows is warm."""
    f = _folds(seed=7)
    dense = props.log_grid(96)
    srv = _server(max_batch=8, search_tol=0.05, search_wave=6)
    srv.submit(SweepRequest("a", f, dense, mode="search"))
    srv.submit(SweepRequest("b", f, dense, mode="search"))
    assert len(srv._queues) == 1          # same geometry → one group
    (ra, rb) = srv.step()
    info = ra.result.extras["engine"]["search"]
    assert info["wave"] == 6              # ServerConfig knob forwarded
    assert info["tol_decades"] == 0.05
    assert info["lams_evaluated"] < dense.size
    assert ra.status == "miss"            # cold populate ...
    assert rb.status in ("hit", "refit")  # ... second rider is warm
    assert rb.result.n_exact_chol == 0

    # cross-mode sharing: the dense grid rides the same cache entry
    srv.submit(SweepRequest("a", f, dense, mode="grid"))
    (rg,) = srv.step()
    assert rg.status in ("hit", "refit")
    assert rg.result.errors.size == dense.size
    gap = abs(np.log10(ra.result.best_lam) - np.log10(rg.result.best_lam))
    assert gap <= info["tol_decades"] + 5.0 / 95.0
    assert srv.stats["served"] == 3


def test_search_and_grid_modes_never_fuse():
    f = _folds(seed=1)
    srv = _server()
    srv.submit(SweepRequest("a", f, LAMS, mode="grid"))
    srv.submit(SweepRequest("b", f, LAMS, mode="search"))
    assert len(srv._queues) == 2
    resps = srv.drain()
    modes = {r.tenant: "search" in r.result.extras["engine"]
             for r in resps}
    assert modes == {"a": False, "b": True}
    assert {r.batch_size for r in resps} == {1}
