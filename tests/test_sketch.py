"""Sketched anchor factorizations (tentpole property suite).

The accuracy/speed-frontier contracts live here:

* **sketch substrate** — every sketch method produces a seeded,
  reproducible, SPD sketched Gram with the right shape; SRHT with a
  full Hadamard (m ≥ next_pow2(n)) is *exact*; Gaussian concentration
  tightens with m.
* **IHS refinement** — with an adequately sized sketch the iterative
  Hessian-sketch loop contracts the solve error geometrically per
  iteration (Pilanci–Wainwright), so the engine's sketched hold-out
  curve converges to the dense curve as m grows.
* **no silent cross-serving** — the sketch descriptor is a first-class
  CacheKey field: perturbing method, m, seed, or IHS depth MISSES and
  repopulates, and a sketched factor can never serve an exact request
  (or vice versa).
* **downstream unchanged** — warm replay is bitwise, persistence
  round-trips, interpolant selection over sketched anchors parks
  anchors-only entries and factorizes nothing on a warm cache, both
  backends agree, and the async sweep equals the fused run.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings

from repro.core import bound, engine, factor_cache, picholesky, solvers
from repro.core import sketch as sk
from repro.core.backends import CountingBackend, ReferenceBackend
from repro.testing import strategies as props

LAMS = props.log_grid(17)


@pytest.fixture(scope="module")
def folds():
    return props.tall_skinny_folds()       # h=24, n=160, k=4 (n_tr=120)


def _strat(**kw):
    kw.setdefault("g", 4)
    kw.setdefault("block", 8)
    kw.setdefault("sketch", _plan())
    return engine.PiCholeskySketched(**kw)


def _plan(**kw):
    """Default test plan: SRHT at m = next_pow2(n_tr) — a full Hadamard,
    so the sketched Gram is exact and cache/replay asserts stay bitwise."""
    cfg = dict(method="srht", m=128, seed=0, ihs_iters=1)
    cfg.update(kw)
    return sk.SketchPlan(**cfg)


def _train_design(folds, f=0):
    """Training design/labels of fold f (rows of every other fold)."""
    x = np.asarray(folds.x_folds)
    y = np.asarray(folds.y_folds)
    keep = [i for i in range(x.shape[0]) if i != f]
    return (jnp.asarray(np.concatenate([x[i] for i in keep])),
            jnp.asarray(np.concatenate([y[i] for i in keep])))


# ------------------------------------------------------- sketch substrate


def test_fwht_orthonormal_involution():
    """The normalized Walsh–Hadamard transform is orthonormal and its own
    inverse; non-power-of-two lengths fail fast."""
    x = jnp.asarray(np.random.RandomState(0).randn(64, 5))
    hx = sk.fwht(x)
    np.testing.assert_allclose(np.asarray(sk.fwht(hx)), np.asarray(x),
                               rtol=1e-12, atol=1e-12)
    np.testing.assert_allclose(float(jnp.linalg.norm(hx)),
                               float(jnp.linalg.norm(x)), rtol=1e-12)
    with pytest.raises(ValueError, match="power-of-two"):
        sk.fwht(jnp.ones((48,)))
    assert sk.next_pow2(120) == 128 and sk.next_pow2(128) == 128


@pytest.mark.parametrize("method", sk.SKETCH_METHODS)
def test_sketch_shapes_and_gram_spd(folds, method):
    """S·X has m rows; the sketched Gram is symmetric PSD of shape (h, h)."""
    x, _ = _train_design(folds)
    plan = sk.SketchPlan(method=method, m=64, seed=3)
    sx = sk.sketch_rows(plan, x, plan.key_for(0))
    assert sx.shape == (min(64, sk.next_pow2(x.shape[0])
                            if method == "srht" else 64), x.shape[1])
    h_sk = sk.sketched_gram(plan, x, 0)
    assert h_sk.shape == (x.shape[1], x.shape[1])
    np.testing.assert_array_equal(np.asarray(h_sk), np.asarray(h_sk).T)
    evals = np.linalg.eigvalsh(np.asarray(h_sk))
    assert evals.min() >= -1e-8 * max(1.0, evals.max())


@pytest.mark.parametrize("method", sk.SKETCH_METHODS)
def test_sketch_reproducible_and_seed_sensitive(folds, method):
    """Same plan + fold index is bitwise reproducible; a different seed or
    fold index draws a different sketch."""
    x, _ = _train_design(folds)
    plan = sk.SketchPlan(method=method, m=64, seed=3)
    a = sk.sketched_gram(plan, x, 0)
    b = sk.sketched_gram(plan, x, 0)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    other_seed = sk.sketched_gram(sk.SketchPlan(method=method, m=64, seed=4),
                                  x, 0)
    other_fold = sk.sketched_gram(plan, x, 1)
    assert not np.array_equal(np.asarray(a), np.asarray(other_seed))
    assert not np.array_equal(np.asarray(a), np.asarray(other_fold))


def test_srht_full_hadamard_is_exact(folds):
    """m ≥ next_pow2(n) keeps every Hadamard row: SᵀS = I exactly, so the
    sketched Gram equals XᵀX to rounding — the degenerate end of the
    accuracy frontier."""
    x, _ = _train_design(folds)                    # (120, 24) → n2 = 128
    h_sk = sk.sketched_gram(_plan(m=128), x, 0)
    np.testing.assert_allclose(np.asarray(h_sk), np.asarray(x.T @ x),
                               rtol=1e-10, atol=1e-8)


def test_gaussian_gram_concentrates_with_m(folds):
    """Gaussian sketch error ≈ sqrt(h/m): quadrupling m must cut the
    relative Gram error (averaged over seeds to dodge draw luck)."""
    x, _ = _train_design(folds)
    exact = np.asarray(x.T @ x)
    scale = np.linalg.norm(exact)

    def rel(m):
        errs = [np.linalg.norm(np.asarray(
            sk.sketched_gram(sk.SketchPlan(method="gaussian", m=m, seed=s),
                             x, 0)) - exact) / scale for s in range(3)]
        return float(np.mean(errs))

    lo, hi = rel(64), rel(1024)
    assert hi < lo / 2, (lo, hi)
    assert hi < 0.25


@given(plan=props.sketch_plans(), cfg=props.tall_skinny_design())
@settings(max_examples=8, deadline=None)
def test_sketched_gram_psd_property(plan, cfg):
    """Property: every plan drawn from the shared strategy produces a
    symmetric PSD Gram for every tall-skinny geometry."""
    f = props.tall_skinny_folds(**cfg)
    x, _ = _train_design(f)
    h_sk = np.asarray(sk.sketched_gram(plan, x, 0))
    np.testing.assert_allclose(h_sk, h_sk.T, rtol=0, atol=0)
    evals = np.linalg.eigvalsh(h_sk)
    assert evals.min() >= -1e-6 * max(1.0, evals.max())


def test_plan_validation_descriptor_json():
    p = _plan()
    assert p.descriptor() == "srht/m128/seed0/ihs1"
    assert sk.SketchPlan.from_json(p.to_json()) == p
    assert sk.as_plan(None) is None
    assert sk.as_plan(p) is p
    assert sk.as_plan(dict(method="gaussian", m=64)) == sk.SketchPlan(
        method="gaussian", m=64)
    with pytest.raises(ValueError, match="method"):
        sk.SketchPlan(method="subgaussian")
    with pytest.raises(ValueError, match="m"):
        sk.SketchPlan(m=0)
    with pytest.raises(ValueError, match="ihs_iters"):
        sk.SketchPlan(ihs_iters=-1)
    with pytest.raises(TypeError, match="SketchPlan"):
        sk.as_plan("countsketch/m256")


# ------------------------------------------------------- IHS refinement


def test_ihs_error_contracts_geometrically(folds):
    """IHS contract (arXiv:1411.0347): preconditioning with the
    interpolated *sketched* factor while computing exact residuals
    contracts the solve error geometrically in the iteration count, down
    to the interpolation floor."""
    x, y = _train_design(folds)
    h_tr, g_tr = x.T @ x, x.T @ y
    plan = sk.SketchPlan(method="gaussian", m=384, seed=0)
    h_sk = sk.sketched_gram(plan, x, 0)
    anchors = picholesky.choose_sample_lambdas(1e-3, 1e2, 4)
    model = picholesky.fit(h_sk, anchors, 2, block=8)
    lams = props.log_grid(5)
    exact = solvers.solve_cholesky_sweep(h_tr, g_tr, lams)
    scale = float(jnp.linalg.norm(exact))
    theta0 = model.solve(lams, g_tr)

    errs = []
    for iters in range(4):
        th = picholesky.refine_solutions(model, h_tr, g_tr, lams, theta0,
                                         iters=iters)
        errs.append(float(jnp.linalg.norm(th - exact)) / scale)
    for prev, cur in zip(errs, errs[1:]):
        assert cur < 0.9 * prev + 1e-12, errs
    assert errs[3] < 0.2 * errs[0], errs


def test_sketched_engine_tightens_toward_dense_with_m(folds):
    """Engine-level frontier: as m grows the sketched hold-out curve
    approaches the dense curve, and the sketched pick's *regret on the
    dense curve* is negligible — λ-selection agreement, robust to the
    noise-dominated plateau."""
    dense = engine.CVEngine("picholesky").run(folds, LAMS)
    ed = np.asarray(dense.errors)
    native = props.active_precision().is_native
    relax = 1.0 if native else 10.0

    diffs = {}
    for m in (512, 2048):
        r = engine.CVEngine("picholesky", sketch=dict(
            method="countsketch", m=m, seed=0, ihs_iters=2)).run(folds, LAMS)
        e = np.asarray(r.errors)
        diffs[m] = float(np.max(np.abs(e - ed)))
        regret = ed[int(np.argmin(e))] - ed.min()
        assert regret <= 0.01 * relax, (m, regret)
    assert diffs[2048] < diffs[512] + (0.0 if native else 0.05), diffs
    assert diffs[2048] < 0.01 * relax, diffs


def test_sketched_thm44_bound_dominates(folds):
    """Thm 4.4/4.7 dominance survives sketched anchors: the analytic
    bound evaluated on the *sketched* Gram dominates the observed
    interpolation error of the sketched factors (the bound machinery
    sees only an SPD matrix — which matrix it is must not matter)."""
    d = 8
    x_np = np.random.RandomState(1).randn(3 * d * 4, d)
    x = jnp.asarray(x_np / np.sqrt(x_np.shape[0]))   # unit-scale XᵀX
    for method, m in (("gaussian", 256), ("countsketch", 512)):
        plan = sk.SketchPlan(method=method, m=m, seed=0)
        a = sk.sketched_gram(plan, x, 0) + jnp.eye(d, dtype=x.dtype)
        lam_c, w, gamma = 0.6, 0.15, 0.15
        sample = jnp.linspace(lam_c - w, lam_c + w, 5)
        model = picholesky.fit(a, sample, 2, block=4)
        rhs = float(bound.picholesky_bound(a, sample, lam_c, gamma))
        big_d = d * (d + 1) / 2.0
        worst = 0.0
        for lam in np.linspace(lam_c - gamma, lam_c + gamma, 9):
            l_i = model.eval_factor(jnp.asarray(lam))
            l_e = jnp.linalg.cholesky(a + lam * jnp.eye(d, dtype=a.dtype))
            worst = max(worst,
                        float(jnp.linalg.norm(l_i - l_e)) / np.sqrt(big_d))
        assert worst <= rhs * 1.01, (method, worst, rhs)


# ----------------------------------------------------- cache + warm replay


def test_sketched_warm_replay_zero_factorizations(folds):
    """Cold sketched run populates; a fresh engine over the warm cache
    traces ZERO cholesky calls and reproduces the cold curve bitwise —
    the tentpole's 'downstream unchanged' floor."""
    cache = factor_cache.FactorCache()
    cold_bk = CountingBackend(props.make_backend("reference"))
    r_cold = engine.CVEngine(_strat(), backend=cold_bk, cache=cache
                             ).run(folds, LAMS)
    assert cold_bk.n_cholesky > 0
    assert r_cold.extras["engine"]["cache"]["status"] == "miss"

    warm_bk = CountingBackend(props.make_backend("reference"))
    r_warm = engine.CVEngine(_strat(), backend=warm_bk, cache=cache
                             ).run(folds, LAMS)
    assert warm_bk.n_cholesky == 0
    assert r_warm.extras["engine"]["cache"]["status"] == "hit"
    assert r_warm.n_exact_chol == 0
    np.testing.assert_array_equal(r_warm.errors, r_cold.errors)


def test_sketched_cache_persistence_bitwise(folds, tmp_path):
    """save → load → warm sketched sweep is bitwise identical to the
    in-memory warm sweep, and the persisted key carries the descriptor."""
    cache = factor_cache.FactorCache()
    engine.CVEngine(_strat(), cache=cache).run(folds, LAMS)
    cache.save(str(tmp_path))
    loaded = factor_cache.FactorCache.load(str(tmp_path))
    assert sorted(loaded.entries) == sorted(cache.entries)
    (back,) = loaded.entries.values()
    assert back.key.sketch == _plan().descriptor()

    r_mem = engine.CVEngine(_strat(), cache=cache).run(folds, LAMS)
    r_disk = engine.CVEngine(_strat(), cache=loaded).run(folds, LAMS)
    assert r_disk.extras["engine"]["cache"]["status"] == "hit"
    np.testing.assert_array_equal(r_mem.errors, r_disk.errors)


def test_sketch_descriptor_in_cache_key(folds):
    """The descriptor is a first-class CacheKey field: it survives JSON,
    feeds all three digests (exact, covering, anchor-reuse), and exact
    vs sketched keys can never alias."""
    h_tr = folds.hess[None] - folds.fold_hess
    meta = _strat().cache_meta(LAMS)
    assert meta["sketch"] == _plan().descriptor()
    key = factor_cache.make_key(h_tr, meta["anchors"], block=8,
                                backend="reference", params=meta["params"],
                                sketch=meta["sketch"])
    assert factor_cache.CacheKey.from_json(key.to_json()).sketch == key.sketch
    exact_key = factor_cache.make_key(h_tr, meta["anchors"], block=8,
                                      backend="reference",
                                      params=meta["params"])
    assert exact_key.sketch == "exact"
    assert key.digest() != exact_key.digest()
    assert key.base_digest() != exact_key.base_digest()
    assert key.anchor_digest() != exact_key.anchor_digest()


_SKETCH_MUTATIONS = {
    "changed_method": dict(strat=lambda: _strat(
        sketch=dict(method="countsketch", m=128, seed=0, ihs_iters=1))),
    "changed_m": dict(strat=lambda: _strat(sketch=_plan(m=64))),
    "changed_seed": dict(strat=lambda: _strat(sketch=_plan(seed=7))),
    "changed_ihs_iters": dict(strat=lambda: _strat(sketch=_plan(ihs_iters=3))),
    "sketched_vs_exact": dict(strat=lambda: engine.PiCholeskyStrategy(
        g=4, block=8)),
}


@pytest.mark.parametrize("mutation", sorted(_SKETCH_MUTATIONS))
def test_sketch_fingerprint_mismatch_misses_and_repopulates(folds, mutation):
    """Negative contract (mirrors the factor-cache matrix): every sketch
    descriptor ingredient invalidates — the mutated run MUST miss, must
    equal its fresh cold run, and must repopulate to a hit."""
    cache = factor_cache.FactorCache()
    engine.CVEngine(_strat(), cache=cache).run(folds, LAMS)
    assert len(cache) == 1

    m_strat = _SKETCH_MUTATIONS[mutation]["strat"]
    r = engine.CVEngine(m_strat(), cache=cache).run(folds, LAMS)
    assert r.extras["engine"]["cache"]["status"] == "miss", mutation
    assert len(cache) == 2

    fresh = engine.CVEngine(m_strat()).run(folds, LAMS)
    np.testing.assert_allclose(r.errors, fresh.errors,
                               **props.parity_tol(1e-7, 1e-9))
    r2 = engine.CVEngine(m_strat(), cache=cache).run(folds, LAMS)
    assert r2.extras["engine"]["cache"]["status"] == "hit", mutation
    np.testing.assert_array_equal(r2.errors, r.errors)


def test_exact_request_never_served_by_sketched_entry(folds):
    """The other direction of the aliasing contract: populate sketched
    first; an exact request misses and computes its own (different)
    answer."""
    cache = factor_cache.FactorCache()
    r_sk = engine.CVEngine(_strat(sketch=_plan(m=64)), cache=cache
                           ).run(folds, LAMS)
    r_ex = engine.CVEngine(engine.PiCholeskyStrategy(g=4, block=8),
                           cache=cache).run(folds, LAMS)
    assert r_ex.extras["engine"]["cache"]["status"] == "miss"
    fresh = engine.CVEngine(engine.PiCholeskyStrategy(g=4, block=8)
                            ).run(folds, LAMS)
    np.testing.assert_allclose(r_ex.errors, fresh.errors,
                               **props.parity_tol(1e-9, 1e-12))
    assert not np.array_equal(np.asarray(r_ex.errors), np.asarray(r_sk.errors))


# ------------------------------------------- engine wiring + selection


def test_engine_sketch_kwarg_wiring(folds):
    """CVEngine(sketch=...) promotes the exact strategy, normalizes dicts,
    rejects conflicts, a plan-less sketched strategy, and non-anchored
    strategies."""
    eng = engine.CVEngine("picholesky", sketch=dict(method="srht", m=128))
    assert isinstance(eng.strategy, engine.PiCholeskySketched)
    assert eng.strategy.sketch == sk.SketchPlan(method="srht", m=128)
    eng2 = engine.CVEngine(engine.PiCholeskySketched(g=4, block=8),
                           sketch=_plan())
    assert eng2.strategy.sketch == _plan()
    with pytest.raises(ValueError, match="sketch"):
        engine.CVEngine(_strat(sketch=_plan(seed=1)), sketch=_plan(seed=2))
    with pytest.raises(ValueError, match="sketch"):
        engine.CVEngine(engine.PiCholeskySketched(g=4, block=8))
    with pytest.raises(ValueError, match="sketch"):
        engine.CVEngine("exact", sketch=_plan())
    assert engine.make_strategy("picholesky_sketched",
                                sketch=_plan()).name == "picholesky_sketched"


def test_select_interpolant_over_sketched_anchors(folds):
    """Satellite: interpolant selection over *sketched* anchor targets —
    a cold selection parks an anchors-only entry whose key carries the
    sketch descriptor; re-selection serves from it with ZERO
    factorizations; the winning engine's sweep refits from the parked
    anchors."""
    cache = factor_cache.FactorCache()
    bk = CountingBackend(ReferenceBackend())
    eng = engine.CVEngine(_strat(), backend=bk, cache=cache,
                          cache_anchors=True)
    sel = eng.select_interpolant(folds, LAMS)
    assert sel["anchor_status"] == "cold+cached"
    assert bk.n_cholesky > 0

    (entry,) = cache.entries.values()
    assert entry.state is None and entry.anchors is not None
    assert entry.key.sketch == _plan().descriptor()

    bk.reset()
    sel2 = eng.select_interpolant(folds, LAMS)
    assert sel2["anchor_status"] == "anchors"
    assert bk.n_cholesky == 0
    assert (sel2["degree"], sel2["basis"]) == (sel["degree"], sel["basis"])

    win = eng.with_interpolant(sel["degree"], sel["basis"])
    r = win.run(folds, LAMS)
    assert r.extras["engine"]["cache"]["status"] in ("refit", "hit")
    assert bk.n_cholesky == 0


def test_advise_anchor_on_sketched_strategy(folds):
    """The bound-guided anchor advisor accepts the sketched strategy
    (it is anchored) and round-trips the probe geometry."""
    eng = engine.CVEngine(_strat())
    out = eng.advise_anchor(folds, LAMS, probe_dim=8, n_grid=3)
    assert out["probe_dim"] == 8
    assert len(out["anchors"]) == 4
    lo, hi = out["intervals"][out["worst"]]
    assert lo < out["proposal"] < hi


# --------------------------------------------------- parity + async


@pytest.mark.tier2
@given(backend=props.backend_names(), plan=props.sketch_plans())
@settings(max_examples=6, deadline=None)
def test_backend_parity_sketched(backend, plan):
    """Property: for every plan in the shared strategy, the sketched
    sweep on the pallas backend selects equivalently to reference (the
    sketch is backend-independent; only factorize/substitute kernels
    differ)."""
    folds = props.tall_skinny_folds(h=16, n=128, k=4, seed=0)
    ref = engine.CVEngine(_strat(sketch=plan)).run(folds, LAMS)
    alt = engine.CVEngine(_strat(sketch=plan),
                          backend=props.make_backend(backend)
                          ).run(folds, LAMS)
    np.testing.assert_allclose(alt.errors, ref.errors,
                               **props.parity_tol(1e-6, 1e-8))
    props.assert_selection_close(alt.errors, ref.errors)


def test_run_async_matches_run_sketched(folds):
    """The chunked async sweep consumes sketched anchors unchanged."""
    r_fused = engine.CVEngine(_strat()).run(folds, LAMS)
    r_async = engine.CVEngine(_strat(), lam_chunk=7).run_async(folds, LAMS)
    np.testing.assert_allclose(r_async.errors, r_fused.errors,
                               **props.parity_tol(1e-9, 1e-12))
    props.assert_selection_close(r_async.errors, r_fused.errors)
