"""End-to-end behaviour: the paper's claim on synthetic data (piCholesky CV
selects the exact-CV λ at ~1/8 the factorization count), kernel-backed CV,
and the full LM-probe path (DESIGN.md §4.1)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.core import cv, picholesky
from repro.data import make_regression_dataset, random_polynomial_features
from repro.models.model import Model


def _dataset():
    return make_regression_dataset(jax.random.PRNGKey(7), 360, 192,
                                   dtype=jnp.float64)


def test_picholesky_cv_end_to_end():
    x, y = _dataset()
    folds = cv.make_folds(x, y, 5)
    lams = jnp.logspace(-3, 2, 31)
    r_exact = cv.cv_exact_cholesky(folds, lams)
    r_pi = cv.cv_picholesky(folds, lams, g=4, block=32)

    # selection parity (paper Table 4)
    i_e, i_p = int(np.argmin(r_exact.errors)), int(np.argmin(r_pi.errors))
    assert abs(i_e - i_p) <= 1
    # cost: 20 vs 155 factorizations (paper's ~4-8x speedup driver)
    assert r_pi.n_exact_chol * 7 <= r_exact.n_exact_chol
    # hold-out error at the selected λ matches exact to <1%
    assert abs(r_exact.errors[i_p] - r_exact.best_error) < 0.01 * r_exact.best_error
    # error curves agree near the optimum (±2 grid steps)
    lo, hi = max(i_e - 2, 0), min(i_e + 3, len(lams))
    np.testing.assert_allclose(r_pi.errors[lo:hi], r_exact.errors[lo:hi],
                               rtol=0.05)


def test_picholesky_cv_with_pallas_kernels():
    """Same CV driven by the Pallas blocked-Cholesky kernel."""
    from repro.kernels.chol_blocked import cholesky_blocked
    x, y = make_regression_dataset(jax.random.PRNGKey(3), 220, 96,
                                   dtype=jnp.float64)
    folds = cv.make_folds(x, y, 4)
    lams = jnp.logspace(-2, 1, 11)
    chol = lambda a: cholesky_blocked(a, block=16)
    r_k = cv.cv_picholesky(folds, lams, g=4, block=16, chol_fn=chol)
    r_j = cv.cv_picholesky(folds, lams, g=4, block=16)
    np.testing.assert_allclose(r_k.errors, r_j.errors, rtol=1e-6)


def test_multilevel_cholesky_narrows_range():
    x, y = _dataset()
    folds = cv.make_folds(x, y, 5)
    r_m = cv.cv_multilevel_cholesky(folds, c=0.0, s=1.5, s0=0.05)
    lams = jnp.logspace(-3, 2, 31)
    r_e = cv.cv_exact_cholesky(folds, lams)
    # MChol converges to within half a decade of the exact optimum
    assert abs(np.log10(r_m.best_lam) - np.log10(r_e.best_lam)) < 0.5


def test_lm_probe_ridge_cv():
    """Hidden states from a zoo model -> piCholesky-CV'd linear probe."""
    cfg = configs.get("smollm-360m").reduced()
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0,
                                cfg.vocab_size)
    logits, _ = jax.jit(m.forward)(params, tokens)
    feats = logits.reshape(-1, cfg.vocab_size)[:, :64].astype(jnp.float64)
    feats = jnp.concatenate([feats, jnp.ones((feats.shape[0], 1),
                                             jnp.float64)], 1)
    y = feats @ jax.random.normal(jax.random.PRNGKey(2), (65,), jnp.float64)
    folds = cv.make_folds(feats, y, 4)
    lams = jnp.logspace(-3, 1, 11)
    r = cv.cv_picholesky(folds, lams, g=4, block=16)
    assert np.isfinite(r.best_error)
    assert r.n_exact_chol == 16
