"""Regression guard for the paper's Table 4 claim at test scale:
piCholesky's interpolated hold-out curve tracks exact CV near the argmin
(where model selection happens), and selects the same λ."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import cv
from repro.data import make_regression_dataset


@pytest.fixture(scope="module")
def results():
    x, y = make_regression_dataset(jax.random.PRNGKey(11), 420, 144,
                                   dtype=jnp.float64)
    folds = cv.make_folds(x, y, 5)
    lams = jnp.logspace(-3, 2, 31)
    r_exact = cv.cv_exact_cholesky(folds, lams)
    r_pi = cv.cv_picholesky(folds, lams, g=4, block=32)
    return lams, r_exact, r_pi


def test_selected_lambda_within_one_grid_step(results):
    _, r_exact, r_pi = results
    i_e = int(np.argmin(r_exact.errors))
    i_p = int(np.argmin(r_pi.errors))
    assert abs(i_e - i_p) <= 1


def test_holdout_curve_tracks_exact_near_argmin(results):
    """Within ±3 grid steps of the exact argmin the interpolated curve must
    sit on the exact curve (2% — Table 4's NRMSE agreement, shrunk)."""
    lams, r_exact, r_pi = results
    i_e = int(np.argmin(r_exact.errors))
    lo, hi = max(i_e - 3, 0), min(i_e + 4, len(lams))
    np.testing.assert_allclose(r_pi.errors[lo:hi], r_exact.errors[lo:hi],
                               rtol=0.02)


def test_error_at_selected_lambda_near_optimal(results):
    """Choosing piCholesky's λ* costs < 1% extra hold-out error vs the
    exact-CV optimum (the paper's 'selection, not estimation' framing)."""
    _, r_exact, r_pi = results
    i_p = int(np.argmin(r_pi.errors))
    assert (r_exact.errors[i_p] - r_exact.best_error) \
        < 0.01 * r_exact.best_error


def test_factorization_budget(results):
    _, r_exact, r_pi = results
    assert r_pi.n_exact_chol == 20           # k·g
    assert r_exact.n_exact_chol == 155       # k·q


def test_warmstart_selects_near_exact_on_second_problem(results):
    """Warm-started refresh holds the Table-4 selection property on a
    problem instance disjoint from test_engine's (guards against the
    anchor-prior fit regressing to edge-of-grid selection)."""
    x, y = make_regression_dataset(jax.random.PRNGKey(11), 420, 144,
                                   dtype=jnp.float64)
    folds = cv.make_folds(x, y, 5)
    lams, r_exact, _ = results
    r_w = cv.cv_picholesky_warmstart(folds, lams, g_first=4, g_rest=2,
                                     block=32)
    i_e = int(np.argmin(r_exact.errors))
    i_w = int(np.argmin(r_w.errors))
    assert abs(i_e - i_w) <= 1
    assert r_w.n_exact_chol == 4 + 5 * 2
