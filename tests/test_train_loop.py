"""Training-loop fault tolerance: auto-resume and straggler accounting."""
import itertools

import jax
import jax.numpy as jnp

from repro import configs
from repro.models.model import Model
from repro.optim import adamw
from repro.train import TrainLoop, TrainLoopConfig, make_train_step
from repro.data import token_stream


def _setup():
    cfg = configs.get("smollm-360m").reduced()
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    opt = adamw(lr=1e-3)
    step = jax.jit(make_train_step(m, opt))
    data = token_stream(jax.random.PRNGKey(1), cfg.vocab_size, 2, 16)
    return m, params, opt, step, data


def test_loss_decreases():
    m, params, opt, step, data = _setup()
    loop = TrainLoop(TrainLoopConfig(total_steps=20, log_every=1),
                     step, params, opt[0](params))
    out = loop.run(itertools.islice(data, 30))
    losses = [e["loss"] for e in out["log"]]
    assert out["final_step"] == 20
    assert losses[-1] < losses[0]


def test_resume_from_checkpoint(tmp_path):
    m, params, opt, step, data = _setup()
    cfg1 = TrainLoopConfig(total_steps=6, ckpt_every=3, ckpt_dir=str(tmp_path),
                           log_every=1)
    loop1 = TrainLoop(cfg1, step, params, opt[0](params))
    loop1.run(itertools.islice(data, 10))     # "crash" after 6 steps

    # new process: same args; must resume at step 6, not 0
    cfg2 = TrainLoopConfig(total_steps=10, ckpt_every=3, ckpt_dir=str(tmp_path),
                           log_every=1)
    loop2 = TrainLoop(cfg2, step, params, opt[0](params))
    assert loop2.start_step == 6
    out = loop2.run(itertools.islice(data, 10))
    assert out["final_step"] == 10


def test_microbatched_step_matches_full():
    from repro.train.steps import make_train_step
    cfg = configs.get("smollm-360m").reduced()
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    opt = adamw(lr=1e-2)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(2), (4, 16), 0,
                                          cfg.vocab_size),
             "labels": jax.random.randint(jax.random.PRNGKey(3), (4, 16), 0,
                                          cfg.vocab_size)}
    s1 = jax.jit(make_train_step(m, opt, microbatches=1))
    s2 = jax.jit(make_train_step(m, opt, microbatches=2))
    p1, _, m1 = s1(params, opt[0](params), batch)
    p2, _, m2 = s2(params, opt[0](params), batch)
    # same gradient in exact arithmetic; small fp tolerance
    dev = jax.tree.reduce(max, jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a - b))), p1, p2))
    assert dev < 1e-4
